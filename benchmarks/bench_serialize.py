"""Paper Fig. 1 analogue: message-bus tensor forwarding vs device-native.

The paper shows Kafka collapsing to ~147 MB/s at 400KB tensors because every
hop pays device->host copy + serialization (45% of sender time) and the
reverse (53% of receiver time). We reproduce the *structure* of that result
with transport codecs: zero-copy (device-native reference passing, the
NCCL/ICI analogue), serialize (pickle + host round-trip, the message-bus
analogue), and IPC (serialize + extra staging copy, the MultiProcessing
analogue of §4.3).
"""
from __future__ import annotations

import asyncio
import time

from repro.core import Cluster, Codec, IPCCodec, SerializeCodec

from .common import TENSOR_SIZES, make_tensor, run_async

N_TENSORS = 200


async def _throughput(codec, n_floats: int) -> float:
    """Returns GB/s for one sender -> one receiver."""
    c = Cluster(codec=codec)
    a, b = c.worker("A"), c.worker("B")
    await asyncio.gather(
        a.manager.initialize_world("w", 0, 2),
        b.manager.initialize_world("w", 1, 2),
    )
    x = make_tensor(n_floats)
    nbytes = x.nbytes

    async def sender():
        for _ in range(N_TENSORS):
            await a.comm.send(x, 1, "w")

    async def receiver():
        for _ in range(N_TENSORS):
            got = await b.comm.recv(0, "w")
            got.block_until_ready()

    t0 = time.monotonic()
    await asyncio.gather(sender(), receiver())
    dt = time.monotonic() - t0
    c.shutdown()
    return N_TENSORS * nbytes / dt / 1e9


def run() -> list[tuple[str, float, str]]:
    rows = []
    for size_name, n in TENSOR_SIZES.items():
        for codec_name, codec in (("zero_copy", None),
                                  ("serialize", SerializeCodec()),
                                  ("ipc", IPCCodec())):
            gbps = run_async(_throughput(codec, n))
            rows.append((f"fig1_forwarding/{size_name}/{codec_name}",
                         gbps, "GB/s"))
    return rows

"""Disaggregation benchmark: split prefill/decode pools A/B'd against the
colocated (``role='both'``) baseline under a mixed prefill-heavy workload.

One replicated stage, same replica budget in both runs:

* **colocated** — 3 ``both`` replicas; every replica serves long prefill
  dispatches and short decode steps, so a burst of long prompts convoys
  decode microbatches behind prefills (the interference the serving-
  optimization survey calls out).
* **split** — 1 ``prefill`` + 2 ``decode`` replicas; prefills queue on the
  prefill pool, freshly built KV caches stream to a decode-pool home over
  the statexfer chunked codec (HANDOFF envelopes), and decode steps never
  share a serve loop with a prefill again.

The workload runs decode-heavy sessions (short prompt, long generation,
per-token timestamps) concurrently with prefill-heavy lanes (long prompt,
2 tokens, continuous). Acceptance (ISSUE 5): the split run sustains >= the
colocated decode tokens/s with lower p95 decode latency, zero
client-visible failures, greedy token parity across the handoff, and the
colocated run does zero handoffs (the ``role='both'`` path is untouched).

  PYTHONPATH=src python -m benchmarks.bench_disagg [--tiny] [--json OUT]

``--tiny`` shrinks the scenario for CI smoke (wall-clock-sensitive gates
are skipped; parity/zero-failure/handoff gates always hold); ``--json``
writes the rows + raw scenario dict (BENCH_disagg.json in CI).
"""
from __future__ import annotations

import argparse
import asyncio
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models import DENSE, BlockGroup, build_model
from repro.core import Cluster
from repro.serving import PipelineServer, ServeEngine

from .common import (collect_obs, run_async, trace_path_for,
                     write_bench_json, write_trace_json)

DECODE_PROMPT = 8
PREFILL_PROMPT = 40      # buckets to the 64-wide prefill executable


def _build():
    cfg = get_smoke("llama3.2-1b").with_(num_layers=2,
                                         groups=(BlockGroup(DENSE, 2),))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n, seed, seq):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (1, seq)) for _ in range(n)]


def _p95(xs: list[float]) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, int(0.95 * len(s)))]


async def _mixed_scenario(split: bool, tiny: bool) -> dict:
    cfg, model, params = _build()
    engine = ServeEngine(model, params, max_len=64)
    cluster = Cluster()
    spec = {"prefill": 1, "decode": 2} if split else 3
    server = PipelineServer(cluster, model, params, [spec], max_len=64)
    await server.start()

    # the A/B isolates *interference*, so decode demand must fit the decode
    # pool (2 sessions per decode replica) in both modes; full mode runs a
    # longer steady state under heavier prefill pressure instead of
    # overcommitting the decode pool
    d_sessions = 4
    d_tokens = 12 if tiny else 32
    lanes = 3 if tiny else 5

    d_prompts = _prompts(cfg, d_sessions, seed=1, seq=DECODE_PROMPT)
    l_prompts = _prompts(cfg, lanes, seed=2, seq=PREFILL_PROMPT)
    d_wants = [engine.generate(p, d_tokens) for p in d_prompts]
    l_wants = [engine.generate(p, 2) for p in l_prompts]

    # warm both pools off-clock: two rounds of the real mixed traffic (like
    # bench_generate/bench_migrate), then an explicit profile replay so
    # every decode convoy width the measurement can coalesce to is
    # compiled — traffic-only warmup is timing-dependent and a
    # mid-measurement width compile masquerades as interference
    for _ in range(2):
        await asyncio.gather(
            *(server.generate(p, 3, step_timeout=120.0) for p in d_prompts),
            *(server.generate(p, 2, step_timeout=120.0) for p in l_prompts))
    profile = {"prefill": [((1, 8), "int32"), ((1, 64), "int32")],
               "widths": [2, 4, 8]}
    for ex in {id(r.executor): r.executor
               for reps in server.replicas for r in reps}.values():
        ex.warm(profile)

    failures = 0
    stop = asyncio.Event()
    lane_outs: list[list] = [[] for _ in range(lanes)]

    async def prefill_lane(i: int) -> None:
        nonlocal failures
        while not stop.is_set():
            try:
                out = await server.generate(l_prompts[i], 2,
                                            step_timeout=60.0)
                lane_outs[i].append(out)
            except Exception:  # noqa: BLE001 — gate counts every failure
                failures += 1

    token_times: list[list[float]] = [[] for _ in range(d_sessions)]

    async def decode_session(i: int):
        return await server.generate(d_prompts[i], d_tokens,
                                     step_timeout=60.0,
                                     token_times=token_times[i])

    lane_tasks = [asyncio.ensure_future(prefill_lane(i))
                  for i in range(lanes)]
    t0 = time.monotonic()
    try:
        d_outs = await asyncio.gather(
            *(decode_session(i) for i in range(d_sessions)))
    except Exception:  # noqa: BLE001
        failures += 1
        d_outs = []
    wall = time.monotonic() - t0
    stop.set()
    await asyncio.gather(*lane_tasks, return_exceptions=True)

    parity = (len(d_outs) == d_sessions
              and all(np.array_equal(w, g)
                      for w, g in zip(d_wants, d_outs))
              and all(np.array_equal(l_wants[i], out)
                      for i in range(lanes) for out in lane_outs[i]))
    intertoken = [b - a for times in token_times
                  for a, b in zip(times, times[1:])]
    m = server.migrations.stats()
    stats = server.replica_stats()
    out = {
        "split": split,
        "decode_sessions": d_sessions,
        "decode_tokens": d_sessions * d_tokens,
        "prefill_lane_requests": sum(len(o) for o in lane_outs),
        "wall_s": wall,
        "decode_tokens_per_s": d_sessions * d_tokens / max(wall, 1e-9),
        "decode_p50_s": (sorted(intertoken)[len(intertoken) // 2]
                         if intertoken else 0.0),
        "decode_p95_s": _p95(intertoken),
        "token_parity": parity,
        "failures": failures,
        "handoffs": m["handoffs_total"],
        "handoff_failures": m["handoff_failures"],
        "handoff_p50_s": m["handoff_p50_s"],
        "handoff_bytes": m["handoff_bytes_total"],
        "reprefills": m["reprefills_total"],
        "retries": sum(s["retries_sent"] for s in stats.values()),
        "decode_steps_on_prefill_pool": sum(
            s["decode_steps"] for s in stats.values()
            if s["role"] == "prefill"),
        "obs": collect_obs(server),
    }
    cluster.shutdown()
    return out


async def _scenario(tiny: bool) -> dict:
    return {
        "colocated": await _mixed_scenario(split=False, tiny=tiny),
        "split": await _mixed_scenario(split=True, tiny=tiny),
    }


def run(tiny: bool = False, json_path: str | None = None
        ) -> list[tuple[str, float, str]]:
    r = run_async(_scenario(tiny))
    co, sp = r["colocated"], r["split"]
    rows = [
        ("disagg_decode_tokens_per_s/split", sp["decode_tokens_per_s"],
         f"{sp['decode_sessions']} sessions + "
         f"{sp['prefill_lane_requests']} prefill-heavy requests"),
        ("disagg_decode_tokens_per_s/colocated", co["decode_tokens_per_s"],
         f"{co['decode_sessions']} sessions + "
         f"{co['prefill_lane_requests']} prefill-heavy requests"),
        ("disagg_decode_p95_ms/split", sp["decode_p95_s"] * 1e3,
         "inter-token latency under prefill interference"),
        ("disagg_decode_p95_ms/colocated", co["decode_p95_s"] * 1e3,
         "inter-token latency under prefill interference"),
        ("disagg_decode_p50_ms/split", sp["decode_p50_s"] * 1e3, ""),
        ("disagg_decode_p50_ms/colocated", co["decode_p50_s"] * 1e3, ""),
        ("disagg_handoffs", float(sp["handoffs"]),
         f"prefill->decode KV handoffs "
         f"(p50 {sp['handoff_p50_s'] * 1e3:.1f} ms, "
         f"{sp['handoff_bytes']}B)"),
        ("disagg_failures/split", float(sp["failures"]),
         "must be 0 — zero client-visible failures"),
        ("disagg_failures/colocated", float(co["failures"]), "must be 0"),
    ]
    # acceptance gates (ISSUE 5)
    assert sp["token_parity"], \
        "greedy token parity lost across the prefill->decode handoff"
    assert co["token_parity"], "colocated (role='both') parity lost"
    assert sp["failures"] == 0 and co["failures"] == 0, (sp, co)
    assert sp["handoffs"] >= sp["decode_sessions"], sp
    assert co["handoffs"] == 0, \
        f"role='both' run must never hand off: {co}"
    assert sp["reprefills"] == 0 and sp["handoff_failures"] == 0, sp
    assert sp["decode_steps_on_prefill_pool"] == 0, \
        f"decode leaked into the prefill pool: {sp}"
    if not tiny:
        # the A/B gate: dedicated decode capacity must not lose throughput
        # and must cut tail latency under prefill interference
        assert sp["decode_tokens_per_s"] >= co["decode_tokens_per_s"], \
            (f"split {sp['decode_tokens_per_s']:.1f} tok/s < colocated "
             f"{co['decode_tokens_per_s']:.1f} tok/s")
        assert sp["decode_p95_s"] < co["decode_p95_s"], \
            (f"split p95 {sp['decode_p95_s'] * 1e3:.1f}ms not under "
             f"colocated {co['decode_p95_s'] * 1e3:.1f}ms")
    if json_path:
        # obs snapshots ride the trace artifact, not the bench metrics doc
        phases = {k: v.pop("obs", {}) for k, v in r.items()}
        write_bench_json(json_path, suite="disagg", rows=rows, raw=r,
                         tiny=tiny)
        write_trace_json(trace_path_for(json_path, "disagg"),
                         suite="disagg", phases=phases)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small scenario, no wall-clock gates")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write rows + raw results as JSON artifact")
    args = ap.parse_args()
    for name, value, derived in run(tiny=args.tiny, json_path=args.json):
        print(f"{name},{value:.4f},{derived}")

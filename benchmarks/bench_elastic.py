"""Closed-loop elasticity benchmark: the controller the paper left open.

Timeline (one run, Fig. 5 in spirit but for the whole control plane):

  t=0      pipeline starts at [1, 1] replicas, controller on, calm Poisson
           traffic
  burst    an open-loop flash crowd arrives; per-replica backlog crosses the
           policy target; the controller scales the bottleneck stage up
  kill     one stage-1 replica is killed (silent hang) mid-burst; watchdogs
           fence its worlds; the controller heals it via online instantiation
  calm     the burst ends; backlog drains; the controller drains-and-removes
           surplus replicas back toward the floor

Pass criterion (ISSUE acceptance): zero client-visible request failures
across the whole scenario — redispatch, parked payloads, and drain-before-
remove together must hide every transition from the client.

  PYTHONPATH=src python -m benchmarks.bench_elastic
"""
from __future__ import annotations

import asyncio
import sys
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.control import (
    BurstProfile,
    ElasticController,
    HysteresisPolicy,
    OpenLoopGenerator,
    TargetQueueDepthPolicy,
)
from repro.core import Cluster, FailureKind
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import PipelineServer

from .common import run_async

BURST_T0, BURST_T1 = 1.0, 3.0
KILL_T = 2.0
DURATION = 8.0
BATCH, SEQ = 8, 64


async def _scenario() -> dict:
    cfg = get_smoke("llama3.2-1b").with_(num_layers=2,
                                         groups=(BlockGroup(DENSE, 2),))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    cluster = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.1)
    server = PipelineServer(cluster, model, params, replicas=[1, 1],
                            least_loaded=True)
    await server.start()

    policy = HysteresisPolicy(
        TargetQueueDepthPolicy(target=3.0, scale_down_at=0.3,
                               min_replicas=1, max_replicas=4),
        confirm=2, cooldown_s=0.8)
    ctrl = ElasticController(server, policy, interval=0.05)

    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (BATCH, SEQ))
    await server.submit(toks)          # warm the stage compiles off-clock

    # calibrate traffic to this machine: the burst must overwhelm one
    # replica (so the controller has to scale) regardless of host speed
    t0 = time.monotonic()
    for _ in range(10):
        await server.submit(toks)
    per_req = (time.monotonic() - t0) / 10
    capacity_rps = 1.0 / per_req
    # replicas on this single-host simulation share the same cores, so
    # scaling adds queue slots rather than FLOPs: a mild (1.35x) overload
    # builds the backlog that triggers the policy without accumulating
    # more work than the host can absorb before client timeouts
    burst_rps = min(100.0, max(15.0, 1.35 * capacity_rps))
    base_rps = min(6.0, max(1.0, 0.15 * capacity_rps))

    gen = OpenLoopGenerator(
        lambda: server.submit(toks, timeout=4.0, retries=3),
        BurstProfile(base=base_rps, burst=burst_rps,
                     t0=BURST_T0, t1=BURST_T1),
        seed=1)

    t_start = time.monotonic()
    replica_track: list[tuple[float, list[int]]] = []
    marks: list[tuple[float, str]] = []

    async def observer():
        killed = False
        while True:
            t = time.monotonic() - t_start
            replica_track.append((t, ctrl.replica_counts()))
            if not killed and t >= KILL_T:
                # kill a replica of whichever stage scaled out (guaranteeing
                # the watchdog->heal path runs while capacity still matters)
                scaled = [s for s in range(server.n_stages)
                          if len(server.healthy_replicas(s)) > 1]
                if scaled:
                    killed = True
                    victim = server.healthy_replicas(scaled[0])[0]
                    cluster.kill(victim, FailureKind.SILENT_HANG)
                    marks.append((t, f"kill {victim}"))
            await asyncio.sleep(0.05)

    ctrl.start()
    obs = asyncio.ensure_future(observer())
    summary = await gen.run(DURATION)
    # let the backlog fully drain, then give scale-down a chance to fire
    await asyncio.sleep(1.5)
    await ctrl.step()
    await ctrl.stop()
    obs.cancel()

    timeline = sorted(
        [(e.t - t_start, e.kind, f"s{e.stage} {e.detail}")
         for e in ctrl.timeline]
        + [(t, "mark", m) for t, m in marks])
    peak = max(sum(counts) for _, counts in replica_track)
    final = ctrl.replica_counts()
    cluster.shutdown()
    return {
        "summary": summary,
        "timeline": timeline,
        "controller": ctrl,
        "peak_total_replicas": peak,
        "final_counts": final,
    }


def run() -> list[tuple[str, float, str]]:
    r = run_async(_scenario())
    s, ctrl = r["summary"], r["controller"]

    print("# elastic control timeline (t, event, detail)", file=sys.stderr)
    for t, kind, detail in r["timeline"]:
        print(f"#  {t:7.2f}s  {kind:<11} {detail}", file=sys.stderr)

    rows = [
        ("elastic_requests_ok", float(s["ok"]), "client-visible successes"),
        ("elastic_requests_failed", float(s["failed"]),
         "must be 0 — transitions hidden from clients"),
        ("elastic_p50_ms", s["p50_s"] * 1e3, "across the whole scenario"),
        ("elastic_p95_ms", s["p95_s"] * 1e3, "includes burst + kill window"),
        ("elastic_scale_ups", float(ctrl.scale_ups),
         "controller-driven add_replica"),
        ("elastic_scale_downs", float(ctrl.scale_downs),
         "controller-driven drain-and-remove"),
        ("elastic_heals", float(ctrl.heals),
         "watchdog-fenced replicas auto-replaced"),
        ("elastic_peak_replicas", float(r["peak_total_replicas"]),
         "total across stages at burst peak"),
        ("elastic_final_replicas", float(sum(r["final_counts"])),
         "after post-burst scale-down"),
    ]
    # acceptance: scaled up under the burst, healed the kill, scaled back
    # down, and no client-visible failures anywhere
    assert s["failed"] == 0, f"client-visible failures: {s}"
    assert ctrl.scale_ups >= 1, "controller never scaled up under burst"
    assert ctrl.heals >= 1, "controller never healed the killed replica"
    assert ctrl.scale_downs >= 1, "controller never scaled back down"
    return rows


if __name__ == "__main__":
    for name, value, derived in run():
        print(f"{name},{value:.4f},{derived}")

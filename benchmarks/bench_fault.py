"""Paper Fig. 4 reproduction: fault tolerance timeline.

Setup (paper §4.1): a leader and two senders. Sender 1 sends one tensor per
tick, sender 2 every two ticks; sender 2 dies after its 10th tensor.

* Single world (all three in one world): the leader stalls — in the paper it
  stops receiving at the 22.3s mark; here the whole world is fenced and every
  subsequent receive aborts.
* MultiWorld (leader in two worlds): world 2 breaks and is cleaned up; world
  1 keeps delivering every tensor.

Reported: tensors delivered on each path + detection latency.
"""
from __future__ import annotations

import asyncio
import time

from repro.core import Cluster, FailureKind, WorldBrokenError

from .common import make_tensor, run_async

# timing scaled so the failure + watchdog detection land mid-run (the paper
# kills at the 20s mark of a ~30s run; we compress wall-clock 100x)
N_FAST = 80          # tensors sender 1 will send
N_BEFORE_DEATH = 10  # tensors sender 2 sends before dying
TICK = 0.005


async def _multiworld() -> dict:
    c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
    leader, s1, s2 = c.worker("L"), c.worker("S1"), c.worker("S2")
    await asyncio.gather(
        leader.manager.initialize_world("w1", 0, 2),
        s1.manager.initialize_world("w1", 1, 2),
        leader.manager.initialize_world("w2", 0, 2),
        s2.manager.initialize_world("w2", 1, 2),
    )
    return await _drive(c, leader, s1, s2, w_fast="w1", w_slow="w2")


async def _single_world() -> dict:
    c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
    leader, s1, s2 = c.worker("L"), c.worker("S1"), c.worker("S2")
    await asyncio.gather(
        leader.manager.initialize_world("w", 0, 3),
        s1.manager.initialize_world("w", 1, 3),
        s2.manager.initialize_world("w", 2, 3),
    )
    return await _drive(c, leader, s1, s2, w_fast="w", w_slow="w",
                        slow_rank=2)


async def _drive(c, leader, s1, s2, *, w_fast, w_slow, slow_rank=1) -> dict:
    x = make_tensor(1_000)
    received = {"fast": 0, "slow": 0}
    t_break = {}

    async def fast_sender():
        for _ in range(N_FAST):
            try:
                await s1.comm.send(x, 0, w_fast)
            except WorldBrokenError:
                return
            await asyncio.sleep(TICK)

    async def slow_sender():
        for _ in range(N_BEFORE_DEATH):
            await s2.comm.send(x, 0, w_slow)
            await asyncio.sleep(2 * TICK)
        c.kill("S2", FailureKind.SILENT_HANG)

    async def recv_loop(world, src_rank, key, n):
        for _ in range(n):
            try:
                await leader.comm.recv(src_rank, world)
                received[key] += 1
            except WorldBrokenError:
                t_break[key] = time.monotonic()
                return

    t0 = time.monotonic()
    await asyncio.gather(
        fast_sender(), slow_sender(),
        recv_loop(w_fast, 1, "fast", N_FAST),
        recv_loop(w_slow, slow_rank, "slow", N_FAST),
    )
    c.shutdown()
    return {"fast": received["fast"], "slow": received["slow"],
            "detect_s": (t_break.get("slow", t0) - t0)}


def run() -> list[tuple[str, float, str]]:
    mw = run_async(_multiworld())
    sw = run_async(_single_world())
    rows = [
        ("fig4_multiworld/fast_delivered", mw["fast"],
         f"of {N_FAST}; healthy world unaffected"),
        ("fig4_multiworld/slow_delivered", mw["slow"],
         f"<= {N_BEFORE_DEATH}; broken world fenced"),
        ("fig4_single_world/fast_delivered", sw["fast"],
         "single fault domain: fast sender collateral"),
        ("fig4_detection_latency_s", mw["detect_s"], "watchdog detection"),
    ]
    assert mw["fast"] == N_FAST, "MultiWorld must deliver every fast tensor"
    assert sw["fast"] < N_FAST, "single world must lose fast tensors"
    return rows

"""Fleet-scale observability benchmark: the telemetry plane under 10k
concurrent sessions.

The tentpole claim of the fleet-observability layer is that the telemetry
plane itself scales: sketches summarize tails without shipping samples,
digests aggregate hierarchically without changing decisions, sampling
bounds tracing cost, and burn-rate alerting pages on real regressions
only. Four gates, one per claim:

(a) **sketch accuracy** — LogSketch p95/p99 over the run's replayed TTFT
    stream are within the sketch's guaranteed relative error of the exact
    (sorted-list) percentiles, and merge order (flat vs shard-tree) cannot
    change an estimate;
(b) **digest/raw decision parity** — replaying the identical per-replica
    sample stream through the scaling policies via the flat fold
    (``shard=None``, the raw reference) and the hierarchical fold
    (``shard=N``, the fleet path) yields byte-identical decision records
    on every tick;
(c) **telemetry overhead** — an open-loop diurnal run over a stub
    executor fleet (10k+ concurrent stub sessions at peak in full mode),
    A/B with the full telemetry stack (sketch inserts, sampled tracing,
    SLO observation) vs telemetry-off, costs <= 5% tokens/s;
(d) **burn-rate alerting** — on a virtual-time request stream, an
    injected latency regression trips the multi-window burn-rate alert
    (and clears after recovery) while the steady baseline stays quiet.

  PYTHONPATH=src python -m benchmarks.bench_fleet [--tiny] [--json OUT]

``--tiny`` shrinks session counts/durations for CI smoke; gate (c) is
report-only there (an overhead *ratio* needs a run long enough to sit
above scheduler noise) and the concurrency floor drops accordingly.
"""
from __future__ import annotations

import argparse
import asyncio
import random
import time

from repro.control import (
    DiurnalProfile,
    OpenLoopGenerator,
    ReplicaSample,
    StageSnapshot,
    TailLatencySLOPolicy,
    TargetQueueDepthPolicy,
    TokenRatePolicy,
    TTFTSLOPolicy,
    percentile,
)
from repro.obs import LogSketch, SLOMonitor, SLOSpec, Tracer
from repro.obs.digest import fold_samples

from .common import (run_async, trace_path_for, write_bench_json,
                     write_trace_json)

FULL = {
    "duration_s": 6.0,
    "rate_mean": 5000.0,
    "rate_amp": 2500.0,
    "period_s": 6.0,
    "max_inflight": 20000,
    "chunk_s": 0.5,
    "chunks": 4,
    "concurrency_floor": 10_000,
    "replay_replicas": 96,
    "replay_ticks": 60,
    "shard": 8,
}
TINY = {
    "duration_s": 1.5,
    "rate_mean": 400.0,
    "rate_amp": 200.0,
    "period_s": 2.0,
    "max_inflight": 2000,
    "chunk_s": 0.25,
    "chunks": 3,
    "concurrency_floor": 100,
    "replay_replicas": 24,
    "replay_ticks": 20,
    "shard": 8,
}

TOKENS_PER_CHUNK = 8
TTFT_SLO_S = 0.02
DECODE_SLO_S = 1.5


# --------------------------------------------------------------------------
# gates (a) + (b): replayed sample stream -> sketch accuracy + fold parity
# --------------------------------------------------------------------------
def _replay_samples(seed: int, n_replicas: int, n_ticks: int):
    """Deterministic per-tick ReplicaSample streams for a synthetic stage:
    load swings diurnally, a few replicas fail mid-run, latencies are
    log-normal with a heavy decode tail. Returns (ticks, exact_ttfts):
    one (samples, failed) pair per tick plus the exact TTFT stream the
    sketch gate compares against."""
    import math
    rng = random.Random(seed)
    sketches = [(LogSketch(), LogSketch()) for _ in range(n_replicas)]
    exact_ttfts: list[float] = []
    ticks = []
    for tick in range(n_ticks):
        load = 1.0 + 0.8 * math.sin(2 * math.pi * tick / n_ticks)
        failed = set()
        if n_ticks // 3 <= tick < n_ticks // 2:
            failed = {f"w{i}" for i in range(0, n_replicas, 17)}
        samples = []
        for i in range(n_replicas):
            tsk, dsk = sketches[i]
            # every replica serves a few prefills/decodes per tick; the
            # per-replica sketches accumulate across ticks like live ones
            for _ in range(4):
                ttft = rng.lognormvariate(-4.5, 0.6) * load
                tsk.insert(ttft)
                exact_ttfts.append(ttft)
                dsk.insert(rng.lognormvariate(-5.5, 0.9) * load)
            # one replica drains for a mid-run window (and is excluded
            # from those ticks' digests) but is healthy again by the final
            # tick, so the last digest folds every cumulative sketch and
            # the exact-stream comparison in gate (a) is apples-to-apples
            draining = (i == n_replicas - 1
                        and n_ticks * 2 // 3 <= tick < n_ticks * 5 // 6)
            samples.append(ReplicaSample(
                worker_id=f"w{i}", stage=0, alive=True,
                draining=draining,
                queue_depth=max(0, int(rng.gauss(3.0 * load, 1.5))),
                inflight=rng.randrange(4),
                processed=100 * tick + i,
                throughput=max(0.0, rng.gauss(8.0, 1.0)),
                latency_s=max(1e-4, rng.gauss(0.02, 0.004) * load),
                tokens_per_s=max(0.0, rng.gauss(300.0 * load, 40.0)),
                open_sessions=rng.randrange(6),
                expired=rng.randrange(2),
                role="both",
                ttft_s=tsk.mean(), decode_lat_s=dsk.mean(),
                ttft_sketch=tsk, decode_sketch=dsk))
        ticks.append((samples, failed))
    return ticks, exact_ttfts


def _snap_from_digest(d) -> StageSnapshot:
    """The digest -> policy-view conversion, shared verbatim by both fold
    modes so the parity gate isolates the *aggregation*, not the view."""
    return StageSnapshot(
        stage=d.stage, t=d.t, n_replicas=d.n_replicas,
        n_failed=d.n_failed, queue_total=d.queue_total,
        queue_per_replica=d.queue_per_replica,
        throughput=d.throughput, latency_s=d.latency_s,
        tokens_per_s=d.tokens_per_s, open_sessions=d.open_sessions,
        expired=d.expired, ttft_s=d.ttft_s,
        decode_latency_s=d.decode_latency_s,
        p95_ttft_s=d.p95_ttft_s, p99_ttft_s=d.p99_ttft_s,
        p95_decode_s=d.p95_decode_s, p99_decode_s=d.p99_decode_s,
        digest=d)


def _policies():
    """Stateless policy set (no hysteresis: its wall-clock cooldown would
    add a timing dependence the replay must not have)."""
    return [
        TargetQueueDepthPolicy(target=4.0, max_replicas=256),
        TTFTSLOPolicy(slo_s=TTFT_SLO_S, max_replicas=256),
        TokenRatePolicy(target_tokens_per_s=400.0, max_replicas=256),
        TailLatencySLOPolicy(ttft_slo_s=TTFT_SLO_S * 2,
                             decode_slo_s=DECODE_SLO_S, max_replicas=256),
    ]


def run_replay(p: dict) -> dict:
    ticks, exact_ttfts = _replay_samples(
        seed=11, n_replicas=p["replay_replicas"], n_ticks=p["replay_ticks"])
    raw_pols, dig_pols = _policies(), _policies()
    mismatches = 0
    decisions = 0
    fleet_sketch = LogSketch()
    for t, (samples, failed) in enumerate(ticks):
        flat = fold_samples(samples, failed, stage=0, t=float(t),
                            shard=None)
        sharded = fold_samples(samples, failed, stage=0, t=float(t),
                               shard=p["shard"])
        raw_records = [pol.decide(_snap_from_digest(flat)).as_record()
                       for pol in raw_pols]
        dig_records = [pol.decide(_snap_from_digest(sharded)).as_record()
                       for pol in dig_pols]
        decisions += len(raw_records)
        mismatches += sum(1 for a, b in zip(raw_records, dig_records)
                          if a != b)
        if t == len(ticks) - 1:
            fleet_sketch = sharded.ttft_sketch
    # gate (a): the fleet-level merged sketch vs the exact stream. The
    # last tick's digest folded every replica's cumulative sketch, so it
    # covers the full TTFT stream.
    exact_ttfts.sort()
    ra = fleet_sketch.relative_accuracy
    errs = {}
    for q in (0.95, 0.99):
        exact = percentile(exact_ttfts, q * 100)
        est = fleet_sketch.quantile(q)
        errs[q] = abs(est - exact) / exact
    # merge-order invariance: radically different shard widths, same result
    alt = fold_samples(ticks[-1][0], ticks[-1][1], stage=0,
                       t=float(len(ticks) - 1), shard=3)
    return {
        "n_samples": fleet_sketch.count,
        "rel_err_p95": errs[0.95],
        "rel_err_p99": errs[0.99],
        "guaranteed_ra": ra,
        "decisions": decisions,
        "mismatches": mismatches,
        "merge_invariant": (
            alt.ttft_sketch.quantile(0.99) == fleet_sketch.quantile(0.99)
            and alt.ttft_sketch.quantile(0.95)
            == fleet_sketch.quantile(0.95)
            and alt.ttft_sketch.count == fleet_sketch.count),
    }


# --------------------------------------------------------------------------
# gate (c): stub-executor fleet under open-loop diurnal traffic, A/B
# --------------------------------------------------------------------------
class _StubFleet:
    """A fleet of stub replicas serving stub sessions: every latency is a
    deterministic function of the session index (same in both A/B arms),
    so the tokens/s delta isolates the telemetry stack's own cost."""

    def __init__(self, p: dict, *, telemetry: bool, seed: int = 0) -> None:
        self.p = p
        self.telemetry = telemetry
        self.seed = seed
        self.tokens = 0
        self.sessions_done = 0
        self.inflight = 0
        self.peak_inflight = 0
        self.next_idx = 0
        if telemetry:
            self.ttft_sketch = LogSketch()
            self.decode_sketch = LogSketch()
            # slow_keep sits above the worst-case *healthy* session span
            # (chunks * chunk_s * 1.2 + ttft), so only the injected slow
            # outliers trip the tail-keep rule
            self.tracer = Tracer(
                16384, sample_rate=0.05,
                slow_keep_s=self.p["chunks"] * self.p["chunk_s"] * 1.5,
                seed=seed)
            self.slo = SLOMonitor(
                (SLOSpec("ttft_p99", "ttft", TTFT_SLO_S, 0.99),
                 SLOSpec("decode_p99", "decode", DECODE_SLO_S, 0.99)),
                bucket_s=0.5)
        else:
            self.ttft_sketch = self.decode_sketch = None
            self.tracer = Tracer(enabled=False)
            self.slo = None

    async def session(self) -> None:
        idx = self.next_idx
        self.next_idx += 1
        self.inflight += 1
        self.peak_inflight = max(self.peak_inflight, self.inflight)
        rng = random.Random((self.seed << 20) ^ idx)
        p = self.p
        try:
            root = self.tracer.begin()
            t0 = time.monotonic()
            ttft = rng.lognormvariate(-6.0, 0.5)
            await asyncio.sleep(ttft)
            if self.telemetry:
                now = time.monotonic()
                self.ttft_sketch.insert(ttft)
                self.slo.observe("ttft", ttft, now)
                self.tracer.span(root, "ttft", now - ttft)
            # inject a rare slow outlier (~0.1% of sessions, identically
            # in both A/B arms): the traces sampling must tail-keep
            slow = (idx % 997 == 0)
            for chunk in range(p["chunks"]):
                dt = p["chunk_s"] * rng.uniform(0.8, 1.2)
                if slow and chunk == 0:
                    dt *= 6.0
                await asyncio.sleep(dt)
                self.tokens += TOKENS_PER_CHUNK
                if self.telemetry:
                    now = time.monotonic()
                    self.decode_sketch.insert(dt)
                    self.slo.observe("decode", dt, now)
                    self.tracer.span(root, "decode_step", now - dt)
            self.tracer.record(root, "session", t0,
                               time.monotonic() - t0, "",
                               f"tokens={p['chunks'] * TOKENS_PER_CHUNK}")
            self.sessions_done += 1
        finally:
            self.inflight -= 1


async def _fleet_run(p: dict, *, telemetry: bool) -> dict:
    fleet = _StubFleet(p, telemetry=telemetry, seed=3)
    gen = OpenLoopGenerator(
        fleet.session,
        DiurnalProfile(mean=p["rate_mean"], amplitude=p["rate_amp"],
                       period_s=p["period_s"]),
        seed=5, max_inflight=p["max_inflight"])
    t0 = time.monotonic()
    summary = await gen.run(p["duration_s"])
    wall = time.monotonic() - t0
    out = {
        "telemetry": telemetry,
        "wall_s": wall,
        "tokens": fleet.tokens,
        "tokens_per_s": fleet.tokens / wall,
        "sessions": fleet.sessions_done,
        "peak_sessions": fleet.peak_inflight,
        "gen": summary,
    }
    if telemetry:
        out["spans_recorded"] = fleet.tracer.recorded
        out["traces_sampled_out"] = fleet.tracer.sampled_out
        out["traces_tail_kept"] = fleet.tracer.tail_kept
        out["sketch_p99_ttft_s"] = fleet.ttft_sketch.p99()
        out["slo_firing"] = fleet.slo.firing()
        out["span_summary"] = fleet.tracer.summary()
    return out


# --------------------------------------------------------------------------
# gate (d): burn-rate alerting on a virtual-time stream
# --------------------------------------------------------------------------
def _burn_scenario(*, regression: bool, seed: int = 7) -> dict:
    """120 virtual seconds of request traffic at ~50 req/s against a 1%
    error budget: steady traffic runs 0.2% bad (burn 0.2 — quiet);
    the regression arm turns 50% of requests bad for t in [40, 70)
    (burn 50 — both windows blow through the 14.4 page threshold), then
    recovers (the short window clears the alert)."""
    mon = SLOMonitor((SLOSpec("ttft_p99", "ttft", 0.2, objective=0.99),),
                     bucket_s=1.0)
    rng = random.Random(seed)
    events = []
    for tick in range(120):
        now = float(tick)
        bad_frac = 0.5 if (regression and 40 <= tick < 70) else 0.002
        for _ in range(50):
            v = 0.5 if rng.random() < bad_frac else 0.05
            mon.observe("ttft", v, now)
        events.extend(mon.evaluate(now))
    fired = [e for e in events if e["kind"] == "slo_alert"]
    cleared = [e for e in events if e["kind"] == "slo_clear"]
    return {"fired": len(fired), "cleared": len(cleared),
            "firing_after": mon.firing(), "events": events}


# --------------------------------------------------------------------------
def run(tiny: bool = False, json_path=None) -> list[tuple[str, float, str]]:
    p = TINY if tiny else FULL

    replay = run_replay(p)
    on = run_async(_fleet_run(p, telemetry=True))
    off = run_async(_fleet_run(p, telemetry=False))
    steady = _burn_scenario(regression=False)
    regress = _burn_scenario(regression=True)

    overhead = 1.0 - on["tokens_per_s"] / off["tokens_per_s"]

    rows = [
        ("fleet_sketch_rel_err_p95", replay["rel_err_p95"],
         f"vs exact over {replay['n_samples']} TTFTs; bound "
         f"{replay['guaranteed_ra']:g}"),
        ("fleet_sketch_rel_err_p99", replay["rel_err_p99"],
         "merged across replica sketches, shard-tree fold"),
        ("fleet_parity_decisions", float(replay["decisions"]),
         "policy votes compared raw-fold vs sharded-fold"),
        ("fleet_parity_mismatches", float(replay["mismatches"]),
         "must be 0 — hierarchy cannot change a decision"),
        ("fleet_tokens_per_s/telemetry_on", on["tokens_per_s"],
         "sketches + sampled tracing + SLO observation"),
        ("fleet_tokens_per_s/telemetry_off", off["tokens_per_s"],
         "same seeded workload, telemetry disabled"),
        ("fleet_telemetry_overhead_ratio", overhead,
         "<= 0.05 gate (full mode)"),
        ("fleet_peak_sessions", float(on["peak_sessions"]),
         f"concurrent stub sessions (floor {p['concurrency_floor']})"),
        ("fleet_sessions_total", float(on["sessions"]),
         "completed stub sessions, telemetry arm"),
        ("fleet_traces_sampled_out", float(on["traces_sampled_out"]),
         "boring unsampled traces dropped wholesale"),
        ("fleet_traces_tail_kept", float(on["traces_tail_kept"]),
         "unsampled traces promoted by tail keep rules"),
        ("fleet_spans_recorded", float(on["spans_recorded"]),
         "ring writes after sampling"),
        ("fleet_alerts_steady", float(steady["fired"]),
         "must be 0 — no false pages on healthy traffic"),
        ("fleet_alerts_regression", float(regress["fired"]),
         "must fire on the injected latency regression"),
        ("fleet_alert_clears_regression", float(regress["cleared"]),
         "short-window recovery clears the alert"),
    ]

    # ---- gate (a): sketch accuracy within the guaranteed bound ----------
    ra = replay["guaranteed_ra"]
    assert replay["rel_err_p95"] <= ra + 1e-9, replay
    assert replay["rel_err_p99"] <= ra + 1e-9, replay
    assert replay["merge_invariant"], "shard width changed a quantile"
    # ---- gate (b): digest-mode decisions identical to raw-mode ----------
    assert replay["mismatches"] == 0, \
        f"{replay['mismatches']}/{replay['decisions']} decisions diverged"
    # ---- gate (c): telemetry overhead <= 5% tokens/s (full runs only —
    # a tiny run is too short for the ratio to sit above scheduler noise,
    # where it is reported but not enforced) ------------------------------
    if on["gen"]["shed"] == 0 and off["gen"]["shed"] == 0:
        assert on["tokens"] == off["tokens"], \
            "A/B arms served different work — overhead ratio is meaningless"
    if not tiny:
        assert overhead <= 0.05, \
            f"telemetry overhead {overhead:.1%} > 5% tokens/s"
        assert on["traces_tail_kept"] >= 1, \
            "no injected slow outlier survived head sampling"
    assert on["peak_sessions"] >= p["concurrency_floor"], \
        (f"peak concurrency {on['peak_sessions']} under the "
         f"{p['concurrency_floor']} floor — the run never reached scale")
    # sampling must actually bound the ring: most healthy traces dropped
    assert on["traces_sampled_out"] > 0, on
    # ---- gate (d): regression pages, steady stays quiet ------------------
    assert steady["fired"] == 0, steady
    assert regress["fired"] >= 1, regress
    assert regress["cleared"] >= 1, regress
    assert not regress["firing_after"], "alert never cleared post-recovery"

    raw = {"replay": {k: v for k, v in replay.items()},
           "telemetry_on": {k: v for k, v in on.items()
                            if k != "span_summary"},
           "telemetry_off": off,
           "steady": {k: v for k, v in steady.items() if k != "events"},
           "regression": {k: v for k, v in regress.items()
                          if k != "events"},
           "regression_events": regress["events"],
           }
    if json_path:
        write_bench_json(json_path, suite="fleet", rows=rows, raw=raw,
                         tiny=tiny)
        write_trace_json(
            trace_path_for(json_path, "fleet"), suite="fleet",
            phases={"fleet": {
                "span_summary": on.get("span_summary", {}),
                "spans_recorded": on.get("spans_recorded"),
                "spans_dropped": 0,
            }})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: few sessions, short run")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write rows + raw results as JSON artifact")
    args = ap.parse_args()
    for name, value, derived in run(tiny=args.tiny, json_path=args.json):
        print(f"{name},{value:.4f},{derived}")

"""State-transfer benchmark: live handoff vs re-prefill, snapshot restore,
and warm bootstrap.

Phase A — planned drain with open mid-decode sessions, run twice on the
identical scenario: once with the PR 2 recovery path (``migrate=False``:
drain unpins, every displaced session re-prefills its full history on a
survivor) and once with live handoff (``migrate=True``: KV state streams to
a survivor, pins flip, decode resumes). The acceptance bar (ISSUE 3): the
handoff path does **zero re-prefill** and completes the drain scenario
strictly faster than the re-prefill path.

Phase B — unplanned kill with background snapshots: sessions rebuild from
the SnapshotStore and replay only the suffix since the latest snapshot;
asserted strictly less than the full history the PR 2 path recomputes.

Phase C — warm bootstrap: a fresh-process executor's first dispatch cost,
cold vs pre-warmed from a peer's shape profile (plus the weight-transfer
cost, which rides the same chunked bulk path as migrations).

  PYTHONPATH=src python -m benchmarks.bench_migrate [--tiny] [--json OUT]

``--tiny`` shrinks the scenario for CI smoke; ``--json`` writes the rows +
raw scenario dict as a machine-readable artifact (BENCH_migrate.json in CI).
"""
from __future__ import annotations

import argparse
import asyncio
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core import Cluster, FailureKind
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import PipelineServer

from .common import (collect_obs, run_async, trace_path_for,
                     write_bench_json, write_trace_json)

PROMPT_LEN = 16


def _build():
    cfg = get_smoke("llama3.2-1b").with_(num_layers=2,
                                         groups=(BlockGroup(DENSE, 2),))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n, seed, seq=PROMPT_LEN):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (1, seq)) for _ in range(n)]


async def _warm(cfg, server, sessions: int) -> None:
    """Compile everything both recovery paths can touch off-clock: decode
    convoy widths up to ``sessions`` (two rounds, like bench_generate) and
    the longer prefill bucket that full-history re-prefill lands in."""
    ps = _prompts(cfg, sessions, seed=9)
    for _ in range(2):
        await asyncio.gather(*(server.generate(p, 3, step_timeout=120.0)
                               for p in ps))
    await server.generate(_prompts(cfg, 1, seed=8, seq=24)[0], 2,
                          step_timeout=120.0)


async def _wait_open(server, stage: int, n: int, timeout=20.0) -> None:
    """Every session's prefill has landed — the drain/kill below then hits
    genuinely mid-decode sessions, deterministically."""
    deadline = time.monotonic() + timeout
    while sum(r.open_sessions() for r in server.replicas[stage]) < n:
        if time.monotonic() > deadline:
            break
        await asyncio.sleep(0.005)


async def _drain_scenario(migrate: bool, tiny: bool) -> dict:
    """Open N mid-decode sessions, drain the loaded stage-1 replica, time
    the drain + every session's completion."""
    cfg, model, params = _build()
    cluster = Cluster()
    server = PipelineServer(cluster, model, params, [1, 2], max_len=64)
    await server.start()
    sessions = 4 if tiny else 8
    new_tokens = 8 if tiny else 16
    await _warm(cfg, server, sessions)
    ps = _prompts(cfg, sessions, seed=1)
    tasks = [asyncio.ensure_future(server.generate(p, new_tokens,
                                                   step_timeout=30.0))
             for p in ps]
    await _wait_open(server, 1, sessions)
    victims = [r for r in server.replicas[1]
               if r.worker.alive and not r.draining]
    victim = max(victims, key=lambda r: r.open_sessions())
    open_at_drain = victim.open_sessions()
    t0 = time.monotonic()
    await server.remove_replica(1, victim.worker_id, drain=True,
                                timeout=60.0, migrate=migrate)
    drain_s = time.monotonic() - t0
    await asyncio.gather(*tasks)
    complete_s = time.monotonic() - t0
    m = server.migrations.stats()
    stats = server.replica_stats()
    out = {
        "migrate": migrate,
        "sessions": sessions,
        "open_at_drain": open_at_drain,
        "drain_s": drain_s,
        "complete_s": complete_s,       # drain + all sessions finished
        "migrations": m["migrations_total"],
        "migration_p50_s": m["migration_p50_s"],
        "migration_bytes": m["migration_bytes_total"],
        "reprefills": m["reprefills_total"],
        "recovered_tokens": m["recovered_tokens"],
        "recomputed_tokens": m["recomputed_tokens"],
        "retries": sum(s["retries_sent"] for s in stats.values()),
        "obs": collect_obs(server),
    }
    cluster.shutdown()
    return out


async def _kill_restore_scenario(tiny: bool) -> dict:
    """Kill a loaded replica with background snapshots on; sessions restore
    and replay only the post-snapshot suffix."""
    cfg, model, params = _build()
    cluster = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
    server = PipelineServer(cluster, model, params, [1, 2], max_len=64,
                            snapshot_interval_s=0.05)
    await server.start()
    sessions = 3 if tiny else 6
    new_tokens = 8 if tiny else 16
    await _warm(cfg, server, sessions)
    ps = _prompts(cfg, sessions, seed=2)
    # a silently-hung replica is only detectable for an *in-flight* step via
    # the client timeout (PR 2 semantics), so step_timeout bounds recovery
    # latency; everything is pre-warmed, so 3s >> any real service time
    tasks = [asyncio.ensure_future(server.generate(p, new_tokens,
                                                   step_timeout=3.0))
             for p in ps]
    await _wait_open(server, 1, sessions)
    # ensure every open session has a snapshot before the "unplanned" kill
    # (the background task snapshots too; this pins down the worst case)
    await server.snapshots.sweep()
    victims = [r for r in server.replicas[1] if r.worker.alive]
    victim = max(victims, key=lambda r: r.open_sessions())
    t0 = time.monotonic()
    cluster.kill(victim.worker_id, FailureKind.SILENT_HANG)
    await asyncio.gather(*tasks)
    recover_s = time.monotonic() - t0
    m = server.migrations.stats()
    out = {
        "sessions": sessions,
        "full_history_tokens": sessions * (PROMPT_LEN + new_tokens),
        "recover_s": recover_s,
        "restores": m["restores_total"],
        "restore_failures": m["restore_failures"],
        "reprefills": m["reprefills_total"],
        "recovered_tokens": m["recovered_tokens"],
        "recomputed_tokens": m["recomputed_tokens"],
        "snapshots_taken": server.snapshots.snapshots_taken,
        "snapshot_bytes_total": server.snapshots.snapshot_bytes_total,
        "obs": collect_obs(server),
    }
    cluster.shutdown()
    return out


async def _bootstrap_scenario(tiny: bool) -> dict:
    """First-dispatch cost of a fresh-process stage executor, cold vs
    warm-bootstrapped from a peer, plus the weight-transfer bill."""
    from repro.serving.executor import StageExecutor

    import jax.numpy as jnp

    cfg, model, params = _build()
    cluster = Cluster()
    server = PipelineServer(cluster, model, params, [1, 1], max_len=64)
    await server.start()
    p = _prompts(cfg, 1, seed=3, seq=8)[0]
    await server.generate(p, 4, step_timeout=120.0)   # peer serves traffic
    peer = server.replicas[1][0]
    # the new replica's first real dispatch has the shapes its peer serves
    shape, dtype = peer.executor.warm_profile()["prefill"][0]

    def first_dispatch_s(ex) -> float:
        t0 = time.monotonic()
        x = jnp.zeros(shape, jnp.dtype(dtype))
        out, cache = ex.prefill(x)
        step = jnp.zeros((shape[0], 1) + tuple(shape[2:]), jnp.dtype(dtype))
        y, _ = ex.decode(cache, step, min(shape[1], ex.max_len - 1))
        jax.block_until_ready(y)
        return time.monotonic() - t0

    # cold: a brand-new executor (fresh jit cache), no warmup
    cold = StageExecutor(server.cfg, server.stage_specs[1],
                         server.stage_param_sets[1], max_len=server.max_len)
    cold_s = first_dispatch_s(cold)

    # warm: the real pipeline path — add_replica(warm=True) fetches weights
    # from the peer and replays its shape profile into the fresh executor
    t0 = time.monotonic()
    wid = await server.add_replica(1, warm=True, fresh_executor=True)
    add_s = time.monotonic() - t0
    rep = next(r for r in server.replicas[1] if r.worker_id == wid)
    warm_s = first_dispatch_s(rep.executor)

    out = {
        "cold_first_dispatch_s": cold_s,
        "warm_first_dispatch_s": warm_s,
        "warm_add_replica_s": add_s,
        "weight_bytes": (server.bootstrap.weight_bytes or [0])[-1],
        "weight_transfer_s": (server.bootstrap.transfer_s or [0.0])[-1],
        "profile_warm_s": (server.bootstrap.warm_s or [0.0])[-1],
        "warmed_dispatches": rep.executor.stats["warmed_dispatches"],
        "obs": collect_obs(server),
    }
    cluster.shutdown()
    return out


async def _scenario(tiny: bool) -> dict:
    return {
        "drain_reprefill": await _drain_scenario(migrate=False, tiny=tiny),
        "drain_migrate": await _drain_scenario(migrate=True, tiny=tiny),
        "kill_restore": await _kill_restore_scenario(tiny),
        "bootstrap": await _bootstrap_scenario(tiny),
    }


def run(tiny: bool = False, json_path: str | None = None
        ) -> list[tuple[str, float, str]]:
    r = run_async(_scenario(tiny))
    dm, dr = r["drain_migrate"], r["drain_reprefill"]
    k, b = r["kill_restore"], r["bootstrap"]
    rows = [
        ("migrate_drain_complete_s/live_handoff", dm["complete_s"],
         f"{dm['open_at_drain']} open sessions moved, "
         f"{dm['migrations']} migrations"),
        ("migrate_drain_complete_s/reprefill", dr["complete_s"],
         f"{dr['open_at_drain']} open sessions bounced, "
         f"{dr['reprefills']} re-prefills"),
        ("migrate_drain_speedup", dr["complete_s"] / max(dm["complete_s"],
                                                         1e-9),
         "re-prefill wall / live-handoff wall (same scenario)"),
        ("migrate_handoff_p50_ms", dm["migration_p50_s"] * 1e3,
         "per-session pause->stream->install->resume"),
        ("migrate_handoff_bytes", float(dm["migration_bytes"]),
         "KV snapshot bytes over the wire"),
        ("migrate_reprefills/live_handoff", float(dm["reprefills"]),
         "must be 0 — zero re-prefill drain"),
        ("restore_replayed_tokens", float(k["recomputed_tokens"]),
         f"vs {k['full_history_tokens']} full-history tokens "
         f"(PR 2 path recomputes all)"),
        ("restore_recovered_tokens", float(k["recovered_tokens"]),
         f"{k['restores']} sessions restored from snapshots"),
        ("restore_recover_s", k["recover_s"],
         "kill -> every session finished"),
        ("snapshot_bytes_total", float(k["snapshot_bytes_total"]),
         f"{k['snapshots_taken']} background snapshots"),
        ("bootstrap_first_dispatch_s/cold", b["cold_first_dispatch_s"],
         "fresh executor, no warmup"),
        ("bootstrap_first_dispatch_s/warm", b["warm_first_dispatch_s"],
         f"after peer warm ({b['warmed_dispatches']} warm dispatches)"),
        ("bootstrap_weight_bytes", float(b["weight_bytes"]),
         f"stage weights streamed in {b['weight_transfer_s']:.3f}s"),
    ]
    # acceptance gates (ISSUE 3)
    assert dm["reprefills"] == 0 and dm["retries"] == 0, \
        f"live-handoff drain was not re-prefill-free: {dm}"
    assert dm["migrations"] >= dm["open_at_drain"] >= 1, dm
    if not tiny:
        assert dm["open_at_drain"] >= 4, dm
        assert dm["complete_s"] < dr["complete_s"], \
            (f"live handoff ({dm['complete_s']:.3f}s) not faster than "
             f"re-prefill ({dr['complete_s']:.3f}s)")
        assert b["warm_first_dispatch_s"] < b["cold_first_dispatch_s"], b
    assert k["restores"] >= 1, k
    assert k["recomputed_tokens"] < k["full_history_tokens"], k
    if json_path:
        # obs snapshots ride the trace artifact, not the bench metrics doc
        phases = {k: v.pop("obs", {}) for k, v in r.items()}
        write_bench_json(json_path, suite="migrate", rows=rows, raw=r,
                         tiny=tiny)
        write_trace_json(trace_path_for(json_path, "migrate"),
                         suite="migrate", phases=phases)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small scenario, no wall-clock gates")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write rows + raw results as JSON artifact")
    args = ap.parse_args()
    for name, value, derived in run(tiny=args.tiny, json_path=args.json):
        print(f"{name},{value:.4f},{derived}")

"""Speculative decoding on elastic role pools: the ISSUE 10 headline A/B.

At *equal replica budget*, does a draft pool beat spending the same
replica on plain target decode? Plain mode runs ``{both: 2}``; spec mode
trades one of those replicas for a draft replica (``{both: 1, draft: 1}``)
proposing ``k`` tokens per round, verified by the target in one fused
dispatch. The uplift lever is per-session decode latency: each accepted
round commits ``k+1`` tokens for one target dispatch instead of ``k+1``.

The target model is built with an *identity tail*: every layer past the
first has its attention/MLP output projections zeroed, so those layers are
exact residual no-ops and the 4-layer target computes bit-for-bit the same
function as its own first layer. The draft (that first layer, shared
embeddings) therefore agrees with the target exactly — acceptance 1.0 at a
quarter of the target's per-token cost — which makes the A/B a controlled
measurement of the *serving mechanism* (propose/verify round structure,
fused verification, commit bookkeeping) with the model-quality variable
pinned, and makes greedy parity a hard bitwise gate in both modes.

Second scenario (recovery-matrix row): kill the only draft replica mid-
generation. Every session must finish with exact parity through the
plain-decode fallback — zero client-visible failures, zero target-pool
tokens recomputed (draft loss never invalidates target KV state).

Gates (full mode; structural gates enforced in --tiny too):
* exact greedy parity vs the single-engine oracle, both modes;
* acceptance == 1.0 and zero fallbacks in the healthy A/B;
* spec tokens/s > plain tokens/s at equal replica budget (full only);
* draft-kill: all sessions complete, fallbacks > 0, zero re-prefills and
  zero recomputed target tokens.
"""
from __future__ import annotations

import argparse
import asyncio
import time

import jax
import numpy as np

from benchmarks.common import (
    collect_obs,
    run_async,
    trace_path_for,
    write_bench_json,
    write_trace_json,
)
from repro.configs import get_smoke
from repro.core import Cluster, FailureKind
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import PipelineServer, ROLE_DRAFT, ServeEngine

MAX_LEN = 64


def _build(tiny: bool):
    """Identity-tail target + its first-layer draft (shared embeddings)."""
    layers = 2 if tiny else 4
    cfg = get_smoke("llama3.2-1b").with_(num_layers=layers,
                                         groups=(BlockGroup(DENSE, layers),))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # residual no-op tail: zero the output projections of layers 1..N-1 on
    # the scan-stacked group params — the N-layer function becomes layer 0's
    g = dict(params["groups"][0])
    g["attn"] = dict(g["attn"], wo=g["attn"]["wo"].at[1:].set(0.0))
    g["mlp"] = dict(g["mlp"], w_down=g["mlp"]["w_down"].at[1:].set(0.0))
    params = dict(params, groups=[g])
    draft_cfg = cfg.with_(num_layers=1, groups=(BlockGroup(DENSE, 1),))
    draft_model = build_model(draft_cfg)
    draft_params = {k: v for k, v in params.items() if k != "groups"}
    draft_params["groups"] = [jax.tree.map(lambda a: a[:1],
                                           params["groups"][0])]
    return cfg, model, params, draft_model, draft_params


def _prompts(cfg, n, seq=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (1, seq)) for _ in range(n)]


async def _wait_open(server, stage, n, timeout=60.0):
    deadline = time.monotonic() + timeout
    while sum(r.open_sessions() for r in server.replicas[stage]) < n:
        assert time.monotonic() < deadline, "sessions never all opened"
        await asyncio.sleep(0.005)


async def _ab_mode(build, *, spec: bool, sessions: int, new_tokens: int,
                   k: int, wants) -> dict:
    """One side of the equal-budget A/B: measure tokens/s over a fully
    warmed round of ``sessions`` concurrent generations."""
    cfg, model, params, draft_model, draft_params = build
    c = Cluster()
    if spec:
        pools = {"both": 1, "draft": 1}
        server = PipelineServer(c, model, params, [pools], max_len=MAX_LEN,
                                draft_model=draft_model,
                                draft_params=draft_params, spec_k=k)
    else:
        pools = {"both": 2}
        server = PipelineServer(c, model, params, [pools], max_len=MAX_LEN)
    await server.start()
    prompts = _prompts(cfg, sessions)

    async def one_round():
        return await asyncio.gather(*(
            server.generate(p, new_tokens, step_timeout=300.0)
            for p in prompts))

    # deterministic warm: two identical-traffic rounds compile every
    # (coalescing width, K) bucket — including the shrinking tail k_round
    # shapes — the measured round will hit; jit compiles mid-measurement
    # would otherwise dominate the timing
    for _ in range(2):
        outs = await one_round()
    prop0 = server.spec_proposed_total
    acc0 = server.spec_accepted_total
    fb0 = server.spec_fallbacks_total
    t0 = time.monotonic()
    outs = await one_round()
    dt = time.monotonic() - t0
    parity = all(np.array_equal(got, want)
                 for got, want in zip(outs, wants))
    proposed = server.spec_proposed_total - prop0
    r = {
        "pools": pools,
        "tokens_per_s": sessions * new_tokens / dt,
        "round_s": dt,
        "parity": parity,
        "fallbacks": server.spec_fallbacks_total - fb0,
        "acceptance": ((server.spec_accepted_total - acc0) / proposed
                       if proposed else 0.0),
        "replica_stats": server.replica_stats(),
        "obs": collect_obs(server),
    }
    c.shutdown()
    return r


async def _draft_kill(build, *, sessions: int, new_tokens: int,
                      k: int, wants) -> dict:
    """Recovery-matrix row: the only draft replica dies mid-generation;
    sessions degrade to plain decode with zero client-visible failures and
    zero target-pool recomputation."""
    cfg, model, params, draft_model, draft_params = build
    c = Cluster()
    server = PipelineServer(c, model, params, [{"both": 1, "draft": 1}],
                            max_len=MAX_LEN, draft_model=draft_model,
                            draft_params=draft_params, spec_k=k)
    await server.start()
    prompts = _prompts(cfg, sessions)
    # warm round so the kill lands mid-measurement, not mid-compile
    await asyncio.gather(*(server.generate(p, new_tokens,
                                           step_timeout=300.0)
                           for p in prompts))
    rounds0 = server.spec_rounds_total
    tasks = [asyncio.ensure_future(
        server.generate(p, new_tokens, step_timeout=60.0))
        for p in prompts]
    await _wait_open(server, 0, sessions)
    # let at least one speculative round commit, then kill while most of
    # the generation is still ahead — the remaining rounds must all hit
    # the degrade path (killing later risks the sessions simply finishing
    # speculatively and the scenario proving nothing)
    deadline = time.monotonic() + 60.0
    while server.spec_rounds_total - rounds0 < 1:
        assert time.monotonic() < deadline, "no spec rounds before kill"
        await asyncio.sleep(0.002)
    draft = next(r for r in server.replicas[0] if r.role == ROLE_DRAFT)
    c.kill(draft.worker_id, FailureKind.CRASH_DETECTABLE)
    failures = 0
    outs = []
    for t in tasks:
        try:
            outs.append(await t)
        except Exception:  # noqa: BLE001 — the gate counts these
            failures += 1
            outs.append(None)
    parity = all(o is not None and np.array_equal(o, want)
                 for o, want in zip(outs, wants))
    m = server.migrations.stats()
    r = {
        "failures": failures,
        "parity": parity,
        "fallbacks": server.spec_fallbacks_total,
        "reprefills": m["reprefills_total"],
        "recomputed_tokens": m["recomputed_tokens"],
        "obs": collect_obs(server),
    }
    c.shutdown()
    return r


def run(tiny: bool = False, json_path: str | None = None):
    sessions = 2
    new_tokens = 8 if tiny else 48
    # the kill scenario needs enough generation left *after* the kill that
    # the degrade path is actually exercised — give it its own budget
    kill_tokens = 24 if tiny else 48
    k = 3 if tiny else 4
    build = _build(tiny)
    cfg, model, params = build[:3]
    engine = ServeEngine(model, params, max_len=MAX_LEN)
    wants = [engine.generate(p, new_tokens)
             for p in _prompts(cfg, sessions)]
    wants_kill = [engine.generate(p, kill_tokens)
                  for p in _prompts(cfg, sessions)]

    plain = run_async(_ab_mode(build, spec=False, sessions=sessions,
                               new_tokens=new_tokens, k=k, wants=wants))
    spec = run_async(_ab_mode(build, spec=True, sessions=sessions,
                              new_tokens=new_tokens, k=k, wants=wants))
    kill = run_async(_draft_kill(build, sessions=sessions,
                                 new_tokens=kill_tokens, k=k,
                                 wants=wants_kill))

    speedup = spec["tokens_per_s"] / max(plain["tokens_per_s"], 1e-9)
    # hard gates — structural ones hold in tiny mode too
    assert plain["parity"], "plain-mode greedy parity broke"
    assert spec["parity"], "spec-mode greedy parity broke"
    assert spec["fallbacks"] == 0, spec["fallbacks"]
    assert spec["acceptance"] >= 0.999, spec["acceptance"]
    assert kill["failures"] == 0, kill["failures"]
    assert kill["parity"], "post-kill parity broke"
    assert kill["fallbacks"] >= 1, "kill produced no degrade fallbacks"
    assert kill["reprefills"] == 0, kill["reprefills"]
    assert kill["recomputed_tokens"] == 0, kill["recomputed_tokens"]
    if not tiny:
        # the headline: draft replica beats the same replica spent on
        # plain decode (tiny CI boxes are too noisy for a throughput gate)
        assert speedup > 1.0, (spec["tokens_per_s"], plain["tokens_per_s"])

    rows = [
        ("spec_tokens_per_s", spec["tokens_per_s"],
         f"{{both:1, draft:1}}, k={k}, {sessions}x{new_tokens} tokens"),
        ("plain_tokens_per_s", plain["tokens_per_s"],
         "{both:2}, same sessions/tokens — equal replica budget"),
        ("spec_speedup", speedup,
         "spec vs plain tokens/s at equal replica budget"),
        ("spec_acceptance_rate", spec["acceptance"],
         "accepted/proposed over the measured round (identity tail: 1.0)"),
        ("spec_fallbacks", float(spec["fallbacks"]),
         "healthy A/B: degrade rounds (must be 0)"),
        ("spec_parity_ok", float(plain["parity"] and spec["parity"]),
         "bitwise greedy parity vs single engine, both modes"),
        ("draftkill_failures", float(kill["failures"]),
         "client-visible failures after mid-generation draft kill"),
        ("draftkill_fallbacks", float(kill["fallbacks"]),
         "rounds degraded to plain decode after the kill"),
        ("draftkill_recomputed_tokens", float(kill["recomputed_tokens"]),
         "target-pool tokens recomputed because of draft loss (must be 0)"),
        ("draftkill_parity_ok", float(kill["parity"]),
         "bitwise greedy parity through the degrade"),
    ]
    r = {"plain": plain, "spec": spec, "draft_kill": kill}
    if json_path:
        phases = {name: scen.pop("obs", {}) for name, scen in r.items()}
        write_bench_json(json_path, suite="spec", rows=rows, raw=r,
                         tiny=tiny)
        write_trace_json(trace_path_for(json_path, "spec"),
                         suite="spec", phases=phases)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: fewer layers/tokens, no throughput gate")
    ap.add_argument("--json", default=None,
                    help="write BENCH_spec.json (+ TRACE_spec.json) here")
    args = ap.parse_args()
    for name, value, derived in run(tiny=args.tiny, json_path=args.json):
        print(f"{name},{value:.4f},{derived}")

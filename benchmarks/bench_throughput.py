"""Paper Figs. 6-7 reproduction: MultiWorld overhead vs single world.

Fig. 6: one sender -> one receiver across tensor sizes, three transports:
SW (bare channel, the vanilla-PyTorch analogue), MW (full MultiWorld stack:
store, watchdog heartbeats, world-status checks on every op), MP (serialize
+ staging copy, the MultiProcessing strawman of §4.3).

Both SW and MW move payloads through the same wire model (one memcpy per
hop, the cost structure of a DMA transfer) in lockstep send->recv pairs, so
the measured delta is exactly MultiWorld's per-op bookkeeping amortized
against a real transfer cost — the paper's measurement, minus the GPUs.

Fig. 7: 1/2/3 senders -> one receiver (the paper's 4-GPU VM), MW vs SW.
The paper's claim: 1.4-4.3% loss in most scenarios, 14.6% worst case at
small tensors.
"""
from __future__ import annotations

import asyncio
import time

from repro.core import Cluster, CopyCodec, IPCCodec

from .common import SingleWorldChannel, TENSOR_SIZES, make_tensor, run_async

N_TENSORS = 400
WARMUP = 20


async def _sw_throughput(n_floats: int, n_senders: int = 1) -> float:
    x = make_tensor(n_floats)
    chans = [SingleWorldChannel(CopyCodec()) for _ in range(n_senders)]

    async def pairs(n):
        for _ in range(n):
            for ch in chans:
                await ch.send(x)
            for ch in chans:
                await ch.recv()

    await pairs(WARMUP)
    t0 = time.monotonic()
    await pairs(N_TENSORS)
    dt = time.monotonic() - t0
    return n_senders * N_TENSORS * x.nbytes / dt / 1e9


async def _mw_throughput(n_floats: int, n_senders: int = 1,
                         codec="copy") -> float:
    c = Cluster(codec=CopyCodec() if codec == "copy" else codec)
    leader = c.worker("L")
    x = make_tensor(n_floats)
    names = [f"w{i}" for i in range(n_senders)]
    inits = []
    for i, name in enumerate(names):
        inits.append(leader.manager.initialize_world(name, 0, 2))
        inits.append(c.worker(f"S{i}").manager.initialize_world(name, 1, 2))
    await asyncio.gather(*inits)
    senders = [c.worker(f"S{i}").comm for i in range(n_senders)]

    async def pairs(n):
        for _ in range(n):
            for i, comm in enumerate(senders):
                await comm.send(x, 0, names[i])
            for name in names:
                await leader.comm.recv(1, name)

    await pairs(WARMUP)
    t0 = time.monotonic()
    await pairs(N_TENSORS)
    dt = time.monotonic() - t0
    c.shutdown()
    return n_senders * N_TENSORS * x.nbytes / dt / 1e9


def _best(fn, *a, reps=3, **kw):
    return max(run_async(fn(*a, **kw)) for _ in range(reps))


def run() -> list[tuple[str, float, str]]:
    rows = []
    # Fig. 6: 1 -> 1, three transports
    for size_name, n in TENSOR_SIZES.items():
        sw = _best(_sw_throughput, n)
        mw = _best(_mw_throughput, n)
        mp = _best(_mw_throughput, n, codec=IPCCodec())
        overhead = (sw - mw) / sw * 100.0
        rows.append((f"fig6_sw/{size_name}", sw, "GB/s"))
        rows.append((f"fig6_mw/{size_name}", mw,
                     f"GB/s ({overhead:+.1f}% vs SW)"))
        rows.append((f"fig6_mp/{size_name}", mp, "GB/s (IPC strawman)"))

    # Fig. 7: N senders -> 1 receiver, MW vs SW overhead
    for n_senders in (1, 2, 3):
        for size_name in ("4KB", "4MB"):
            n = TENSOR_SIZES[size_name]
            sw = _best(_sw_throughput, n, n_senders)
            mw = _best(_mw_throughput, n, n_senders)
            overhead = (sw - mw) / sw * 100.0
            rows.append((f"fig7_overhead_pct/{n_senders}tx/{size_name}",
                         overhead, f"MW {mw:.2f} vs SW {sw:.2f} GB/s"))
    return rows

"""Placement + heal benchmark: topology-aware state movement A/B'd against
the placement-blind and recompute disciplines it replaces.

Phase A — **drain migration on a two-host topology**, run twice on the
identical scenario: placement-aware survivor choice (queue load + placement
cost of the KV bytes about to move) vs the placement-blind queue-depth-only
baseline. The blind baseline's tie-break lands on a cross-host survivor;
the aware run must keep every migrated byte on-host. Acceptance (ISSUE 4):
the aware run picks a same-host survivor and moves **strictly fewer
cross-host bytes** than the blind run.

Phase B — **heal of an alive-but-fenced replica** with open mid-decode
sessions, run twice: snapshot-assisted live heal (state live-migrates to
the replacement; bounced clients restore from it inside the grace window)
vs the PR 3 heal (drain-migrate fails on pin-less fenced sessions, every
client re-prefills its full history). Acceptance: the live heal recomputes
**zero tokens** while preserving greedy token parity; the PR 3 heal
recomputes at least every affected session's full prompt.

  PYTHONPATH=src python -m benchmarks.bench_place [--tiny] [--json OUT]

``--tiny`` shrinks the scenario for CI smoke; ``--json`` writes the rows +
raw scenario dict as a machine-readable artifact (BENCH_place.json in CI).
"""
from __future__ import annotations

import argparse
import asyncio
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.control import ElasticController, MetricsHub
from repro.core import Cluster, PlacementCost, Topology
from repro.models import DENSE, BlockGroup, build_model
from repro.obs import validate_dump
from repro.serving import PipelineServer, ServeEngine

from .common import (collect_obs, run_async, trace_path_for,
                     write_bench_json, write_trace_json)

PROMPT_LEN = 8


def _build():
    cfg = get_smoke("llama3.2-1b").with_(num_layers=2,
                                         groups=(BlockGroup(DENSE, 2),))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n, seed, seq=PROMPT_LEN):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (1, seq)) for _ in range(n)]


async def _warm(cfg, server, sessions: int) -> None:
    ps = _prompts(cfg, sessions, seed=9)
    for _ in range(2):
        await asyncio.gather(*(server.generate(p, 3, step_timeout=120.0)
                               for p in ps))
    # wait for the warm-up FINISHes to land: a lingering warm-up session
    # satisfies _wait_open spuriously and the fence then hits orphans
    # instead of the scenario's mid-decode sessions
    deadline = time.monotonic() + 5.0
    while any(r.sessions for reps in server.replicas for r in reps):
        if time.monotonic() > deadline:
            break
        await asyncio.sleep(0.005)


async def _wait_open(server, stage: int, n: int, timeout=20.0) -> None:
    deadline = time.monotonic() + timeout
    while sum(r.open_sessions() for r in server.replicas[stage]) < n:
        if time.monotonic() > deadline:
            break
        await asyncio.sleep(0.005)


async def _drain_placement_scenario(aware: bool, tiny: bool) -> dict:
    """Drain a loaded replica on a two-host topology with one same-host and
    one cross-host survivor; count where the migrated KV bytes went."""
    cfg, model, params = _build()
    topo = Topology(hosts=("h0", "h1"))
    # steep byte pricing: cross-host bandwidth is the scarce resource this
    # suite measures, so the topology term must dominate queue wiggle
    cluster = Cluster(topology=topo,
                      placement_cost=PlacementCost(topo,
                                                   bytes_per_load=8 * 1024))
    server = PipelineServer(cluster, model, params, [1, 3], max_len=64)
    server.migrations.placement_aware = aware
    await server.start()
    sessions = 6 if tiny else 9
    new_tokens = 8 if tiny else 12
    await _warm(cfg, server, sessions)
    ps = _prompts(cfg, sessions, seed=1)
    tasks = [asyncio.ensure_future(server.generate(p, new_tokens,
                                                   step_timeout=30.0))
             for p in ps]
    await _wait_open(server, 1, sessions)
    reps = sorted((r for r in server.replicas[1]
                   if r.worker.alive and not r.draining),
                  key=lambda r: -r.open_sessions())
    victim, survivors = reps[0], reps[1:]
    # identical host map in both runs: the *first-listed* survivor (the
    # blind tie-break winner) sits across the wire, the other shares the
    # victim's host — so blind pays cross-host bytes and aware must not
    in_order = [r for r in server.replicas[1]
                if r is not victim and r in survivors]
    topo.assign(victim.worker_id, "h0")
    topo.assign(in_order[0].worker_id, "h1")     # blind's tie-break pick
    topo.assign(in_order[1].worker_id, "h0")     # the same-host survivor
    same_host_id = in_order[1].worker_id
    open_at_drain = victim.open_sessions()
    cross0 = cluster.transport.bulk_cross_host_bytes_sent
    weighted0 = cluster.transport.bulk_cost_weighted_bytes
    t0 = time.monotonic()
    await server.remove_replica(1, victim.worker_id, drain=True,
                                timeout=60.0)
    drain_s = time.monotonic() - t0
    await asyncio.gather(*tasks)
    m = server.migrations.stats()
    moved = [d for _, k, d in server.events if k == "migrate"]
    out = {
        "aware": aware,
        "sessions": sessions,
        "open_at_drain": open_at_drain,
        "migrations": m["migrations_total"],
        "reprefills": m["reprefills_total"],
        "migration_bytes": m["migration_bytes_total"],
        "cross_host_bulk_bytes": (cluster.transport.bulk_cross_host_bytes_sent
                                  - cross0),
        "cost_weighted_bulk_bytes": (
            cluster.transport.bulk_cost_weighted_bytes - weighted0),
        "same_host_migrations": sum(1 for d in moved if same_host_id in d),
        "drain_s": drain_s,
        "obs": collect_obs(server),
    }
    cluster.shutdown()
    return out


async def _heal_scenario(live_heal: bool, tiny: bool) -> dict:
    """Fence a loaded stage-1 replica (worker alive, every upstream edge
    broken) under open mid-decode sessions and let the controller heal it;
    measure what recovery recomputed and check greedy token parity."""
    cfg, model, params = _build()
    engine = ServeEngine(model, params, max_len=64)
    cluster = Cluster()
    server = PipelineServer(cluster, model, params, [1, 2], max_len=64)
    await server.start()
    sessions = 4 if tiny else 6
    # enough decode runway that the fence always lands mid-generation:
    # a session that slips through finished would dodge the bounce and
    # understate both recovery disciplines
    new_tokens = 12 if tiny else 16
    await _warm(cfg, server, sessions)
    ctrl = ElasticController(server, interval=0.02, scale_stages=[],
                             live_heal=live_heal)
    ctrl.start()
    ps = _prompts(cfg, sessions, seed=2)
    wants = [engine.generate(p, new_tokens) for p in ps]
    tasks = [asyncio.ensure_future(server.generate(p, new_tokens,
                                                   step_timeout=30.0))
             for p in ps]
    await _wait_open(server, 1, sessions)
    victim = max((r for r in server.replicas[1]
                  if r.worker.alive and not r.draining),
                 key=lambda r: r.open_sessions())
    open_at_fence = victim.open_sessions()
    t0 = time.monotonic()
    for world, router in list(victim.upstream_edges):
        router.mark_broken(world)
        server.broken_worlds.add(world)
    outs = await asyncio.gather(*tasks)
    recover_s = time.monotonic() - t0
    await ctrl.stop()
    parity = all(np.array_equal(w, g) for w, g in zip(wants, outs))
    m = server.migrations.stats()
    hub = MetricsHub(server)
    # acceptance (ISSUE 6): every heal emits a schema-valid flight dump
    heal_dumps = [d for d in server.recorder.dump_log
                  if d["reason"] == "heal"]
    assert len(heal_dumps) >= ctrl.heals >= 1, \
        f"{ctrl.heals} heals but {len(heal_dumps)} heal dumps"
    assert all(validate_dump(d) for d in heal_dumps), \
        "heal flight dump failed schema validation"
    out = {
        "live_heal": live_heal,
        "sessions": sessions,
        "open_at_fence": open_at_fence,
        "prompt_len": PROMPT_LEN,
        "heals": ctrl.heals,
        "heal_migrations": m["heal_migrations_total"],
        "migration_failures": m["migration_failures"],
        "restores": m["restores_total"],
        "restore_failures": m["restore_failures"],
        "reprefills": m["reprefills_total"],
        "timeline": [(e.kind, e.detail) for e in ctrl.timeline],
        "recovered_tokens": m["recovered_tokens"],
        "recomputed_tokens": m["recomputed_tokens"],
        "recover_s": recover_s,
        "token_parity": parity,
        "placement": hub.placement_metrics(),
        "heal_dumps_validated": len(heal_dumps),
        "obs": collect_obs(server),
    }
    cluster.shutdown()
    return out


async def _scenario(tiny: bool) -> dict:
    return {
        "drain_aware": await _drain_placement_scenario(True, tiny),
        "drain_blind": await _drain_placement_scenario(False, tiny),
        "heal_live": await _heal_scenario(True, tiny),
        "heal_reprefill": await _heal_scenario(False, tiny),
    }


def run(tiny: bool = False, json_path: str | None = None
        ) -> list[tuple[str, float, str]]:
    r = run_async(_scenario(tiny))
    da, db = r["drain_aware"], r["drain_blind"]
    hl, hr = r["heal_live"], r["heal_reprefill"]
    rows = [
        ("place_drain_cross_host_bytes/aware",
         float(da["cross_host_bulk_bytes"]),
         f"{da['migrations']} migrations, "
         f"{da['same_host_migrations']} stayed on-host"),
        ("place_drain_cross_host_bytes/blind",
         float(db["cross_host_bulk_bytes"]),
         f"{db['migrations']} migrations, "
         f"{db['same_host_migrations']} stayed on-host"),
        ("place_drain_cost_weighted_bytes/aware",
         da["cost_weighted_bulk_bytes"], "bytes x per-edge placement cost"),
        ("place_drain_cost_weighted_bytes/blind",
         db["cost_weighted_bulk_bytes"], "bytes x per-edge placement cost"),
        ("heal_recomputed_tokens/live",
         float(hl["recomputed_tokens"]),
         f"{hl['heal_migrations']} live handoffs, "
         f"{hl['restores']} restores, {hl['reprefills']} re-prefills"),
        ("heal_recomputed_tokens/reprefill",
         float(hr["recomputed_tokens"]),
         f"PR 3 heal: {hr['reprefills']} full-history re-prefills"),
        ("heal_recover_s/live", hl["recover_s"],
         f"fence -> {hl['sessions']} sessions finished"),
        ("heal_recover_s/reprefill", hr["recover_s"],
         f"fence -> {hr['sessions']} sessions finished"),
    ]
    # acceptance gates (ISSUE 4)
    assert da["migrations"] >= da["open_at_drain"] >= 1, da
    assert da["same_host_migrations"] == da["migrations"], \
        f"placement-aware drain left the victim's host: {da}"
    assert db["cross_host_bulk_bytes"] > 0, \
        f"blind baseline never crossed hosts — A/B is vacuous: {db}"
    assert da["cross_host_bulk_bytes"] < db["cross_host_bulk_bytes"], \
        (f"aware drain moved {da['cross_host_bulk_bytes']}B cross-host, "
         f"blind moved {db['cross_host_bulk_bytes']}B")
    assert da["reprefills"] == 0 and db["reprefills"] == 0, (da, db)
    assert hl["token_parity"] and hr["token_parity"], \
        "greedy token parity lost through heal"
    assert hl["open_at_fence"] >= 1 and hr["open_at_fence"] >= 1, (hl, hr)
    assert hl["recomputed_tokens"] == 0 and hl["reprefills"] == 0, \
        f"live heal recomputed tokens: {hl}"
    assert hl["heal_migrations"] >= hl["open_at_fence"], hl
    assert hl["restores"] >= hl["open_at_fence"], hl
    # the PR 3 discipline pays at least every affected session's prompt
    assert hr["recomputed_tokens"] >= \
        hr["open_at_fence"] * hr["prompt_len"], hr
    if json_path:
        # obs snapshots ride the trace artifact, not the bench metrics doc
        phases = {k: v.pop("obs", {}) for k, v in r.items()}
        write_bench_json(json_path, suite="place", rows=rows, raw=r,
                         tiny=tiny)
        write_trace_json(trace_path_for(json_path, "place"),
                         suite="place", phases=phases)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small scenario")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write rows + raw results as JSON artifact")
    args = ap.parse_args()
    for name, value, derived in run(tiny=args.tiny, json_path=args.json):
        print(f"{name},{value:.4f},{derived}")

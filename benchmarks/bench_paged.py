"""Paged KV-cache benchmark: the PagePool + paged decode path A/B'd
against the contiguous per-session cache baseline.

Three phases, mirroring the acceptance gates (ISSUE 7):

* **capacity** — sessions resident at *equal cache memory*. All sessions
  share one long system prompt; the paged pool maps the shared prefix to
  one physical copy (radix-trie page reuse), so a byte budget that holds K
  contiguous sessions must hold >= 1.5x K paged sessions (the gate). The
  run also exercises the exhaustion edge: the first session past capacity
  degrades to a contiguous cache (flight ``page_alloc_failure``), never
  crashes.
* **bytes** — state-transfer cost on a split prefill/decode stage, paged
  vs contiguous: the prefill->decode handoff and the background snapshot
  ship only a session's *used pages* instead of the whole ``max_len``
  buffer. Gates: paged handoff bytes and per-snapshot bytes strictly below
  contiguous, with greedy token parity across the handoff in both modes.
* **parity** — unplanned kill with background snapshots on, paged mode:
  sessions restore from page-granular snapshots (pages install directly
  into the survivor's pool) and finish with exact greedy tokens.

  PYTHONPATH=src python -m benchmarks.bench_paged [--tiny] [--json OUT]

``--tiny`` shrinks sequence lengths and session counts for CI smoke; every
gate above is structural (memory accounting, byte counts, token equality),
so they hold in tiny mode too.
"""
from __future__ import annotations

import argparse
import asyncio
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core import Cluster, FailureKind
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import PipelineServer, ServeEngine, StageExecutor
from repro.serving.kvpool import PagedCacheHandle
from repro.serving.partition import split_stages, stage_params
from repro.statexfer import cache_nbytes

from .common import (collect_obs, run_async, trace_path_for,
                     write_bench_json, write_trace_json)


def _build():
    cfg = get_smoke("llama3.2-1b").with_(num_layers=2,
                                         groups=(BlockGroup(DENSE, 2),))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _shared_prefix_prompts(cfg, n, *, system, tail, seed):
    """n prompts sharing one ``system``-token system prompt followed by a
    ``tail``-token unique suffix each."""
    rng = np.random.default_rng(seed)
    sys_ids = rng.integers(0, cfg.vocab_size, (1, system))
    return [np.concatenate(
        [sys_ids, rng.integers(0, cfg.vocab_size, (1, tail))], axis=1)
        for _ in range(n)]


# ------------------------------------------------------------------ capacity

def _capacity_scenario(tiny: bool) -> dict:
    """Executor-level residency under one byte budget. The contiguous
    baseline's capacity is the budget by construction (every session owns a
    full ``max_len`` cache); the paged pool is sized to exactly that many
    bytes and admits sessions until the free list runs dry."""
    cfg, model, params = _build()
    max_len = 64 if tiny else 512
    page = 8 if tiny else 16
    system = 32 if tiny else 256
    tail = page                      # one unique full page per session
    budget_sessions = 2 if tiny else 4
    pages_per_seq = max_len // page

    spec = split_stages(cfg, 1)[0]
    sp = stage_params(cfg, params, spec)
    ex = StageExecutor(cfg, spec, sp, max_len=max_len, paged=True,
                       page_size=page,
                       pool_pages=budget_sessions * pages_per_seq + 1)
    events: list = []
    ex.on_event = lambda kind, **f: events.append((kind, f))
    ex_contig = StageExecutor(cfg, spec, sp, max_len=max_len)

    prompts = _shared_prefix_prompts(
        cfg, 4 * budget_sessions * pages_per_seq, system=system, tail=tail,
        seed=1)
    _, contig_cache = ex_contig.prefill(jax.numpy.asarray(prompts[0]))
    contig_bytes = cache_nbytes(contig_cache)

    resident = []
    degraded = False
    for x in prompts:
        out, cache = ex.prefill(jax.numpy.asarray(x))
        if not isinstance(cache, PagedCacheHandle):
            degraded = True          # pool exhausted at prefill: contiguous
            break
        t = x.shape[1]
        for _ in range(2):           # a couple of live decode steps each
            last = np.asarray(out)
            last = last[:, -1] if last.ndim == 3 else last  # prefill (B,S,V)
            tok = last.argmax(-1).astype(np.int32).reshape(1, 1)
            out, cache = ex.decode(cache, jax.numpy.asarray(tok), t)
            t += 1
        if not isinstance(cache, PagedCacheHandle):
            degraded = True          # exhausted mid-decode: degraded, alive
            break
        resident.append(cache)
    # equal-memory accounting: the pool's usable pages hold exactly the
    # bytes of ``budget_sessions`` contiguous caches (page_nbytes is known
    # once the first install binds the leaf shapes)
    pool = ex._ensure_pool()
    pool_bytes = (pool.num_pages - 1) * pool.page_nbytes
    assert pool_bytes == budget_sessions * contig_bytes, \
        (pool_bytes, budget_sessions, contig_bytes)
    stats = pool.stats()
    out = {
        "max_len": max_len, "page_size": page,
        "system_prompt_tokens": system,
        "budget_sessions_contiguous": budget_sessions,
        "cache_bytes_contiguous": contig_bytes,
        "pool_bytes": pool_bytes,
        "resident_sessions_paged": len(resident),
        "capacity_ratio": len(resident) / budget_sessions,
        "prefix_pages_reused": stats["prefix_pages_reused"],
        "page_alloc_failures": stats["page_alloc_failures"],
        "alloc_failure_events": sum(1 for k, _ in events
                                    if k == "page_alloc_failure"),
        "hit_capacity_gracefully": degraded,
        "paged_degrades": ex.stats["paged_degrades"],
    }
    for h in resident:
        ex.release_cache(h)
    assert pool.stats()["kv_pages_used"] == 0, pool.stats()
    return out


# --------------------------------------------------------------------- bytes

async def _bytes_scenario(paged: bool, tiny: bool) -> dict:
    """Split prefill/decode stage: every session's KV crosses the wire once
    (handoff) and is snapshotted while open. Counts the bytes each path
    moves and checks greedy parity against the single engine."""
    cfg, model, params = _build()
    engine = ServeEngine(model, params, max_len=64)
    cluster = Cluster()
    server = PipelineServer(cluster, model, params,
                            [{"prefill": 1, "decode": 1}], max_len=64,
                            paged=paged, page_size=8,
                            snapshot_interval_s=3600.0)   # manual sweeps
    await server.start()
    sessions = 2 if tiny else 4
    new_tokens = 6 if tiny else 12
    ps = _shared_prefix_prompts(cfg, sessions, system=8, tail=8, seed=2)
    wants = [engine.generate(p, new_tokens) for p in ps]
    tasks = [asyncio.ensure_future(
        server.generate(p, new_tokens, step_timeout=120.0)) for p in ps]
    deadline = time.monotonic() + 60.0
    while sum(r.open_sessions() for r in server.replicas[0]) < sessions:
        assert time.monotonic() < deadline, "sessions never opened"
        await asyncio.sleep(0.005)
    swept = await server.snapshots.sweep()
    outs = await asyncio.gather(*tasks)
    parity = all(np.array_equal(w, g) for w, g in zip(wants, outs))
    m = server.migrations.stats()
    out = {
        "paged": paged,
        "sessions": sessions,
        "token_parity": parity,
        "handoffs": m["handoffs_total"],
        "handoff_failures": m["handoff_failures"],
        "handoff_bytes": m["handoff_bytes_total"],
        "handoff_bytes_per_session": m["handoff_bytes_total"]
        / max(m["handoffs_total"], 1),
        "snapshots_taken": swept,
        "snapshot_bytes_per_snapshot": server.snapshots.snapshot_bytes_total
        / max(swept, 1),
        "obs": collect_obs(server),
    }
    cluster.shutdown()
    return out


# -------------------------------------------------------------- kill/restore

async def _restore_scenario(tiny: bool) -> dict:
    """Unplanned kill in paged mode: page-granular snapshots restore into
    the survivor's pool and sessions finish token-exact."""
    cfg, model, params = _build()
    engine = ServeEngine(model, params, max_len=64)
    cluster = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
    server = PipelineServer(cluster, model, params, [1, 2], max_len=64,
                            paged=True, page_size=8,
                            snapshot_interval_s=0.05)
    await server.start()
    sessions = 3 if tiny else 6
    new_tokens = 8 if tiny else 16
    ps = _shared_prefix_prompts(cfg, sessions, system=8, tail=8, seed=3)
    # warm both compile paths off-clock (two rounds of real traffic)
    for _ in range(2):
        await asyncio.gather(*(server.generate(p, 3, step_timeout=120.0)
                               for p in ps))
    wants = [engine.generate(p, new_tokens) for p in ps]
    tasks = [asyncio.ensure_future(server.generate(p, new_tokens,
                                                   step_timeout=3.0))
             for p in ps]
    deadline = time.monotonic() + 20.0
    while sum(r.open_sessions() for r in server.replicas[1]) < sessions:
        if time.monotonic() > deadline:
            break
        await asyncio.sleep(0.005)
    await server.snapshots.sweep()
    victim = max((r for r in server.replicas[1] if r.worker.alive),
                 key=lambda r: r.open_sessions())
    t0 = time.monotonic()
    cluster.kill(victim.worker_id, FailureKind.SILENT_HANG)
    outs = await asyncio.gather(*tasks)
    recover_s = time.monotonic() - t0
    m = server.migrations.stats()
    out = {
        "sessions": sessions,
        "token_parity": all(np.array_equal(w, g)
                            for w, g in zip(wants, outs)),
        "recover_s": recover_s,
        "restores": m["restores_total"],
        "reprefills": m["reprefills_total"],
        "recovered_tokens": m["recovered_tokens"],
        "obs": collect_obs(server),
    }
    cluster.shutdown()
    return out


async def _scenario(tiny: bool) -> dict:
    return {
        "capacity": _capacity_scenario(tiny),
        "bytes_contiguous": await _bytes_scenario(paged=False, tiny=tiny),
        "bytes_paged": await _bytes_scenario(paged=True, tiny=tiny),
        "restore": await _restore_scenario(tiny),
    }


def run(tiny: bool = False, json_path: str | None = None
        ) -> list[tuple[str, float, str]]:
    r = run_async(_scenario(tiny))
    cap, co, pg, rs = (r["capacity"], r["bytes_contiguous"],
                       r["bytes_paged"], r["restore"])
    rows = [
        ("paged_capacity_ratio", cap["capacity_ratio"],
         f"{cap['resident_sessions_paged']} paged sessions in a "
         f"{cap['budget_sessions_contiguous']}-contiguous-session budget "
         f"({cap['system_prompt_tokens']}-token shared system prompt)"),
        ("paged_prefix_pages_reused", float(cap["prefix_pages_reused"]),
         "physical pages deduplicated by the prefix trie"),
        ("paged_handoff_bytes/paged", pg["handoff_bytes_per_session"],
         "prefill->decode KV handoff, per session"),
        ("paged_handoff_bytes/contiguous", co["handoff_bytes_per_session"],
         "prefill->decode KV handoff, per session"),
        ("paged_snapshot_bytes/paged", pg["snapshot_bytes_per_snapshot"],
         "background snapshot of an open session"),
        ("paged_snapshot_bytes/contiguous", co["snapshot_bytes_per_snapshot"],
         "background snapshot of an open session"),
        ("paged_restore_recovered_tokens", float(rs["recovered_tokens"]),
         f"{rs['restores']} sessions restored from page-granular snapshots"),
        ("paged_restore_recover_s", rs["recover_s"],
         "kill -> every paged session finished"),
    ]
    # acceptance gates (ISSUE 7)
    assert cap["capacity_ratio"] >= 1.5, \
        (f"paged capacity {cap['capacity_ratio']:.2f}x < 1.5x at equal "
         f"cache memory: {cap}")
    assert cap["hit_capacity_gracefully"] and cap["paged_degrades"] >= 0, cap
    assert cap["page_alloc_failures"] >= 1, \
        f"capacity run never exercised the exhaustion edge: {cap}"
    assert cap["alloc_failure_events"] >= 1, \
        f"pool exhaustion raised no flight event: {cap}"
    assert cap["prefix_pages_reused"] > 0, cap
    assert pg["token_parity"] and co["token_parity"], (pg, co)
    assert pg["handoff_failures"] == 0 and co["handoff_failures"] == 0
    assert pg["handoffs"] >= pg["sessions"], pg
    assert pg["handoff_bytes_per_session"] \
        < co["handoff_bytes_per_session"], \
        (f"paged handoff moved {pg['handoff_bytes_per_session']:.0f}B/session"
         f", contiguous {co['handoff_bytes_per_session']:.0f}B — page "
         f"granularity must be strictly smaller")
    assert pg["snapshot_bytes_per_snapshot"] \
        < co["snapshot_bytes_per_snapshot"], (pg, co)
    assert rs["token_parity"], \
        "greedy parity lost across kill + page-granular snapshot restore"
    assert rs["restores"] >= 1, rs
    if json_path:
        phases = {k: v.pop("obs", {}) for k, v in r.items()
                  if isinstance(v, dict) and "obs" in v}
        write_bench_json(json_path, suite="paged", rows=rows, raw=r,
                         tiny=tiny)
        write_trace_json(trace_path_for(json_path, "paged"),
                         suite="paged", phases=phases)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: short sequences, few sessions")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write rows + raw results as JSON artifact")
    args = ap.parse_args()
    for name, value, derived in run(tiny=args.tiny, json_path=args.json):
        print(f"{name},{value:.4f},{derived}")

"""Benchmark driver — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig4,fig6]

Prints ``name,value,derived`` CSV rows:
* fig1  — message-bus strawman (serialize/IPC codecs vs zero-copy)
* fig4  — fault-tolerance timeline (MultiWorld vs single world)
* fig5  — online instantiation under live traffic
* fig6/7 — MultiWorld throughput overhead vs single world, 1->1 and N->1
* pipeline — end-to-end elastic pipeline latency (Fig. 2 scenario)
* elastic — closed-loop autoscale/heal/drain scenario (control plane)
* generate — generative data plane: continuous batching + kill/drain
  recovery of in-flight sessions
* migrate — state transfer: live KV-session handoff vs re-prefill on
  drain, snapshot restore after a kill, warm scale-up bootstrap
* place — topology-aware placement: same-host vs cross-host survivor
  choice on drain, and snapshot-assisted live heal vs the re-prefill heal
* disagg — disaggregated prefill/decode pools vs colocated replicas under
  a mixed prefill-heavy workload (decode tokens/s + tail latency A/B)
* paged — paged KV pool vs contiguous caches: session capacity at equal
  cache memory (shared-prefix reuse), page-granular handoff/snapshot
  bytes, greedy parity incl. kill + page-granular restore
* multimodel — multi-model multi-tenant pool: shared vs dedicated
  consolidation A/B, in-rotation residency swap under traffic, and
  per-tenant SLO tails under a skewed two-tenant mix
* spec — speculative decoding: draft pool vs plain decode at equal
  replica budget (tokens/s + exact greedy parity), and mid-generation
  draft-pool kill degrading to plain decode with zero recomputation
"""
from __future__ import annotations

import argparse
import sys


def _rows_pipeline():
    """End-to-end serving latency through the rhombus pipeline, including
    under failure + after online replacement."""
    import asyncio
    import time

    import jax
    import numpy as np

    from repro.configs import get_smoke
    from repro.core import Cluster, FailureKind
    from repro.models import DENSE, BlockGroup, build_model
    from repro.serving import PipelineServer

    cfg = get_smoke("llama3.2-1b").with_(num_layers=4,
                                         groups=(BlockGroup(DENSE, 4),))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    async def scenario():
        c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.1)
        server = PipelineServer(c, model, params, [1, 2, 1])
        await server.start()
        toks = np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 16))

        async def sample(n):
            lat = []
            for _ in range(n):
                t0 = time.monotonic()
                await server.submit(toks, timeout=20.0)
                lat.append(time.monotonic() - t0)
            return sum(lat) / n * 1e3

        warm = await sample(3)          # includes compiles
        healthy = await sample(10)
        c.kill(server.replicas[1][0].worker_id, FailureKind.SILENT_HANG)
        await asyncio.sleep(0.3)
        degraded = await sample(10)
        await server.add_replica(1)
        healed = await sample(10)
        c.shutdown()
        return warm, healthy, degraded, healed

    warm, healthy, degraded, healed = asyncio.run(scenario())
    return [
        ("pipeline_latency_ms/warmup", warm, "includes stage compiles"),
        ("pipeline_latency_ms/healthy_2replica", healthy, "rhombus"),
        ("pipeline_latency_ms/degraded_1replica", degraded,
         "after replica death"),
        ("pipeline_latency_ms/healed_online", healed,
         "after online instantiation"),
    ]


def _rows_roofline():
    """§Roofline terms from dry-run artifacts (skipped if absent)."""
    from benchmarks.roofline import run as roofline_run

    rows = roofline_run()
    return rows or [("roofline", float("nan"),
                     "no artifacts/dryrun — run repro.launch.dryrun first")]


SUITES = {
    "fig1": lambda: __import__("benchmarks.bench_serialize",
                               fromlist=["run"]).run(),
    "fig4": lambda: __import__("benchmarks.bench_fault",
                               fromlist=["run"]).run(),
    "fig5": lambda: __import__("benchmarks.bench_online",
                               fromlist=["run"]).run(),
    "fig6": lambda: __import__("benchmarks.bench_throughput",
                               fromlist=["run"]).run(),
    "pipeline": _rows_pipeline,
    "elastic": lambda: __import__("benchmarks.bench_elastic",
                                  fromlist=["run"]).run(),
    "generate": lambda: __import__("benchmarks.bench_generate",
                                   fromlist=["run"]).run(),
    "migrate": lambda: __import__("benchmarks.bench_migrate",
                                  fromlist=["run"]).run(),
    "place": lambda: __import__("benchmarks.bench_place",
                                fromlist=["run"]).run(),
    "disagg": lambda: __import__("benchmarks.bench_disagg",
                                 fromlist=["run"]).run(),
    "paged": lambda: __import__("benchmarks.bench_paged",
                                fromlist=["run"]).run(),
    "multimodel": lambda: __import__("benchmarks.bench_multimodel",
                                     fromlist=["run"]).run(),
    "spec": lambda: __import__("benchmarks.bench_spec",
                               fromlist=["run"]).run(),
    "roofline": _rows_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names " + str(list(SUITES)))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)

    print("name,value,derived")
    failures = 0
    for name in names:
        try:
            for row_name, value, derived in SUITES[name]():
                print(f"{row_name},{value:.4f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
            print(f"{name}_FAILED,nan,{type(e).__name__}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

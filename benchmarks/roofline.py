"""Roofline report: renders EXPERIMENTS.md §Roofline from dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun] [--md]

Per (arch × shape) on the single-pod mesh: the three roofline terms in
seconds (compute / HBM / ICI), the dominant term, MODEL_FLOPS/HLO_FLOPS, and
the per-device memory high-water mark vs the 16 GiB v5e budget.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(directory: str, mesh_tag: str = "pod1") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(directory, f"*__{mesh_tag}.json"))):
        r = json.load(open(f))
        if r.get("status") == "ok":
            rows.append(r)
    return rows


def bottleneck_note(r: dict) -> str:
    dom = r["dominant"]
    by = r.get("collectives_by_op", {})
    if dom == "collective" and by:
        worst = max(by, key=by.get)
        return f"cut {worst} traffic"
    if dom == "memory":
        return "raise arithmetic intensity / shrink working set"
    return "near MXU roofline; overlap collectives"


def render(rows: list[dict], md: bool = False) -> str:
    out = []
    if md:
        out.append("| arch | shape | compute_s | memory_s | collective_s | "
                   "dominant | useful_flops | peak GiB/dev | fits 16G |")
        out.append("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        gib = r["peak_state_bytes_per_dev"] / 2 ** 30
        fits = "yes" if gib <= 16 else "NO"
        if md:
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
                f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
                f"{gib:.1f} | {fits} |")
        else:
            out.append(
                f"roofline/{r['arch']}/{r['shape']},"
                f"{max(r['compute_s'], r['memory_s'], r['collective_s']):.4f},"
                f"dom={r['dominant']} c={r['compute_s']:.3f} "
                f"m={r['memory_s']:.3f} x={r['collective_s']:.3f} "
                f"useful={r['useful_flops_ratio']:.2f} mem={gib:.1f}GiB")
    return "\n".join(out)


def run() -> list[tuple[str, float, str]]:
    """Benchmark-suite adapter: step-time bound per combo (single-pod),
    preferring the optimized-config artifacts."""
    rows = load("artifacts/dryrun_opt") or load("artifacts/dryrun")
    out = []
    for r in rows:
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        out.append((f"roofline_bound_s/{r['arch']}/{r['shape']}", bound,
                    f"dominant={r['dominant']} "
                    f"useful={r['useful_flops_ratio']:.2f}"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir, args.mesh)
    print(render(rows, md=args.md))


if __name__ == "__main__":
    main()

"""Generative serving benchmark: continuous batching + the elastic scenario.

Phase A — continuous batching lever: N concurrent sessions generate through
the same 2-stage pipeline twice, once with one-dispatch-per-request decode
(``microbatch_max=1``) and once with the continuous-batching micro-scheduler
(``microbatch_max=8``). The acceptance bar (ISSUE 2) is >= 2x tokens/s at
8+ concurrent sessions.

Phase B — the full elastic generative scenario: a ramp of generation
sessions arrives; the pipeline scales up under load; one replica is killed
mid-generation (the controller auto-heals it and every affected session
re-prefills its history on a survivor); finally a replica is drained away
while sessions are still open. Reports tokens/s and per-token latency
percentiles; zero client-visible failures is asserted — redispatch, RETRY
bounce, session re-prefill, and drain-unpinning together must hide every
transition from the clients.

  PYTHONPATH=src python -m benchmarks.bench_generate [--tiny]

``--tiny`` shrinks the scenario for CI smoke (fewer sessions/tokens, no
2x assertion — CI machines are too noisy to gate on a throughput ratio).
"""
from __future__ import annotations

import argparse
import asyncio
import sys
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.control import ElasticController
from repro.core import Cluster, FailureKind
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import PipelineServer

from .common import (collect_obs, run_async, trace_path_for,
                     write_bench_json, write_trace_json)

PROMPT_LEN = 8

#: tracing must stay in the noise: tracer-on tokens/s within this fraction
#: of tracer-off in the full run (tiny CI boxes are too noisy to gate hard)
TRACING_OVERHEAD_BUDGET = 0.05


def _build():
    cfg = get_smoke("llama3.2-1b").with_(num_layers=2,
                                         groups=(BlockGroup(DENSE, 2),))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (1, PROMPT_LEN))
            for _ in range(n)]


async def _phase_batching(tiny: bool) -> dict:
    """tokens/s: one-dispatch-per-request vs continuous microbatching."""
    cfg, model, params = _build()
    sessions = 4 if tiny else 8
    new_tokens = 4 if tiny else 8
    out = {"sessions": sessions, "new_tokens": new_tokens}
    for label, mb in (("single_dispatch", 1), ("continuous", 8)):
        cluster = Cluster()
        server = PipelineServer(cluster, model, params, [1, 1],
                                max_len=64, microbatch_max=mb)
        await server.start()
        prompts = _prompts(cfg, sessions, seed=1)

        async def round_() -> float:
            t0 = time.monotonic()
            await asyncio.gather(*(server.generate(p, new_tokens,
                                                   step_timeout=120.0)
                                   for p in prompts))
            return time.monotonic() - t0

        await round_()          # absorb prefill/decode compiles
        await round_()          # ...including every convoy-width variant
        dt = min(await round_(), await round_())
        out[label] = sessions * new_tokens / dt
        stats = server.replica_stats()
        out[f"{label}_batches"] = sum(s["decode_batches"]
                                      for s in stats.values())
        out[f"{label}_steps"] = sum(s["decode_steps"]
                                    for s in stats.values())
        cluster.shutdown()
    out["speedup"] = out["continuous"] / max(out["single_dispatch"], 1e-9)
    return out


async def _phase_tracing_overhead(tiny: bool) -> dict:
    """Tracer on vs off on the identical continuous-batching scenario:
    default-on tracing is only tenable if the span path stays in the
    measurement noise (the ``TRACING_OVERHEAD_BUDGET`` smoke gate)."""
    cfg, model, params = _build()
    sessions = 4 if tiny else 8
    new_tokens = 4 if tiny else 8
    out = {"sessions": sessions, "new_tokens": new_tokens}
    for label, tracing in (("tracer_off", False), ("tracer_on", True)):
        cluster = Cluster()
        server = PipelineServer(cluster, model, params, [1, 1],
                                max_len=64, microbatch_max=8,
                                tracing=tracing)
        await server.start()
        prompts = _prompts(cfg, sessions, seed=1)

        async def round_() -> float:
            t0 = time.monotonic()
            await asyncio.gather(*(server.generate(p, new_tokens,
                                                   step_timeout=120.0)
                                   for p in prompts))
            return time.monotonic() - t0

        await round_()          # absorb compiles
        await round_()
        dt = min(await round_(), await round_())
        out[label] = sessions * new_tokens / dt
        if tracing:
            out["spans_recorded"] = server.tracer.recorded
            out["obs"] = collect_obs(server)
        cluster.shutdown()
    out["overhead_frac"] = 1.0 - (out["tracer_on"]
                                  / max(out["tracer_off"], 1e-9))
    return out


async def _phase_elastic(tiny: bool) -> dict:
    """ramp -> scale-up -> kill mid-generation -> heal/re-prefill ->
    drain-based scale-down with open sessions."""
    cfg, model, params = _build()
    cluster = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.1)
    server = PipelineServer(cluster, model, params, [1, 1],
                            least_loaded=True, max_len=64)
    await server.start()
    # controller in heal-only mode: the scenario beats are scripted so the
    # bench is deterministic; the kill recovery is the controller's job
    ctrl = ElasticController(server, interval=0.05, scale_stages=[])
    ctrl.start()

    new_tokens = 4 if tiny else 8
    waves = 2 if tiny else 4
    per_wave = 3 if tiny else 4
    ok = failed = 0
    step_lat: list[float] = []

    async def one(p) -> None:
        nonlocal ok, failed
        times: list[float] = []
        try:
            await server.generate(p, new_tokens, step_timeout=10.0,
                                  token_times=times)
            ok += 1
            step_lat.extend(b - a for a, b in zip(times, times[1:]))
        except Exception as e:  # noqa: BLE001 — a failure is data, not a crash
            failed += 1
            print(f"# session failed: {type(e).__name__}: {e}",
                  file=sys.stderr)

    # warm the compiles off-clock
    await server.generate(_prompts(cfg, 1, seed=9)[0], 2, step_timeout=120.0)

    t_start = time.monotonic()
    tasks: list[asyncio.Task] = []
    killed = None
    for wave in range(waves):
        for p in _prompts(cfg, per_wave, seed=10 + wave):
            tasks.append(asyncio.ensure_future(one(p)))
        if wave == 0:
            # ramp crosses one replica's capacity: scale the decode stage up
            await server.add_replica(1)
        if wave == 1:
            # kill a replica that holds live sessions, mid-generation
            await asyncio.sleep(0.02)
            victims = [r for r in server.replicas[1]
                       if r.worker.alive and not r.draining]
            victim = max(victims, key=lambda r: r.open_sessions())
            killed = victim.worker_id
            cluster.kill(killed, FailureKind.SILENT_HANG)
        await asyncio.sleep(0.1)
    await asyncio.gather(*tasks)
    gen_wall = time.monotonic() - t_start

    # scale-down while sessions are open: drain must relocate, not lose them
    tail = [asyncio.ensure_future(one(p))
            for p in _prompts(cfg, per_wave, seed=99)]
    await asyncio.sleep(0.05)
    drained = None
    if len(server.healthy_replicas(1)) > 1:
        drained = await server.remove_replica(1, drain=True, timeout=20.0)
    await asyncio.gather(*tail)

    await ctrl.stop()
    stats = server.replica_stats()
    total_sessions = waves * per_wave + per_wave
    lat = sorted(step_lat)

    def pct(p):
        return lat[min(int(p / 100 * len(lat)), len(lat) - 1)] if lat \
            else float("nan")

    result = {
        "ok": ok, "failed": failed, "sessions": total_sessions,
        "tokens_per_s": waves * per_wave * new_tokens / gen_wall,
        "p50_token_s": pct(50), "p95_token_s": pct(95),
        "heals": ctrl.heals, "killed": killed, "drained": drained,
        "retries": sum(s["retries_sent"] for s in stats.values()),
        "obs": collect_obs(server),
    }
    cluster.shutdown()
    return result


async def _scenario(tiny: bool) -> dict:
    return {"batching": await _phase_batching(tiny),
            "elastic": await _phase_elastic(tiny),
            "tracing": await _phase_tracing_overhead(tiny)}


def run(tiny: bool = False, json_path: str | None = None
        ) -> list[tuple[str, float, str]]:
    r = run_async(_scenario(tiny))
    b, e = r["batching"], r["elastic"]
    tr = r["tracing"]
    rows = [
        ("generate_tokens_per_s/single_dispatch", b["single_dispatch"],
         f"{b['sessions']} sessions, microbatch off"),
        ("generate_tokens_per_s/continuous", b["continuous"],
         f"{b['sessions']} sessions, fused decode dispatches"),
        ("generate_batching_speedup", b["speedup"],
         "continuous vs one-dispatch-per-request"),
        ("generate_fused_batches", float(b["continuous_batches"]),
         f"dispatches for {b['continuous_steps']} decode steps"),
        ("elastic_generate_ok", float(e["ok"]),
         "sessions completed (ramp+kill+drain scenario)"),
        ("elastic_generate_failed", float(e["failed"]),
         "must be 0 — transitions hidden from clients"),
        ("elastic_generate_tokens_per_s", e["tokens_per_s"],
         "across ramp + kill + heal"),
        ("elastic_generate_p50_token_ms", e["p50_token_s"] * 1e3,
         "per-token latency"),
        ("elastic_generate_p95_token_ms", e["p95_token_s"] * 1e3,
         "includes kill/re-prefill window"),
        ("elastic_generate_heals", float(e["heals"]),
         f"killed={e['killed']} auto-replaced"),
        ("elastic_generate_retries", float(e["retries"]),
         "RETRY bounces (sessions relocated)"),
        ("generate_tokens_per_s/tracer_off", tr["tracer_off"],
         "tracing disabled, continuous batching"),
        ("generate_tokens_per_s/tracer_on", tr["tracer_on"],
         f"default-on tracing ({tr['spans_recorded']} spans recorded)"),
        ("generate_tracing_overhead_ratio", tr["overhead_frac"],
         f"budget {TRACING_OVERHEAD_BUDGET:.0%} (gated in full mode)"),
    ]
    assert e["failed"] == 0, f"client-visible failures: {e}"
    assert e["ok"] == e["sessions"], e
    assert e["heals"] >= 1, "controller never healed the killed replica"
    assert tr["spans_recorded"] > 0, \
        "tracer-on run recorded no spans — the A/B is vacuous"
    if not tiny:
        assert b["speedup"] >= 2.0, \
            f"continuous batching speedup {b['speedup']:.2f} < 2x"
        # the tracing-overhead smoke gate (ISSUE 6): default-on spans must
        # cost at most the budgeted fraction of decode throughput
        assert tr["overhead_frac"] <= TRACING_OVERHEAD_BUDGET, \
            (f"tracing overhead {tr['overhead_frac']:.1%} > "
             f"{TRACING_OVERHEAD_BUDGET:.0%} budget "
             f"(on {tr['tracer_on']:.1f} vs off {tr['tracer_off']:.1f} "
             f"tokens/s)")
    if json_path:
        # obs snapshots ride the trace artifact, not the bench metrics doc
        phases = {k: v.pop("obs", {}) for k, v in r.items()}
        write_bench_json(json_path, suite="generate", rows=rows, raw=r,
                         tiny=tiny)
        write_trace_json(trace_path_for(json_path, "generate"),
                         suite="generate", phases=phases)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small scenario, no throughput gate")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write rows + raw results as JSON artifact")
    args = ap.parse_args()
    for name, value, derived in run(tiny=args.tiny, json_path=args.json):
        print(f"{name},{value:.4f},{derived}")

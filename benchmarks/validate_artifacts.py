"""Schema + regression gate for committed bench/trace artifacts.

Every suite commits a ``BENCH_*.json`` (``bench/v1``) and ``TRACE_*.json``
(``trace/v1``) snapshot of its last full run. Those artifacts are the
repo's performance record — and nothing guarded them: a suite could start
writing malformed documents, or a refactor could silently halve a headline
metric, and the diff would scroll past review. This tool is the CI
tripwire:

1. **schema check** — every committed artifact must carry the right
   schema tag and the structural fields its readers (CI trend tooling,
   the README tables) rely on;
2. **regression diff** — headline metrics are compared against the same
   artifact at a base git revision (default: the previous commit).
   A *watched* metric (suffix-classified: throughput-like higher-better,
   latency-like lower-better) that moved more than ``--threshold``
   (default 20%) in the bad direction fails the run, unless the commit
   touched that suite's bench (an *explained* regression — the bench
   itself changed, so the comparison is void).

  PYTHONPATH=src python -m benchmarks.validate_artifacts [--base REV]
      [--threshold 0.2] [--no-diff]
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import subprocess
import sys

BENCH_SCHEMA = "bench/v1"
TRACE_SCHEMA = "trace/v1"

#: metric-name suffixes where bigger is better
_HIGHER_BETTER = ("_per_s", "_tokens_per_s", "_speedup", "_ok",
                  "_sessions", "_reused", "_acceptance_rate")
#: suffixes where smaller is better
_LOWER_BETTER = ("_ms", "_s", "_bytes", "_bytes_total", "_failed",
                 "_failures", "_overhead_ratio", "_rel_err_p95",
                 "_rel_err_p99", "_mismatches", "_fallbacks")


def _direction(name: str):
    """+1 higher-better, -1 lower-better, 0 unwatched."""
    base = name.split("/", 1)[0]
    for suf in _HIGHER_BETTER:
        if base.endswith(suf):
            return 1
    for suf in _LOWER_BETTER:
        if base.endswith(suf):
            return -1
    return 0


# ------------------------------------------------------------------ schema
def check_bench(doc: dict, path: str) -> list[str]:
    errs = []
    if doc.get("schema") != BENCH_SCHEMA:
        errs.append(f"{path}: schema {doc.get('schema')!r} != "
                    f"{BENCH_SCHEMA!r}")
        return errs
    for field in ("suite", "git_rev", "wall_clock", "metrics"):
        if field not in doc:
            errs.append(f"{path}: missing field {field!r}")
    metrics = doc.get("metrics", {})
    if not isinstance(metrics, dict) or not metrics:
        errs.append(f"{path}: metrics must be a non-empty dict")
        return errs
    for name, rec in metrics.items():
        if not isinstance(rec, dict) or "value" not in rec \
                or "unit" not in rec:
            errs.append(f"{path}: metric {name!r} lacks value/unit")
    return errs


def check_trace(doc: dict, path: str) -> list[str]:
    errs = []
    if doc.get("schema") != TRACE_SCHEMA:
        errs.append(f"{path}: schema {doc.get('schema')!r} != "
                    f"{TRACE_SCHEMA!r}")
        return errs
    for field in ("suite", "wall_clock", "span_summary"):
        if field not in doc:
            errs.append(f"{path}: missing field {field!r}")
    if not isinstance(doc.get("span_summary", None), dict):
        errs.append(f"{path}: span_summary must be a dict")
    return errs


# ------------------------------------------------------------------- diff
def _git_show(rev: str, path: str):
    """The file's JSON at ``rev``, or None if it did not exist there."""
    try:
        out = subprocess.run(
            ["git", "show", f"{rev}:{path}"],
            capture_output=True, text=True, timeout=30, check=True).stdout
        return json.loads(out)
    except Exception:  # noqa: BLE001 — new artifact / no git / bad JSON
        return None


def _changed_files(rev: str) -> set:
    try:
        out = subprocess.run(
            ["git", "diff", "--name-only", rev, "HEAD"],
            capture_output=True, text=True, timeout=30, check=True).stdout
        return set(out.split())
    except Exception:  # noqa: BLE001
        return set()


def diff_bench(doc: dict, base_doc: dict, path: str,
               threshold: float, explained: bool) -> tuple[list, list]:
    """(regressions, notes) for one artifact vs its base revision."""
    regressions, notes = [], []
    base_metrics = base_doc.get("metrics", {})
    for name, rec in doc.get("metrics", {}).items():
        d = _direction(name)
        if d == 0 or name not in base_metrics:
            continue
        new = rec.get("value")
        old = base_metrics[name].get("value")
        if not (isinstance(new, (int, float))
                and isinstance(old, (int, float))):
            continue
        if (isinstance(new, float) and math.isnan(new)) \
                or (isinstance(old, float) and math.isnan(old)):
            continue
        if old == 0:
            continue  # ratio undefined; absolute-zero baselines stay soft
        change = (new - old) / abs(old)
        bad = (d > 0 and change < -threshold) \
            or (d < 0 and change > threshold)
        if bad:
            line = (f"{path}: {name} {old:g} -> {new:g} "
                    f"({change:+.1%}, threshold {threshold:.0%})")
            if explained:
                notes.append(line + "  [explained: bench changed]")
            else:
                regressions.append(line)
    return regressions, notes


# ------------------------------------------------------------------- main
def run(base: str = "HEAD~1", threshold: float = 0.2,
        diff: bool = True, root: str = ".") -> int:
    errs: list[str] = []
    regressions: list[str] = []
    notes: list[str] = []
    bench_paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    trace_paths = sorted(glob.glob(os.path.join(root, "TRACE_*.json")))
    if not bench_paths and not trace_paths:
        print("validate_artifacts: no committed artifacts found")
        return 0
    changed = _changed_files(base) if diff else set()
    for path in bench_paths:
        with open(path) as f:
            doc = json.load(f)
        errs.extend(check_bench(doc, path))
        if diff:
            rel = os.path.relpath(path, root)
            base_doc = _git_show(base, rel)
            if base_doc is None:
                notes.append(f"{path}: no base at {base} (new artifact)")
                continue
            suite = doc.get("suite", "")
            explained = any(
                c == rel or c.endswith(f"bench_{suite}.py")
                for c in changed)
            r, n = diff_bench(doc, base_doc, path, threshold, explained)
            regressions.extend(r)
            notes.extend(n)
    for path in trace_paths:
        with open(path) as f:
            doc = json.load(f)
        errs.extend(check_trace(doc, path))
    for line in notes:
        print(f"note: {line}")
    for line in errs:
        print(f"SCHEMA: {line}", file=sys.stderr)
    for line in regressions:
        print(f"REGRESSION: {line}", file=sys.stderr)
    print(f"validate_artifacts: {len(bench_paths)} bench + "
          f"{len(trace_paths)} trace artifacts, {len(errs)} schema "
          f"errors, {len(regressions)} unexplained regressions")
    return 1 if (errs or regressions) else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--base", default="HEAD~1",
                    help="git rev to diff headline metrics against")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="fractional regression that fails the run")
    ap.add_argument("--no-diff", action="store_true",
                    help="schema checks only (no git comparison)")
    args = ap.parse_args()
    sys.exit(run(base=args.base, threshold=args.threshold,
                 diff=not args.no_diff))

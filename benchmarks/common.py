"""Shared benchmark helpers."""
from __future__ import annotations

import asyncio
import json
import subprocess
import time
from collections import deque

import jax.numpy as jnp

#: one schema for every BENCH_*.json artifact — CI trend tooling reads
#: suite/rev/metrics uniformly instead of per-suite ad-hoc shapes
BENCH_SCHEMA = "bench/v1"

TENSOR_SIZES = {            # paper Figs 1/6/7: 4 KB .. 4 MB float32 tensors
    "4KB": 1_000,
    "40KB": 10_000,
    "400KB": 100_000,
    "4MB": 1_000_000,
}


def make_tensor(n: int):
    return jnp.arange(n, dtype=jnp.float32)


class SingleWorldChannel:
    """The 'vanilla single world' baseline (paper's SW): a bare in-process
    channel with the same asyncio polling discipline and the same wire cost
    (one memcpy per hop via the codec) but none of MultiWorld's bookkeeping —
    no store, no watchdog, no world-status checks, no fencing. The delta
    between this and WorldCommunicator is MultiWorld's overhead."""

    def __init__(self, codec=None) -> None:
        self.buf: deque = deque()
        self.codec = codec

    async def send(self, tensor) -> None:
        if self.codec is not None:
            tensor = self.codec.encode(tensor)
        self.buf.append(tensor)

    async def recv(self):
        while True:
            if self.buf:
                got = self.buf.popleft()
                if self.codec is not None:
                    got = self.codec.decode(got)
                return got
            await asyncio.sleep(0)


def run_async(coro):
    return asyncio.run(coro)


def git_rev() -> str:
    """Short git revision of the working tree, or ``unknown`` outside a
    checkout — artifacts must stay writable anywhere the bench runs."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            check=True).stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001 — no git, not a repo, timeout: all fine
        return "unknown"


def _unit_for(name: str) -> str:
    """Infer a metric's unit from its name suffix (the suites use a
    consistent *_s / *_ms / *_bytes / *_per_s naming discipline). Variant
    rows are spelled ``metric/variant`` — the unit rides the metric part."""
    name = name.split("/", 1)[0]
    if name.endswith("_tokens_per_s"):
        return "tokens/s"
    if name.endswith("_per_s"):
        return "1/s"
    if name.endswith("_ms"):
        return "ms"
    if name.endswith("_s"):
        return "s"
    if name.endswith("_bytes") or name.endswith("_bytes_total"):
        return "bytes"
    if name.endswith("_speedup") or name.endswith("_ratio"):
        return "ratio"
    if name.endswith("_tokens"):
        return "tokens"
    return "count"


def write_bench_json(path: str, *, suite: str,
                     rows: list[tuple[str, float, str]],
                     raw=None, tiny: bool = False) -> dict:
    """Write the suite's ``BENCH_*.json`` artifact in the shared
    ``bench/v1`` schema: suite name, git revision, wall-clock, and one
    ``{value, unit, derived}`` record per reported metric. ``raw`` carries
    the suite's full scenario dict for deep dives; ``rows`` are the
    headline ``(name, value, derived)`` tuples every suite already prints.
    Returns the document (tests assert on it without re-reading)."""
    doc = {
        "schema": BENCH_SCHEMA,
        "suite": suite,
        "git_rev": git_rev(),
        "wall_clock": time.time(),
        "tiny": tiny,
        "metrics": {
            name: {"value": value, "unit": _unit_for(name),
                   "derived": derived}
            for name, value, derived in rows
        },
    }
    if raw is not None:
        doc["raw"] = raw
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, default=str)
    return doc


def trace_path_for(json_path: str, suite: str) -> str:
    """Where the trace artifact lands: ``TRACE_<suite>.json`` in the same
    directory as the suite's ``BENCH_*.json``."""
    import os
    return os.path.join(os.path.dirname(os.path.abspath(json_path)),
                        f"TRACE_{suite}.json")


def collect_obs(server) -> dict:
    """Snapshot a server's tracer + flight recorder into a plain dict —
    the benches tear servers down between phases, so the obs state must be
    captured before teardown and carried to the artifact writer."""
    out: dict = {}
    tracer = getattr(server, "tracer", None)
    if tracer is not None:
        out["span_summary"] = tracer.summary()
        out["spans_recorded"] = tracer.recorded
        out["spans_dropped"] = tracer.dropped
    rec = getattr(server, "recorder", None)
    if rec is not None:
        out["flight_events"] = len(rec)
        out["flight_dumps"] = rec.dumps_total
        out["last_dump"] = rec.last_dump
    return out


def write_trace_json(path: str, *, suite: str, phases: dict) -> dict:
    """Write the suite's ``TRACE_*.json`` next to its ``BENCH_*.json``:
    one ``collect_obs`` snapshot per phase, with the last non-empty phase
    promoted to the artifact's headline summary."""
    from repro.obs.export import write_trace_artifact

    primary = next((p for p in reversed(list(phases.values())) if p), {})
    rec_keys = ("flight_events", "flight_dumps", "last_dump")
    return write_trace_artifact(
        path, suite=suite,
        tracer=primary.get("span_summary", {}),
        recorder=({k: primary[k] for k in rec_keys if k in primary}
                  or None),
        extra={"phases": phases,
               "spans_recorded": primary.get("spans_recorded"),
               "spans_dropped": primary.get("spans_dropped")})

"""Shared benchmark helpers."""
from __future__ import annotations

import asyncio
from collections import deque

import jax.numpy as jnp

TENSOR_SIZES = {            # paper Figs 1/6/7: 4 KB .. 4 MB float32 tensors
    "4KB": 1_000,
    "40KB": 10_000,
    "400KB": 100_000,
    "4MB": 1_000_000,
}


def make_tensor(n: int):
    return jnp.arange(n, dtype=jnp.float32)


class SingleWorldChannel:
    """The 'vanilla single world' baseline (paper's SW): a bare in-process
    channel with the same asyncio polling discipline and the same wire cost
    (one memcpy per hop via the codec) but none of MultiWorld's bookkeeping —
    no store, no watchdog, no world-status checks, no fencing. The delta
    between this and WorldCommunicator is MultiWorld's overhead."""

    def __init__(self, codec=None) -> None:
        self.buf: deque = deque()
        self.codec = codec

    async def send(self, tensor) -> None:
        if self.codec is not None:
            tensor = self.codec.encode(tensor)
        self.buf.append(tensor)

    async def recv(self):
        while True:
            if self.buf:
                got = self.buf.popleft()
                if self.codec is not None:
                    got = self.codec.decode(got)
                return got
            await asyncio.sleep(0)


def run_async(coro):
    return asyncio.run(coro)

"""Paper Fig. 5 reproduction: online instantiation under live traffic.

Timeline (paper §4.2): W1 carries steady sender->leader traffic. Mid-run the
leader begins initializing W2 (non-blocking: W1 throughput must be
unaffected while the leader waits); the second worker joins later (the paper
measures a 20 ms join); traffic then flows on both worlds, with a brief
first-collective dip (paper: NCCL lazy communicator init; here: first-use
path warmup) before both stabilize.

Reported: W1 throughput before/during/after the join, join latency, and the
dip ratio on W2's first batch.
"""
from __future__ import annotations

import asyncio
import time

from repro.core import Cluster

from .common import make_tensor, run_async

TENSOR = 1_000_000       # 4 MB, as in the paper
BATCH = 50               # tensors per throughput sample


async def _scenario() -> dict:
    c = Cluster()
    leader, s1, s2 = c.worker("L"), c.worker("S1"), c.worker("S2")
    await asyncio.gather(
        leader.manager.initialize_world("w1", 0, 2),
        s1.manager.initialize_world("w1", 1, 2),
    )
    x = make_tensor(TENSOR)
    samples: dict[str, list[float]] = {"w1": [], "w2": []}
    phases: list[str] = []
    stop = asyncio.Event()

    async def w1_traffic():
        while not stop.is_set():
            t0 = time.monotonic()
            for _ in range(BATCH):
                await s1.comm.send(x, 0, "w1")
                await leader.comm.recv(1, "w1")
            samples["w1"].append(BATCH * x.nbytes / (time.monotonic() - t0)
                                 / 1e9)
            phases.append(current_phase[0])

    current_phase = ["before"]
    traffic = asyncio.ensure_future(w1_traffic())
    await asyncio.sleep(0.3)

    # leader begins W2 init; S2 arrives later (leader must keep serving W1)
    current_phase[0] = "waiting"
    leader_init = asyncio.ensure_future(
        leader.manager.initialize_world("w2", 0, 2, timeout=30.0))

    await asyncio.sleep(0.3)
    t_join0 = time.monotonic()
    await asyncio.gather(leader_init,
                         s2.manager.initialize_world("w2", 1, 2))
    join_latency = time.monotonic() - t_join0

    current_phase[0] = "after"
    # W2 traffic: first batch shows the warmup dip, then stabilizes
    for _ in range(4):
        t0 = time.monotonic()
        for _ in range(BATCH):
            await s2.comm.send(x, 0, "w2")
            await leader.comm.recv(1, "w2")
        samples["w2"].append(BATCH * x.nbytes / (time.monotonic() - t0) / 1e9)
    await asyncio.sleep(0.2)
    stop.set()
    await traffic
    c.shutdown()

    def mean(vals):
        return sum(vals) / max(len(vals), 1)

    w1_before = mean([s for s, p in zip(samples["w1"], phases)
                      if p == "before"])
    w1_waiting = mean([s for s, p in zip(samples["w1"], phases)
                       if p == "waiting"])
    w1_after = mean([s for s, p in zip(samples["w1"], phases)
                     if p == "after"])
    return {
        "w1_before": w1_before,
        "w1_waiting": w1_waiting or w1_before,
        "w1_after": w1_after or w1_before,
        "w2_first": samples["w2"][0],
        "w2_stable": mean(samples["w2"][1:]),
        "join_latency_ms": join_latency * 1e3,
    }


def run() -> list[tuple[str, float, str]]:
    r = run_async(_scenario())
    rows = [
        ("fig5_w1_before_GBps", r["w1_before"], "steady traffic"),
        ("fig5_w1_during_wait_GBps", r["w1_waiting"],
         "leader waiting on W2 rendezvous"),
        ("fig5_w1_after_join_GBps", r["w1_after"], "both worlds active"),
        ("fig5_w2_first_batch_GBps", r["w2_first"], "warmup dip"),
        ("fig5_w2_stable_GBps", r["w2_stable"], "post-warmup"),
        ("fig5_join_latency_ms", r["join_latency_ms"],
         "paper reports ~20 ms"),
    ]
    # Fig.5 property: waiting for W2 must not dent W1 (>= 70% of baseline)
    assert r["w1_waiting"] >= 0.7 * r["w1_before"], r
    return rows

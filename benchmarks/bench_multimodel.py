"""Multi-model, multi-tenant serving benchmark: one elastic pool hosting
several registered models vs dedicated per-model pools, hot residency
swaps under live traffic, and per-tenant SLO tails under a skewed mix.

Three phases, mirroring the acceptance gates (ISSUE 9):

* **consolidation** — equal replica budget, 80/20 model skew. The shared
  pool (one pipeline, every replica hosting both models) load-balances the
  hot model across the whole budget; the dedicated layout (one
  single-replica pipeline per model) strands the cold model's replica
  while the hot one queues. Gate: shared aggregate tokens/s >= dedicated.
  On a single-core host both layouts serialize onto the same device and
  the A/B degenerates to parity — the gate then asserts the multi-model
  machinery adds *no consolidation tax* (ratio >= 0.9 noise floor); on
  multi-core hosts the shared pool's load balancing wins outright.
* **swap** — residency swap B -> base on a replica with open B sessions:
  the incoming weights stream as a SWAP-headed LOAD envelope stream from
  a resident peer, incumbents live-migrate, and every client finishes
  token-exact. Gates: zero client-visible failures, greedy parity across
  the swap, and a non-empty peer wire transfer.
* **tenant mix** — open-loop 80/20 two-tenant mix (heavy tenant on the
  default model, light tenant on the hot-loaded one) under
  weighted-deficit fair decode scheduling. Gate: every tenant's
  client-observed p95 TTFT stays under that tenant's SLO — the light
  tenant must not starve behind the heavy one's flood.

  PYTHONPATH=src python -m benchmarks.bench_multimodel [--tiny] [--json OUT]

``--tiny`` shrinks token counts and the traffic window for CI smoke; every
gate above is structural (load-balance arithmetic, token equality, fair
scheduling), so they hold in tiny mode too.
"""
from __future__ import annotations

import argparse
import asyncio
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.control import ConstantProfile, MetricsHub, TenantProfile
from repro.control.workload import MultiTenantGenerator
from repro.core import Cluster
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import PipelineServer, ServeEngine

from .common import (collect_obs, run_async, trace_path_for,
                     write_bench_json, write_trace_json)


def _build():
    cfg = get_smoke("llama3.2-1b").with_(num_layers=2,
                                         groups=(BlockGroup(DENSE, 2),))
    model = build_model(cfg)
    hot = model.init(jax.random.PRNGKey(0))
    cold = model.init(jax.random.PRNGKey(1))
    return cfg, model, hot, cold


def _prompts(cfg, n, seq, seed):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (1, seq)) for _ in range(n)]


# -------------------------------------------------------------- consolidation

async def _consolidation_scenario(tiny: bool) -> dict:
    """Equal replica budget (2), 80/20 request skew between two models.
    Shared: one pipeline, both replicas host both models. Dedicated: one
    single-replica pipeline per model. Same requests, same budget — the
    only variable is whether residency lets the hot model's traffic use
    the whole pool."""
    cfg, model, hot, cold = _build()
    new_tokens = 6 if tiny else 16
    n_hot, n_cold = 8, 2                     # the 80/20 skew
    ps_hot = _prompts(cfg, n_hot, 8, seed=1)
    ps_cold = _prompts(cfg, n_cold, 8, seed=2)
    total_tokens = (n_hot + n_cold) * new_tokens

    async def drive(gen_hot, gen_cold):
        # one warm round off-clock (compiles), then the timed batch
        await asyncio.gather(gen_hot(ps_hot[0], 2), gen_cold(ps_cold[0], 2))
        t0 = time.monotonic()
        await asyncio.gather(
            *(gen_hot(p, new_tokens) for p in ps_hot),
            *(gen_cold(p, new_tokens) for p in ps_cold))
        return time.monotonic() - t0

    # shared: 2 replicas, both models resident on both
    c = Cluster()
    shared = PipelineServer(c, model, hot, [2], max_len=64,
                            default_model="hot")
    shared.register_model("cold", model, cold)
    await shared.start()
    for rep in shared.replicas[0]:
        await shared.load_model(rep.worker_id, "cold")
    shared_s = await drive(
        lambda p, n: shared.generate(p, n, step_timeout=120.0,
                                     tenant="heavy"),
        lambda p, n: shared.generate(p, n, step_timeout=120.0,
                                     model="cold", tenant="light"))
    obs = collect_obs(shared)
    model_metrics = MetricsHub(shared, alpha=1.0).model_metrics()
    c.shutdown()

    # dedicated: one single-replica pipeline per model, same total budget
    c_hot, c_cold = Cluster(), Cluster()
    ded_hot = PipelineServer(c_hot, model, hot, [1], max_len=64,
                             name="ded_hot")
    ded_cold = PipelineServer(c_cold, model, cold, [1], max_len=64,
                              name="ded_cold")
    await ded_hot.start()
    await ded_cold.start()
    ded_s = await drive(
        lambda p, n: ded_hot.generate(p, n, step_timeout=120.0),
        lambda p, n: ded_cold.generate(p, n, step_timeout=120.0))
    c_hot.shutdown()
    c_cold.shutdown()

    return {
        "requests_hot": n_hot, "requests_cold": n_cold,
        "new_tokens": new_tokens,
        "shared_s": shared_s, "dedicated_s": ded_s,
        "shared_tokens_per_s": total_tokens / shared_s,
        "dedicated_tokens_per_s": total_tokens / ded_s,
        "speedup": ded_s / shared_s,
        "model_metrics": model_metrics,
        "obs": obs,
    }


# ----------------------------------------------------------------------- swap

async def _swap_scenario(tiny: bool) -> dict:
    """Swap a replica's residency away from model B while B sessions are
    decoding on it. The other replica keeps hosting B, so incumbents
    live-migrate and every client finishes token-exact."""
    cfg, model, hot, cold = _build()
    eng_base = ServeEngine(model, hot, max_len=64)
    eng_b = ServeEngine(model, cold, max_len=64)
    new_tokens = 8 if tiny else 16
    c = Cluster()
    server = PipelineServer(c, model, hot, [2], max_len=64,
                            default_model="base")
    server.register_model("B", model, cold)
    await server.start()
    rep0, rep1 = server.replicas[0]
    await server.load_model(rep0.worker_id, "B")
    peer_report = await server.load_model(rep1.worker_id, "B")

    ps = _prompts(cfg, 4, 8, seed=3)
    wants = [eng_b.generate(p, new_tokens) for p in ps[:3]] \
        + [eng_base.generate(ps[3], new_tokens)]
    tasks = [asyncio.ensure_future(
        server.generate(p, new_tokens, step_timeout=120.0, model="B",
                        tenant="b"))
        for p in ps[:3]]
    tasks.append(asyncio.ensure_future(
        server.generate(ps[3], new_tokens, step_timeout=120.0,
                        tenant="base")))
    deadline = time.monotonic() + 30.0
    while time.monotonic() < deadline:
        if any(s.model == "B" for s in rep1.sessions.values()):
            break
        await asyncio.sleep(0.005)

    t0 = time.monotonic()
    report = await server.swap_model(rep1.worker_id, "B", "base")
    swap_s = time.monotonic() - t0
    outs = await asyncio.gather(*tasks, return_exceptions=True)
    failures = sum(1 for o in outs if isinstance(o, Exception))
    parity = all(not isinstance(o, Exception) and np.array_equal(w, o)
                 for w, o in zip(wants, outs))
    out = {
        "clients": len(tasks),
        "client_failures": failures,
        "token_parity": parity,
        "swap_s": swap_s,
        "swap_source": report["source"],
        "swap_bytes": report["bytes"],
        "swap_transfer_s": report["transfer_s"],
        "peer_load_bytes": peer_report["bytes"],
        "b_still_resident_on": server.registry.resident_counts()["B"],
        "swaps_total": server.swaps_total,
        "wire": {
            "model_loads_total": server.bootstrap.model_loads_total,
            "model_loads_cold": server.bootstrap.model_loads_cold,
            "model_swaps_total": server.bootstrap.model_swaps_total,
        },
        "obs": collect_obs(server),
    }
    c.shutdown()
    return out


# ----------------------------------------------------------------- tenant mix

async def _tenant_mix_scenario(tiny: bool) -> dict:
    """Open-loop 80/20 two-tenant mix on the shared pool: the heavy tenant
    floods the default model while the light tenant runs the hot-loaded
    one. Weighted-deficit scheduling keeps the light tenant's p95 TTFT
    under its SLO instead of letting it starve in FIFO order."""
    cfg, model, hot, cold = _build()
    duration = 2.5 if tiny else 8.0
    new_tokens = 4 if tiny else 8
    rate = 6.0 if tiny else 10.0
    slos = {"heavy": 8.0, "light": 8.0} if tiny else \
        {"heavy": 5.0, "light": 5.0}
    c = Cluster()
    server = PipelineServer(c, model, hot, [2], max_len=64,
                            default_model="hot",
                            tenant_weights={"heavy": 1.0, "light": 2.0})
    server.register_model("cold", model, cold)
    await server.start()
    for rep in server.replicas[0]:
        await server.load_model(rep.worker_id, "cold")
    # warm both models' compile paths off-clock
    warm = _prompts(cfg, 1, 8, seed=4)[0]
    await server.generate(warm, 2, step_timeout=120.0)
    await server.generate(warm, 2, step_timeout=120.0, model="cold")

    rng = np.random.default_rng(5)

    async def submit(tenant, prompt_len):
        p = rng.integers(0, cfg.vocab_size, (1, prompt_len))
        await server.generate(p, new_tokens, step_timeout=120.0,
                              model=tenant.model, tenant=tenant.name)

    gen = MultiTenantGenerator(submit, [
        TenantProfile("heavy", ConstantProfile(0.8 * rate),
                      prompt_len=(4, 8), model=None, weight=1.0),
        TenantProfile("light", ConstantProfile(0.2 * rate),
                      prompt_len=(4, 8), model="cold", weight=2.0),
    ], seed=6)
    summary = await gen.run(duration)

    hub = MetricsHub(server, alpha=1.0)
    tails = hub.tenant_tails()
    out = {
        "duration_s": duration,
        "rate_rps": rate,
        "slo_ttft_s": slos,
        "summary": summary,
        "tenant_tails": tails,
        "tenant_tokens": dict(server.tenant_tokens),
        "slo_ok": {
            name: tails.get(name, {}).get("p95_ttft_s", float("inf"))
            <= slos[name]
            for name in slos
        },
        "obs": collect_obs(server),
    }
    c.shutdown()
    return out


async def _scenario(tiny: bool) -> dict:
    return {
        "consolidation": await _consolidation_scenario(tiny),
        "swap": await _swap_scenario(tiny),
        "tenant_mix": await _tenant_mix_scenario(tiny),
    }


def run(tiny: bool = False, json_path: str | None = None
        ) -> list[tuple[str, float, str]]:
    r = run_async(_scenario(tiny))
    con, sw, mix = r["consolidation"], r["swap"], r["tenant_mix"]
    heavy = mix["tenant_tails"].get("heavy", {})
    light = mix["tenant_tails"].get("light", {})
    rows = [
        ("multimodel_tokens_per_s/shared", con["shared_tokens_per_s"],
         f"{con['requests_hot']}+{con['requests_cold']} requests, one pool "
         f"hosting both models on 2 replicas"),
        ("multimodel_tokens_per_s/dedicated", con["dedicated_tokens_per_s"],
         "same requests and budget, one single-replica pipeline per model"),
        ("multimodel_consolidation_speedup", con["speedup"],
         "shared-pool makespan advantage under the 80/20 model skew"),
        ("multimodel_swap_clients_ok",
         float(sw["clients"] - sw["client_failures"]),
         "clients finished token-exact across the in-rotation swap"),
        ("multimodel_swap_client_failures", float(sw["client_failures"]),
         "client-visible failures during the swap (gate: zero)"),
        ("multimodel_swap_load_bytes", float(sw["peer_load_bytes"]),
         "stage weights streamed from the resident peer as LOAD envelopes"),
        ("multimodel_swap_s", sw["swap_s"],
         "swap_model call: stream + migrate incumbents + retire residency"),
        ("multimodel_p95_ttft_s/heavy",
         heavy.get("p95_ttft_s", float("nan")),
         f"heavy tenant (80% of arrivals), SLO "
         f"{mix['slo_ttft_s']['heavy']:.1f}s"),
        ("multimodel_p95_ttft_s/light",
         light.get("p95_ttft_s", float("nan")),
         f"light tenant (20%, distinct model), SLO "
         f"{mix['slo_ttft_s']['light']:.1f}s"),
        ("multimodel_slo_ok", float(all(mix["slo_ok"].values())),
         "every tenant's p95 TTFT under its own SLO"),
    ]
    # acceptance gates (ISSUE 9). The consolidation floor sits just under
    # parity: a serialized single-core host cannot express the shared
    # pool's load-balancing win (both layouts drain one device), so the
    # hard gate there is "hosting two models costs nothing"; any host
    # with real replica parallelism clears 1.0 with margin.
    assert con["speedup"] >= 0.9, \
        (f"shared pool slower than dedicated at equal budget: "
         f"{con['speedup']:.2f}x ({con['shared_s']:.2f}s vs "
         f"{con['dedicated_s']:.2f}s)")
    assert sw["client_failures"] == 0, sw
    assert sw["token_parity"], \
        "greedy parity lost across the residency swap"
    assert sw["swap_source"] == "peer" and sw["peer_load_bytes"] > 0, sw
    assert sw["b_still_resident_on"] >= 1, sw
    assert mix["summary"]["failed"] == 0, mix["summary"]
    for name, ok in mix["slo_ok"].items():
        assert ok, (f"tenant {name!r} p95 TTFT "
                    f"{mix['tenant_tails'][name]['p95_ttft_s']:.2f}s over "
                    f"SLO {mix['slo_ttft_s'][name]:.1f}s")
    for name in ("heavy", "light"):
        assert mix["summary"]["tenants"][name]["ok"] > 0, mix["summary"]
    if json_path:
        phases = {k: v.pop("obs", {}) for k, v in r.items()
                  if isinstance(v, dict) and "obs" in v}
        write_bench_json(json_path, suite="multimodel", rows=rows, raw=r,
                         tiny=tiny)
        write_trace_json(trace_path_for(json_path, "multimodel"),
                         suite="multimodel", phases=phases)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: few tokens, short traffic window")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write rows + raw results as JSON artifact")
    args = ap.parse_args()
    for name, value, derived in run(tiny=args.tiny, json_path=args.json):
        print(f"{name},{value:.4f},{derived}")

"""Online instantiation (paper §3.1 Fig. 2c, §4.2): join without restart."""
import asyncio

import jax.numpy as jnp

from repro.core import (
    Cluster,
    FailureKind,
    OnlineInstantiator,
    WorldSpec,
    WorldStatus,
)


def t(v):
    return jnp.asarray(v, dtype=jnp.float32)


async def make_world(c, name, workers):
    await asyncio.gather(*[
        c.worker(w).manager.initialize_world(name, r, len(workers))
        for r, w in enumerate(workers)
    ])


def test_join_does_not_disturb_existing_traffic(arun):
    """Fig. 5 property: while the leader waits for W2-R1 to arrive, W1-R1's
    traffic continues (init is non-blocking w.r.t. existing worlds)."""
    async def scenario():
        c = Cluster()
        await make_world(c, "w1", ["L", "S1"])
        leader = c.worker("L")
        received = []

        async def traffic():
            for i in range(50):
                await c.worker("S1").comm.send(t([float(i)]), 0, "w1")
                got = await leader.comm.recv(1, "w1")
                received.append(float(got[0]))

        async def late_joiner():
            await asyncio.sleep(0.05)  # join mid-traffic
            await c.worker("S2").manager.initialize_world("w2", 1, 2)

        # leader begins w2 init immediately; S2 arrives only later
        traffic_task = asyncio.ensure_future(traffic())
        await asyncio.gather(
            leader.manager.initialize_world("w2", 0, 2, timeout=5.0),
            late_joiner(),
        )
        await traffic_task
        assert received == [float(i) for i in range(50)]
        assert leader.manager.worlds["w2"].status is WorldStatus.HEALTHY
        # and the new world is immediately usable
        await c.worker("S2").comm.send(t([99.0]), 0, "w2")
        got = await leader.comm.recv(1, "w2")
        assert float(got[0]) == 99.0
        c.shutdown()

    arun(scenario())


def test_instantiator_creates_pairwise_worlds(arun):
    async def scenario():
        c = Cluster()
        inst = OnlineInstantiator(c)
        specs = [
            WorldSpec.pair("e15", "P1", "P5"),
            WorldSpec.pair("e54", "P5", "P4"),
        ]
        await inst.instantiate(specs)
        assert c.worker("P5").manager.worlds["e15"].rank_of("P5") == 1
        assert c.worker("P5").manager.worlds["e54"].rank_of("P5") == 0
        assert c.worker("P1").manager.worlds["e15"].status is WorldStatus.HEALTHY
        c.shutdown()

    arun(scenario())


def test_full_fig2_cycle_fail_then_replace(arun):
    """Fig. 2 end-to-end: rhombus -> P3 dies -> P5 replaces it with fresh
    worlds -> data flows P1->P5->P4 on the new path."""
    async def scenario():
        c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        await make_world(c, "w1", ["P1", "P2"])   # paper Fig. 2 world numbering
        await make_world(c, "w2", ["P1", "P3"])
        await make_world(c, "w3", ["P2", "P4"])
        await make_world(c, "w4", ["P3", "P4"])

        c.kill("P3", FailureKind.SILENT_HANG)
        await asyncio.sleep(0.3)
        assert c.worker("P1").manager.worlds["w2"].status is WorldStatus.BROKEN

        inst = OnlineInstantiator(c)
        specs = await inst.replace("P3", "P5", peers=["P1", "P4"])
        (w_p1, w_p4) = specs
        # P5 inherits P3's role: recv from P1, forward to P4
        async def p5_stage():
            x = await c.worker("P5").comm.recv(0, w_p1.name)
            await c.worker("P5").comm.send(x * 2, 0, w_p4.name)

        task = asyncio.ensure_future(p5_stage())
        await c.worker("P1").comm.send(t([21.0]), 1, w_p1.name)
        got = await c.worker("P4").comm.recv(1, w_p4.name)
        await task
        assert float(got[0]) == 42.0
        # old healthy worlds still healthy
        assert c.worker("P1").manager.worlds["w1"].status is WorldStatus.HEALTHY
        c.shutdown()

    arun(scenario())


def test_join_latency_is_recorded(arun):
    async def scenario():
        c = Cluster()
        inst = OnlineInstantiator(c)
        await inst.instantiate([WorldSpec.pair("e", "A", "B")])
        assert len(inst.joins) == 1
        _, name, dt = inst.joins[0]
        assert name == "e" and dt < 5.0
        c.shutdown()

    arun(scenario())

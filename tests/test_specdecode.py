"""Speculative decoding on elastic role pools (ISSUE 10).

The acceptance bar: a pipeline with a draft pool keeps exact greedy parity
with the single-engine oracle (verification re-derives every committed
token from target-model argmax, so a bad draft can cost speed but never
correctness); killing or draining the draft pool mid-generation degrades
every open session to plain decode with zero client-visible failures and
zero target-pool recomputation; the drain guard allows giving up the last
draft replica (sessions degrade, nothing strands) while still refusing the
last decode-capable one; and the acceptance-driven SpecDecodePolicy trades
draft-vs-target capacity on the measured acceptance rate.
"""
import asyncio
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.control import MetricsHub, ReplicaSample, SpecDecodePolicy, StageSnapshot
from repro.core import Cluster, FailureKind
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import (
    PipelineServer,
    ROLE_DECODE,
    ROLE_DRAFT,
    ServeEngine,
)

CFG = get_smoke("llama3.2-1b").with_(num_layers=2,
                                     groups=(BlockGroup(DENSE, 2),))
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
ENGINE = ServeEngine(MODEL, PARAMS, max_len=64)

# the draft: a 1-layer sibling sharing the embedding/head, its block being
# the target's own first layer — agrees with the target often enough to
# exercise non-trivial acceptance, disagrees enough to exercise rejection
DRAFT_CFG = CFG.with_(num_layers=1, groups=(BlockGroup(DENSE, 1),))
DRAFT_MODEL = build_model(DRAFT_CFG)
DRAFT_PARAMS = {
    k: v for k, v in PARAMS.items() if k != "groups"
}
DRAFT_PARAMS["groups"] = [jax.tree.map(lambda a: a[:1], PARAMS["groups"][0])]


def _prompts(n, seq=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, (1, seq)) for _ in range(n)]


async def _wait_open(server, stage, n, timeout=20.0):
    deadline = time.monotonic() + timeout
    while sum(r.open_sessions() for r in server.replicas[stage]) < n:
        assert time.monotonic() < deadline, "sessions never all opened"
        await asyncio.sleep(0.005)


# ------------------------------------------------------------ parity + wiring

def test_spec_generate_exact_parity(arun):
    """Draft-pool pipeline == single engine, token for token. Also checks
    the plumbing actually ran speculatively (rounds + both-side counters)
    and that spec_k=0 opts a single call out."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS,
                                [{"both": 1, "draft": 1}], max_len=64,
                                draft_model=DRAFT_MODEL,
                                draft_params=DRAFT_PARAMS, spec_k=3)
        await server.start()
        ps = _prompts(3, seed=1)
        wants = [ENGINE.generate(p, 8) for p in ps]
        outs = [await server.generate(p, 8, step_timeout=60.0) for p in ps]
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(got, want)
        # it really was speculative: verify rounds happened, both sides
        # counted, and the target pool accepted at least one draft token
        assert server.spec_rounds_total >= 1
        assert server.spec_fallbacks_total == 0
        assert server.spec_proposed_total >= server.spec_rounds_total
        assert 0 <= server.spec_accepted_total <= server.spec_proposed_total
        stats = {s["role"]: s for s in server.replica_stats().values()}
        assert stats["draft"]["spec_proposals"] >= 1
        assert stats["both"]["spec_verifies"] >= 1
        assert stats["both"]["spec_proposed"] == server.spec_proposed_total
        # per-call opt-out: spec_k=0 must not touch the draft pool
        rounds0 = server.spec_rounds_total
        got = await server.generate(ps[0], 8, step_timeout=60.0, spec_k=0)
        np.testing.assert_array_equal(got, wants[0])
        assert server.spec_rounds_total == rounds0
        # observability rollup: acceptance EWMA + spec metric group
        hub = MetricsHub(server)
        hub.poll()
        await asyncio.sleep(0.01)
        snaps = hub.poll()
        assert "draft" in snaps[0].role_slices
        spec = hub.spec_metrics()
        assert spec["spec_rounds_total"] == server.spec_rounds_total
        assert spec["proposed_tokens_total"] == server.spec_proposed_total
        assert spec["propose_dispatches_total"] >= 1
        assert "repro_spec_proposed_tokens_total" in hub.export_prometheus()
        c.shutdown()

    arun(scenario(), timeout=300.0)


# ------------------------------------------------------- degrade on draft loss

def test_draft_kill_degrades_to_plain_decode(arun):
    """Killing the only draft replica mid-generation: every open session
    finishes with exact parity through the plain-decode fallback, the
    target pool recomputes nothing, and the degrade is visible in the
    fallback counter (a recovery-matrix row)."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS,
                                [{"both": 1, "draft": 1}], max_len=64,
                                draft_model=DRAFT_MODEL,
                                draft_params=DRAFT_PARAMS, spec_k=3)
        await server.start()
        ps = _prompts(2, seed=2)
        wants = [ENGINE.generate(p, 10) for p in ps]
        tasks = [asyncio.ensure_future(
            server.generate(p, 10, step_timeout=30.0)) for p in ps]
        await _wait_open(server, 0, 2)
        draft = next(r for r in server.replicas[0] if r.role == ROLE_DRAFT)
        # detectable crash: the next PROPOSE errors instead of timing out
        c.kill(draft.worker_id, FailureKind.CRASH_DETECTABLE)
        outs = await asyncio.gather(*tasks)
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(got, want)
        assert server.spec_fallbacks_total >= 1
        # target-pool sessions never moved or re-prefilled for this
        m = server.migrations.stats()
        assert m["reprefills_total"] == 0
        assert m["recomputed_tokens"] == 0
        c.shutdown()

    arun(scenario(), timeout=300.0)


def test_draft_drain_under_traffic(arun):
    """Draining the only draft replica (voluntary scale-down) under open
    sessions: allowed by the drain guard — draft sessions degrade, they do
    not strand — and generation completes with parity."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS,
                                [{"both": 1, "draft": 1}], max_len=64,
                                draft_model=DRAFT_MODEL,
                                draft_params=DRAFT_PARAMS, spec_k=3)
        await server.start()
        ps = _prompts(2, seed=3)
        wants = [ENGINE.generate(p, 10) for p in ps]
        tasks = [asyncio.ensure_future(
            server.generate(p, 10, step_timeout=30.0)) for p in ps]
        await _wait_open(server, 0, 2)
        gone = await server.remove_replica(0, role=ROLE_DRAFT, drain=True,
                                           timeout=60.0)
        assert gone
        outs = await asyncio.gather(*tasks)
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(got, want)
        # no draft replica left; sessions finished as plain decode
        assert not any(r.role == ROLE_DRAFT and r.worker.alive
                       and not r.draining for r in server.replicas[0])
        m = server.migrations.stats()
        assert m["reprefills_total"] == 0
        c.shutdown()

    arun(scenario(), timeout=300.0)


def test_drain_guard_three_roles(arun):
    """Three-pool stage: the guard still refuses to give up the last
    decode-capable replica, but the last *draft* replica is removable —
    losing it degrades sessions to plain decode instead of stranding them.
    """
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS,
                                [{"prefill": 1, "decode": 1, "draft": 1}],
                                max_len=64,
                                draft_model=DRAFT_MODEL,
                                draft_params=DRAFT_PARAMS, spec_k=2)
        await server.start()
        victim = next(r for r in server.replicas[0]
                      if r.role == ROLE_DECODE)
        try:
            await server.remove_replica(0, victim.worker_id, drain=True)
            raise AssertionError("drained the last decode-capable replica")
        except RuntimeError as e:
            assert "decode-capable" in str(e)
        gone = await server.remove_replica(0, role=ROLE_DRAFT, drain=True,
                                           timeout=30.0)
        assert gone
        c.shutdown()

    arun(scenario(), timeout=300.0)


# ------------------------------------------------------------ policy (pure)

def _spec_snap(acc, *, n_draft=1, n_decode=2, proposed=100,
               donor="decode"):
    def rep(i, role, spec_proposed=0):
        return ReplicaSample(f"{role}{i}", 0, True, False, 0, 0, 0,
                             0.0, 0.0, role=role,
                             spec_proposed=spec_proposed)

    reps = ([rep(i, "draft") for i in range(n_draft)]
            + [rep(i, donor, spec_proposed=proposed)
               for i in range(n_decode)])
    snap = StageSnapshot(stage=0, t=0.0, n_replicas=len(reps), n_failed=0,
                         queue_total=0, queue_per_replica=0.0,
                         throughput=0.0, latency_s=0.0, replicas=reps,
                         acceptance_rate=acc)
    for role, n in (("draft", n_draft), (donor, n_decode)):
        snap.role_slices[role] = StageSnapshot(
            stage=0, t=0.0, n_replicas=n, n_failed=0, queue_total=0,
            queue_per_replica=0.0, throughput=0.0, latency_s=0.0,
            role=role)
    return snap


def test_spec_policy_trades_capacity_on_acceptance():
    pol = SpecDecodePolicy(grow_at=0.8, shrink_at=0.3, min_tokens=16)
    # high acceptance: grow draft, funded by draining a decode replica
    out = pol.decide_many(_spec_snap(0.95))
    assert [(d.delta, d.role) for d in out] == [(1, "draft"),
                                                (-1, "decode")]
    # low acceptance: drain draft, return the capacity to the target pool
    out = pol.decide_many(_spec_snap(0.1))
    assert [(d.delta, d.role) for d in out] == [(-1, "draft"),
                                                (1, "decode")]
    # in band: hold
    assert all(d.hold for d in pol.decide_many(_spec_snap(0.5)))
    # the trade donor falls back to the colocated pool
    out = pol.decide_many(_spec_snap(0.95, donor="both"))
    assert [(d.delta, d.role) for d in out] == [(1, "draft"), (-1, "both")]


def test_spec_policy_guards():
    pol = SpecDecodePolicy(min_tokens=16, max_draft=2, min_target=1)
    # cold EWMAs: too few proposals ever judged -> hold
    assert all(d.hold for d in pol.decide_many(_spec_snap(1.0, proposed=3)))
    # no draft pool at all -> hold (the policy never bootstraps one)
    snap = _spec_snap(1.0, n_draft=1)
    del snap.role_slices["draft"]
    assert all(d.hold for d in pol.decide_many(snap))
    # draft pool at its cap -> no grow vote
    assert all(d.hold
               for d in pol.decide_many(_spec_snap(1.0, n_draft=2)))
    # donor at min_target: grow stands alone, no trade drain
    out = pol.decide_many(_spec_snap(1.0, n_decode=1))
    assert [(d.delta, d.role) for d in out] == [(1, "draft")]
    # never drain draft below min_draft
    pol2 = SpecDecodePolicy(min_tokens=16, min_draft=1)
    assert all(d.hold for d in pol2.decide_many(_spec_snap(0.0)))

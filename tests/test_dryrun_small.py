"""Dry-run machinery on a reduced 8-device mesh (subprocess so the forced
device count never leaks into other tests), plus unit tests of the
loop-aware HLO analyzer."""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze_hlo_text, parse_module

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.launch.lowering import run_combo, SkipCombo
from repro.launch.mesh import make_test_mesh

results = {}
mesh = make_test_mesh()
for arch, shape in [("llama3.2-1b", "train_4k"),
                    ("mamba2-2.7b", "decode_32k"),
                    ("whisper-base", "prefill_32k"),
                    ("qwen3-moe-235b-a22b", "decode_32k")]:
    r = run_combo(arch, shape, mesh)
    results[f"{arch}/{shape}"] = {
        "dominant": r["dominant"],
        "flops": r["hlo_flops_per_dev"],
        "useful": r["useful_flops_ratio"],
        "ncoll": r["n_collectives"],
    }
# sanctioned skip must raise SkipCombo
try:
    run_combo("yi-34b", "long_500k", mesh)
    results["skip"] = "MISSING"
except SkipCombo:
    results["skip"] = "ok"
# multi-pod test mesh lowers too
mesh2 = make_test_mesh(multi_pod=True)
r = run_combo("llama3.2-1b", "decode_32k", mesh2)
results["multipod"] = r["dominant"]
print(json.dumps(results))
"""


def test_dryrun_reduced_mesh():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=900, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=".")
    assert out.returncode == 0, out.stderr[-3000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    assert results["skip"] == "ok"
    assert results["multipod"] in ("memory", "compute", "collective")
    for combo, r in results.items():
        if combo in ("skip", "multipod"):
            continue
        assert r["flops"] > 0, combo
        assert 0 < r["useful"] <= 2.0, (combo, r)
        assert r["ncoll"] > 0, combo


def test_hlo_cost_scan_trip_counting():
    def body(c, _):
        return jnp.tanh(c @ c), None

    def f(x):
        return jax.lax.scan(body, x, None, length=7)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f).lower(x).compile().as_text()
    r = analyze_hlo_text(txt)
    assert abs(r["flops"] - 7 * 2 * 64 ** 3) / (7 * 2 * 64 ** 3) < 0.01


def test_hlo_cost_dot_flops_exact():
    def g(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 16), jnp.float32)
    txt = jax.jit(g).lower(a, b).compile().as_text()
    r = analyze_hlo_text(txt)
    assert r["flops"] == 2 * 32 * 128 * 16


def test_hlo_parse_handles_tuple_shapes():
    txt = """HloModule m, entry_computation_layout={()->f32[2]{0}}

ENTRY %main (p: f32[2]) -> f32[2] {
  %p = f32[2]{0} parameter(0)
  %t = (f32[2]{0}, s32[], /*index=2*/f32[4,4]{1,0}) tuple(%p, %p, %p)
  ROOT %g = f32[2]{0} get-tuple-element(%t), index=0
}
"""
    comps, entry = parse_module(txt)
    assert entry is not None
    ops = {o.name: o for o in comps[entry].ops}
    assert ops["t"].opcode == "tuple"
    assert ops["g"].is_root

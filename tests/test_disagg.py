"""Disaggregated prefill/decode pools: role-specialized replicas with the
KV handoff as the steady-state data path.

The acceptance bar (ISSUE 5): a split-pool pipeline keeps greedy token
parity with the single engine across the prefill->decode handoff, the
colocated (``role='both'``) path stays behavior-identical, and the
role-aware recovery edges hold — a RETRY raised mid-handoff falls back to
full re-prefill on the prefill pool, and killing the *only* decode replica
while prefill replicas survive heals a replacement into the decode role.
Delta snapshots and the per-kind latency split (satellites) are covered
here too.
"""
import asyncio
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.control import (
    DisaggregatedStagePolicy,
    ElasticController,
    MetricsHub,
    ScaleDecision,
    StageSnapshot,
    TokenRatePolicy,
    TTFTSLOPolicy,
)
from repro.core import Cluster, FailureKind
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import (
    PipelineServer,
    ReplicaRouter,
    ROLE_BOTH,
    ROLE_DECODE,
    ROLE_PREFILL,
    ServeEngine,
)
from repro.serving.partition import split_stages, stage_cache_seq_axes
from repro.statexfer import (
    SessionSnapshot,
    SnapshotTransferError,
    apply_snapshot_delta,
    snapshot_delta_to_blob,
    snapshot_from_blob,
    snapshot_to_blob,
    tree_equal,
)

CFG = get_smoke("llama3.2-1b").with_(num_layers=2,
                                     groups=(BlockGroup(DENSE, 2),))
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
ENGINE = ServeEngine(MODEL, PARAMS, max_len=64)


def _prompts(n, seq=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, (1, seq)) for _ in range(n)]


async def _wait_open(server, stage, n, timeout=20.0):
    deadline = time.monotonic() + timeout
    while sum(r.open_sessions() for r in server.replicas[stage]) < n:
        assert time.monotonic() < deadline, "sessions never all opened"
        await asyncio.sleep(0.005)


# -------------------------------------------------------------------- router

def test_router_role_rotation():
    r = ReplicaRouter()
    r.add("p", role=ROLE_PREFILL)
    r.add("d", role=ROLE_DECODE)
    r.add("b", role=ROLE_BOTH)
    assert r.healthy() == ["p", "d", "b"]
    assert r.healthy(ROLE_PREFILL) == ["p", "b"]
    assert r.healthy(ROLE_DECODE) == ["d", "b"]
    # role-restricted picks never land in the other pool
    for _ in range(8):
        assert r.pick(ROLE_PREFILL) in ("p", "b")
        assert r.pick(ROLE_DECODE) in ("d", "b")
    r.mark_broken("b")
    assert r.healthy(ROLE_PREFILL) == ["p"]
    assert r.try_pick(role=ROLE_DECODE) == "d"
    r.mark_broken("d")
    assert r.try_pick(role=ROLE_DECODE) is None
    assert r.try_pick(role=ROLE_PREFILL) == "p"


def test_router_probe_prune_on_remove_and_break():
    """The load-probe fix: pick_least_loaded must never score a world that
    left rotation — not via the probe, and not via stale routed history."""
    r = ReplicaRouter(["a", "b", "c"])
    scored = []

    def probe(world):
        scored.append(world)
        return 0.0

    r.set_load_probe(probe)
    dropped = []
    r.set_drop_listener(dropped.append)
    r.pick_least_loaded()
    r.remove("a")
    r.mark_broken("b")
    scored.clear()
    for _ in range(4):
        assert r.pick_least_loaded() == "c"
    assert set(scored) == {"c"}
    assert dropped == ["a"]                  # graceful retirement notifies
    assert "a" not in r.routed and "b" not in r.routed
    # no-probe fallback: a fenced world's routed history is gone too
    r.set_load_probe(None)
    assert r.pick_least_loaded() == "c"


def test_edge_load_guards_dead_replicas(arun):
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 2], max_len=64,
                                least_loaded=True)
        await server.start()
        rep = server.replicas[1][0]
        entry = rep.upstream[0]
        assert server._edge_load(entry) == 0.0
        # fenced: the probe must make the edge unpickable, not least-loaded
        server.broken_worlds.add(entry)
        assert server._edge_load(entry) == float("inf")
        server.broken_worlds.discard(entry)
        # retired: remove_replica prunes the probe target entirely
        await server.remove_replica(1, rep.worker_id, drain=True,
                                    timeout=30.0)
        assert server._world_to_replica.get(entry) is None
        c.shutdown()

    arun(scenario())


# ------------------------------------------------------------------- handoff

def test_split_pools_generate_matches_engine(arun):
    """Token parity across the prefill->decode handoff at every stage, and
    the decode pool really is the only pool decoding."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(
            c, MODEL, PARAMS,
            [{"prefill": 1, "decode": 1}, {"prefill": 1, "decode": 2}],
            max_len=64)
        await server.start()
        ps = _prompts(4, seed=2)
        wants = [ENGINE.generate(p, 5) for p in ps]
        outs = await asyncio.gather(
            *[server.generate(p, 5, step_timeout=30.0) for p in ps])
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(got, want)
        m = server.migrations.stats()
        # one handoff per split stage per session (both stages are split)
        assert m["handoffs_total"] == 2 * len(ps), m
        assert m["handoff_failures"] == 0 and m["handoff_bytes_total"] > 0
        for wid, s in server.replica_stats().items():
            if s["role"] == "prefill":
                assert s["decode_steps"] == 0, (wid, s)
                assert s["prefills"] > 0 and s["handoffs_out"] > 0, (wid, s)
            if s["role"] == "decode":
                assert s["prefills"] == 0, (wid, s)
        c.shutdown()

    arun(scenario(), timeout=300.0)


def test_colocated_stage_never_hands_off(arun):
    """role='both' (int replica counts) must keep the pre-disaggregation
    behavior: local installs, zero handoffs, token parity."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 2], max_len=64)
        await server.start()
        p = _prompts(1, seed=3)[0]
        want = ENGINE.generate(p, 5)
        got = await server.generate(p, 5, step_timeout=30.0)
        np.testing.assert_array_equal(got, want)
        assert server.migrations.handoffs_total == 0
        assert all(r.role == ROLE_BOTH
                   for reps in server.replicas for r in reps)
        c.shutdown()

    arun(scenario(), timeout=300.0)


def test_handoff_failure_falls_back_to_reprefill(arun):
    """Satellite edge: a RETRY raised mid-handoff sends the client through
    a full re-prefill on the prefill pool — and the session still finishes
    with the exact greedy tokens."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS,
                                [{"prefill": 1, "decode": 1}, 1],
                                max_len=64)
        await server.start()
        real = server.migrations._stream
        torn = {"n": 0}

        async def failing(src, dst, world, chunks, **kw):
            if world.startswith("hand:") and torn["n"] < 2:
                torn["n"] += 1
                raise SnapshotTransferError("injected torn handoff")
            return await real(src, dst, world, chunks, **kw)

        server.migrations._stream = failing
        p = _prompts(1, seed=4)[0]
        want = ENGINE.generate(p, 5)
        got = await server.generate(p, 5, step_timeout=30.0)
        np.testing.assert_array_equal(got, want)
        m = server.migrations.stats()
        assert m["handoff_failures"] == 2
        assert m["handoffs_total"] >= 1      # the retry eventually lands
        retries = sum(s["retries_sent"]
                      for s in server.replica_stats().values())
        assert retries >= 2                  # each torn handoff bounced once
        # the re-prefills went back through the prefill pool, never the
        # decode pool (served-prefill counter only ticks on success, so
        # the prefill replica shows the one that finally landed)
        prefills = {s["role"]: s["prefills"]
                    for s in server.replica_stats().values()
                    if s["stage"] == 0}
        assert prefills.get("prefill", 0) >= 1
        assert prefills.get("decode", 0) == 0
        c.shutdown()

    arun(scenario(), timeout=300.0)


def test_kill_only_decode_replica_heals_into_role(arun):
    """Satellite edge: the only decode replica dies mid-generation while
    the prefill replica survives — generation completes (prefill pool
    degrades to local serving during the gap) and the controller heals the
    replacement into the *decode* role."""
    async def scenario():
        c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        server = PipelineServer(c, MODEL, PARAMS,
                                [1, {"prefill": 1, "decode": 1}],
                                max_len=64)
        await server.start()
        ctrl = ElasticController(server, interval=0.02, scale_stages=[])
        ctrl.start()
        p = _prompts(1, seed=5)[0]
        want = ENGINE.generate(p, 8)
        task = asyncio.ensure_future(
            server.generate(p, 8, step_timeout=5.0))
        await _wait_open(server, 1, 1)
        victim = next(r for r in server.replicas[1]
                      if r.role == ROLE_DECODE)
        c.kill(victim.worker_id, FailureKind.SILENT_HANG)
        got = await task
        np.testing.assert_array_equal(got, want)
        await ctrl.stop()
        assert ctrl.heals >= 1
        healed = [r for r in server.replicas[1]
                  if r.role == ROLE_DECODE and r.worker.alive]
        assert healed, "decode pool was not healed back"
        assert healed[0].worker_id != victim.worker_id
        c.shutdown()

    arun(scenario(), timeout=300.0)


def test_drain_decode_replica_migrates_within_pool(arun):
    """Scale-down of a decode-pool replica hands its sessions to the other
    decode replica (never the prefill pool), zero re-prefills."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS,
                                [1, {"prefill": 1, "decode": 2}],
                                max_len=64)
        await server.start()
        ps = _prompts(4, seed=6)
        wants = [ENGINE.generate(p, 6) for p in ps]
        tasks = [asyncio.ensure_future(
            server.generate(p, 6, step_timeout=30.0)) for p in ps]
        await _wait_open(server, 1, 4)
        victim = max((r for r in server.replicas[1]
                      if r.role == ROLE_DECODE and not r.draining),
                     key=lambda r: r.open_sessions())
        moved = victim.open_sessions()
        await server.remove_replica(1, victim.worker_id, drain=True,
                                    timeout=60.0)
        outs = await asyncio.gather(*tasks)
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(got, want)
        m = server.migrations.stats()
        assert m["migrations_total"] >= moved >= 1
        assert m["reprefills_total"] == 0
        survivors = [r for r in server.replicas[1] if r.worker.alive]
        assert all(not r.sessions for r in survivors
                   if r.role == ROLE_PREFILL)
        c.shutdown()

    arun(scenario(), timeout=300.0)


def test_drain_guard_protects_last_capable_replica(arun):
    """The role-aware drain guard: a split stage refuses to drain its last
    prefill-capable (or decode-capable) replica even while the other pool
    has spare capacity."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS,
                                [1, {"prefill": 1, "decode": 2}],
                                max_len=64)
        await server.start()
        victim = next(r for r in server.replicas[1]
                      if r.role == ROLE_PREFILL)
        try:
            await server.remove_replica(1, victim.worker_id, drain=True)
            raise AssertionError("drained the last prefill-capable replica")
        except RuntimeError as e:
            assert "prefill-capable" in str(e)
        # decode pool still has slack: draining one decode replica is fine
        gone = await server.remove_replica(1, role=ROLE_DECODE, drain=True,
                                           timeout=30.0)
        assert "decode" in gone
        c.shutdown()

    arun(scenario(), timeout=300.0)


# ----------------------------------------------------------- delta snapshots

def test_delta_snapshot_roundtrip_and_size():
    spec = split_stages(CFG, 1)[0]
    seq_axes = stage_cache_seq_axes(CFG, spec)
    sess = ENGINE.start_session(_prompts(1, seed=7)[0])
    for _ in range(3):
        ENGINE.step_session(sess)
    base = SessionSnapshot(1, 0, sess.t - 1, 1, sess.cache)
    base_blob = snapshot_to_blob(base)
    for _ in range(4):
        ENGINE.step_session(sess)
    cur = SessionSnapshot(1, 0, sess.t - 1, 1, sess.cache)
    delta_blob = snapshot_delta_to_blob(cur, base_step=base.step,
                                        seq_len=64, seq_axes=seq_axes)
    full_blob = snapshot_to_blob(cur)
    # only the 4 new positions re-encode: ~seq_len/interval_tokens smaller
    assert len(delta_blob) < len(full_blob) / 4, (len(delta_blob),
                                                  len(full_blob))
    rec = apply_snapshot_delta(snapshot_from_blob(base_blob), delta_blob)
    assert rec.step == cur.step
    assert tree_equal(rec.cache, cur.cache)
    # fail closed: a delta against the wrong base cursor must not install
    stale = snapshot_delta_to_blob(cur, base_step=base.step + 1,
                                   seq_len=64, seq_axes=seq_axes)
    try:
        apply_snapshot_delta(snapshot_from_blob(base_blob), stale)
        raise AssertionError("stale delta applied")
    except SnapshotTransferError:
        pass


def test_delta_snapshots_in_store(arun):
    """The background sweep ships (base, delta) pairs, reconstructs the
    newest cursor on read, and restore still recovers a killed session."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 1], max_len=64,
                                snapshot_interval_s=3600.0)  # manual sweeps
        await server.start()
        p = _prompts(1, seed=8)[0]
        want = ENGINE.generate(p, 8)
        task = asyncio.ensure_future(server.generate(p, 8,
                                                     step_timeout=30.0))
        await _wait_open(server, 1, 1)
        await server.snapshots.sweep()           # full base
        sid = next(iter(server.replicas[1][0].sessions))
        base_step = server.snapshots.latest_step(sid, 1)
        got = await task
        np.testing.assert_array_equal(got, want)
        c.shutdown()
        assert base_step is not None

    async def scenario_counters():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 1], max_len=64,
                                snapshot_interval_s=3600.0)
        await server.start()
        p = _prompts(1, seed=9)[0]

        async def decoding(n):
            return await server.generate(p, n, step_timeout=30.0)

        task = asyncio.ensure_future(decoding(10))
        await _wait_open(server, 1, 1)
        await server.snapshots.sweep()           # base
        rep = server.replicas[1][0]
        sid = next(iter(rep.sessions))
        step0 = rep.sessions[sid].step
        deadline = time.monotonic() + 20.0
        while rep.sessions.get(sid) is not None \
                and rep.sessions[sid].step == step0:
            assert time.monotonic() < deadline
            await asyncio.sleep(0.005)
        if rep.sessions.get(sid) is not None:
            await server.snapshots.sweep()       # delta vs the base
            assert server.snapshots.delta_snapshots_taken >= 1
            snap = server.snapshots.latest(sid, 1)
            assert snap is not None
            assert snap.step > step0 - 1         # newest cursor, not base
        await task
        hub = MetricsHub(server)
        mm = hub.migration_metrics()
        assert mm["delta_snapshots_total"] == \
            server.snapshots.delta_snapshots_taken
        assert mm["snapshot_delta_bytes_total"] \
            < mm["snapshot_bytes_total"]
        c.shutdown()

    arun(scenario())
    arun(scenario_counters())


# ------------------------------------------------------- metrics and policy

def test_metrics_latency_split_and_role_slices(arun):
    async def scenario():
        c = Cluster()
        server = PipelineServer(
            c, MODEL, PARAMS, [{"prefill": 1, "decode": 1}, 1], max_len=64)
        await server.start()
        hub = MetricsHub(server, alpha=1.0)
        hub.poll()
        await server.generate(_prompts(1, seed=10)[0], 5,
                              step_timeout=30.0)
        await asyncio.sleep(0.05)
        snaps = hub.poll()
        s0 = snaps[0]
        assert set(s0.role_slices) == {"prefill", "decode"}
        assert snaps[1].role_slices.keys() == {"both"}
        pre, dec = s0.role_slices["prefill"], s0.role_slices["decode"]
        assert pre.n_replicas == 1 and dec.n_replicas == 1
        # the split signals: prefill slice saw TTFT, decode slice tokens
        assert pre.ttft_s > 0.0
        assert dec.tokens_per_s > 0.0 and dec.decode_latency_s > 0.0
        assert pre.tokens_per_s == 0.0       # prefill pool never decoded
        lm = hub.latency_metrics()
        assert lm["ttft_s"] > 0.0 and lm["decode_latency_s"] > 0.0
        assert lm["ttft_s"] > lm["decode_latency_s"]
        c.shutdown()

    arun(scenario())


def _snap(role_slices=None, **kw):
    base = dict(stage=0, t=0.0, n_replicas=2, n_failed=0, queue_total=0,
                queue_per_replica=0.0, throughput=0.0, latency_s=0.0,
                replicas=[], tokens_per_s=0.0, open_sessions=0)
    base.update(kw)
    snap = StageSnapshot(**base)
    if role_slices:
        snap.role_slices.update(role_slices)
    return snap


def test_disaggregated_policy_votes_per_role():
    pol = DisaggregatedStagePolicy(
        prefill=TTFTSLOPolicy(slo_s=0.05, queue_target=4.0),
        decode=TokenRatePolicy(target_tokens_per_s=100.0,
                               migration_aware=True))
    # split stage: prefill pool slow on TTFT, decode pool idle
    snap = _snap(role_slices={
        "prefill": _snap(n_replicas=1, ttft_s=0.2, role="prefill"),
        "decode": _snap(n_replicas=2, tokens_per_s=10.0, role="decode"),
    })
    votes = {d.role: d for d in pol.decide_many(snap)}
    assert votes["prefill"].delta == 1        # TTFT breach -> grow prefill
    assert votes["decode"].delta == -1        # idle -> shrink decode
    # colocated stage falls back to the colocated policy, role-less
    flat = pol.decide_many(_snap(role_slices={
        "both": _snap(n_replicas=2, role="both")}))
    assert len(flat) == 1 and flat[0].role is None
    # ScaleDecision carries role through dataclasses.replace
    assert isinstance(votes["prefill"], ScaleDecision)
    # a mixed stage's 'both' replicas are governed too — by an independent
    # copy of the decode policy, never a shared (stateful) instance
    mixed = _snap(role_slices={
        "prefill": _snap(n_replicas=1, role="prefill"),
        "decode": _snap(n_replicas=1, role="decode",
                        tokens_per_s=500.0),
        "both": _snap(n_replicas=1, role="both", tokens_per_s=500.0),
    })
    mixed_votes = {d.role: d for d in pol.decide_many(mixed)}
    assert mixed_votes["both"].delta > 0
    assert pol.colocated is not pol.decode


def test_hysteresis_preserves_role():
    """The stability wrapper must not strip the pool stamp off a confirmed
    per-role vote — a role-less decision would scale the wrong pool."""
    from repro.control import HysteresisPolicy

    inner = DisaggregatedStagePolicy(
        prefill=TTFTSLOPolicy(slo_s=0.05, queue_target=1.0),
        decode=TokenRatePolicy(target_tokens_per_s=100.0))
    hp = HysteresisPolicy(inner, confirm=2, cooldown_s=0.0)
    snap = _snap(role_slices={
        "prefill": _snap(n_replicas=1, queue_per_replica=9.0,
                         role="prefill"),
        "decode": _snap(n_replicas=1, role="decode"),
    })
    hp.decide(snap)
    confirmed = hp.decide(snap)
    assert confirmed.delta == 1 and confirmed.role == "prefill"


def test_ttft_policy_queue_leads_latency():
    pol = TTFTSLOPolicy(slo_s=1.0, queue_target=2.0)
    up = pol.decide(_snap(n_replicas=1, queue_per_replica=5.0, ttft_s=0.1))
    assert up.delta == 1 and "queue" in up.reason
    down = pol.decide(_snap(n_replicas=3, queue_per_replica=0.0,
                            ttft_s=0.01))
    assert down.delta == -1
    hold_ = pol.decide(_snap(n_replicas=1, queue_per_replica=1.0,
                             ttft_s=0.5))
    assert hold_.hold

"""Checkpointing: save/load round trip, bf16 leaves, latest-step discovery."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_smoke
from repro.models import build_model


def test_roundtrip_simple(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": [jnp.int32(3), jnp.zeros((2, 2))]}}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    out = load_checkpoint(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_roundtrip_model_params(tmp_path):
    cfg = get_smoke("qwen3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 0, params)
    out = load_checkpoint(str(tmp_path), 0, model.abstract_params())
    toks = jnp.zeros((1, 8), jnp.int32)
    l1, _ = model.forward(params, toks)
    l2, _ = model.forward(out, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6)


def test_latest_step_multiple(tmp_path):
    tree = {"x": jnp.zeros(2)}
    for s in (1, 5, 3):
        save_checkpoint(str(tmp_path), s, tree)
    assert latest_step(str(tmp_path)) == 5
    assert latest_step(str(tmp_path / "missing")) is None

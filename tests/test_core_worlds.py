"""World lifecycle: rendezvous, multiple worlds per worker, removal."""
import asyncio

import pytest

from repro.core import Cluster, RendezvousTimeout, WorldStatus


def test_two_worker_rendezvous(arun):
    async def scenario():
        c = Cluster()
        a, b = c.worker("A"), c.worker("B")
        wa, wb = await asyncio.gather(
            a.manager.initialize_world("w1", 0, 2),
            b.manager.initialize_world("w1", 1, 2),
        )
        assert wa.status is WorldStatus.HEALTHY
        assert wb.members == {0: "A", 1: "B"}
        assert wa.rank_of("A") == 0 and wa.rank_of("B") == 1
        c.shutdown()

    arun(scenario())


def test_worker_in_multiple_worlds_with_different_ranks(arun):
    """Paper §4.1: 'a process can be a leader for one world but a worker for
    another' — W1-R0 / W2-R0 style multi-membership."""
    async def scenario():
        c = Cluster()
        leader, w1, w2 = c.worker("L"), c.worker("P1"), c.worker("P2")
        await asyncio.gather(
            leader.manager.initialize_world("w1", 0, 2),
            w1.manager.initialize_world("w1", 1, 2),
            leader.manager.initialize_world("w2", 0, 2),
            w2.manager.initialize_world("w2", 1, 2),
        )
        assert set(leader.manager.healthy_worlds()) == {"w1", "w2"}
        assert leader.manager.worlds["w1"].rank_of("L") == 0
        assert leader.manager.worlds["w2"].rank_of("L") == 0
        assert w1.manager.healthy_worlds() == ["w1"]
        c.shutdown()

    arun(scenario())


def test_rendezvous_timeout(arun):
    async def scenario():
        c = Cluster()
        a = c.worker("A")
        with pytest.raises(RendezvousTimeout):
            await a.manager.initialize_world("lonely", 0, 2, timeout=0.1)
        c.shutdown()

    arun(scenario())


def test_remove_world_leaves_others_alone(arun):
    async def scenario():
        c = Cluster()
        a, b = c.worker("A"), c.worker("B")
        await asyncio.gather(
            a.manager.initialize_world("w1", 0, 2),
            b.manager.initialize_world("w1", 1, 2),
            a.manager.initialize_world("w2", 0, 2),
            b.manager.initialize_world("w2", 1, 2),
        )
        a.manager.remove_world("w1")
        assert a.manager.worlds["w1"].status is WorldStatus.REMOVED
        assert a.manager.worlds["w2"].status is WorldStatus.HEALTHY
        # the store no longer advertises A's membership of w1
        assert c.store.get("world/w1/members/0") is None
        assert c.store.get("world/w2/members/0") == "A"
        c.shutdown()

    arun(scenario())


def test_reinitialize_after_removal(arun):
    """A removed world's name can be reused (fresh fault domain)."""
    async def scenario():
        c = Cluster()
        a, b = c.worker("A"), c.worker("B")
        await asyncio.gather(
            a.manager.initialize_world("w", 0, 2),
            b.manager.initialize_world("w", 1, 2),
        )
        a.manager.remove_world("w")
        b.manager.remove_world("w")
        wa, _ = await asyncio.gather(
            a.manager.initialize_world("w", 0, 2),
            b.manager.initialize_world("w", 1, 2),
        )
        assert wa.status is WorldStatus.HEALTHY
        c.shutdown()

    arun(scenario())

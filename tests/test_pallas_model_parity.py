"""End-to-end: model forward with attn_impl='pallas' == reference path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import build_model

B, S = 2, 64


@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-2b", "mamba2-2.7b"])
def test_pallas_path_matches_reference(arch):
    cfg_ref = get_smoke(arch)
    cfg_pal = cfg_ref.with_(attn_impl="pallas")
    model_ref = build_model(cfg_ref)
    model_pal = build_model(cfg_pal)
    params = model_ref.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg_ref.vocab_size)
    lr, _ = model_ref.forward(params, toks)
    lp, _ = model_pal.forward(params, toks)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lr),
                               rtol=5e-4, atol=5e-4)

"""Two-level (sqrt-N) remat must be numerically identical to per-layer
remat — it only changes what is stored vs recomputed."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import DENSE, BlockGroup, build_model


def test_forward_bitwise_identical_across_policies():
    """Remat changes what is stored vs recomputed, never the forward math:
    outputs must be bitwise equal for no-remat / per-layer / two-level."""
    from repro.models import transformer as tfm

    base = get_smoke("llama3.2-1b").with_(
        num_layers=8, groups=(BlockGroup(DENSE, 8),))
    model = build_model(base)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, base.d_model)) * 0.1
    pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32)[None], (2, 16))
    outs = []
    for cfg in (base.with_(remat=False),
                base.with_(remat=True, remat_policy="per_layer"),
                base.with_(remat=True, remat_policy="two_level",
                           remat_block=4)):
        y, _ = tfm._group_prefill(cfg, base.groups[0], params["groups"][0],
                                  x, pos, mrope=None, shared=None)
        outs.append(np.asarray(y))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_two_level_remat_matches_per_layer():
    """Gradients agree up to f32 recompute-reordering noise: same loss, and
    per-leaf gradients aligned in norm and direction. (Bitwise equality is
    not guaranteed — the VJP recompute schedules differ, reassociating f32
    reductions; the forward IS bitwise equal, see above.)"""
    base = get_smoke("llama3.2-1b").with_(
        num_layers=8, groups=(BlockGroup(DENSE, 8),), remat=True)
    cfg_a = base.with_(remat_policy="per_layer")
    cfg_b = base.with_(remat_policy="two_level", remat_block=4)
    model_a, model_b = build_model(cfg_a), build_model(cfg_b)
    params = model_a.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg_a.vocab_size, (2, 16))),
        "targets": jnp.asarray(rng.integers(0, cfg_a.vocab_size, (2, 16))),
    }

    la, ga = jax.value_and_grad(lambda p: model_a.loss(p, batch)[0])(params)
    lb, gb = jax.value_and_grad(lambda p: model_b.loss(p, batch)[0])(params)
    np.testing.assert_allclose(float(la), float(lb), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        a64 = np.asarray(a, np.float64).ravel()
        b64 = np.asarray(b, np.float64).ravel()
        na, nb = np.linalg.norm(a64), np.linalg.norm(b64)
        if na < 1e-9 and nb < 1e-9:
            continue
        assert abs(na - nb) / max(na, nb) < 1e-2, (na, nb)
        cos = float(a64 @ b64 / (na * nb))
        assert cos > 0.999, cos


def test_two_level_falls_back_when_indivisible():
    """94 % 8 != 0 -> silently uses per-layer; forward must still work."""
    cfg = get_smoke("llama3.2-1b").with_(
        num_layers=6, groups=(BlockGroup(DENSE, 6),), remat=True,
        remat_policy="two_level", remat_block=4)   # 6 % 4 != 0
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 8), jnp.int32)
    logits, _ = model.forward(params, toks)
    assert np.all(np.isfinite(np.asarray(logits)))

"""Training substrate: optimizer math, data pipeline, loss-goes-down."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import build_model
from repro.training import (
    AdamWConfig,
    DataConfig,
    MarkovStream,
    adamw_update,
    cosine_schedule,
    init_opt_state,
    make_stream,
    make_train_step,
)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lr = cosine_schedule(cfg)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.int32(100))) <= 1e-3 * cfg.min_lr_ratio + 1e-9
    # monotone decay after warmup
    vals = [float(lr(jnp.int32(s))) for s in range(10, 101, 10)]
    assert all(a >= b - 1e-12 for a, b in zip(vals, vals[1:]))


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, warmup_steps=0, total_steps=10, grad_clip=1.0,
                      weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    _, _, metrics = adamw_update(cfg, params, {"w": jnp.full(4, 1e6)}, state)
    assert float(metrics["grad_norm"]) > 1e5  # measured pre-clip


def test_markov_stream_is_learnable_structure():
    dc = DataConfig(batch_size=4, seq_len=32, vocab_size=64, seed=0)
    stream = iter(MarkovStream(dc))
    batch = next(stream)
    assert batch["tokens"].shape == (4, 32)
    # targets are tokens shifted by one
    b2 = next(stream)
    assert not np.array_equal(batch["tokens"], b2["tokens"])
    # every transition must come from the successor table
    ms = MarkovStream(dc)
    seq = ms._sequence(100)
    for i in range(100):
        assert seq[i + 1] in ms.successors[seq[i]]


def test_data_shards_differ():
    cfg = get_smoke("llama3.2-1b")
    s0 = next(make_stream(cfg, 2, 16, seed=1, rank=0, num_shards=2))
    s1 = next(make_stream(cfg, 2, 16, seed=1, rank=1, num_shards=2))
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_loss_goes_down_small_model():
    """~30 steps of AdamW on Markov data must beat the initial loss clearly."""
    cfg = get_smoke("llama3.2-1b").with_(vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100,
                          weight_decay=0.01)
    state = init_opt_state(params)
    step = jax.jit(make_train_step(model, opt_cfg))
    stream = make_stream(cfg, 16, 32, seed=0)

    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.85, losses[::5]

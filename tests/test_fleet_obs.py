"""Fleet-scale observability: sketches, digests, SLO burn rates, sampling.

The acceptance bar (ISSUE 8): LogSketch quantiles stay within the
guaranteed relative error on adversarial streams and merging is
order-invariant; StageDigest folding is hierarchical without changing
policy decisions (digest-vs-raw parity); SLO burn-rate alerts fire on
regressions and clear on recovery, never on steady traffic; head sampling
drops boring traces wholesale while tail-keep rules promote every
error/incident/slow-outlier trace; flight-recorder dumps rotate on disk;
Prometheus output is scrape-compliant; workload percentiles never index
out of range.
"""
import math
import os
import random
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.control import (
    ReplicaSample,
    StageSnapshot,
    TailLatencySLOPolicy,
    TargetQueueDepthPolicy,
    TokenRatePolicy,
    TTFTSLOPolicy,
    percentile,
)
from repro.obs import (
    FlightRecorder,
    LogSketch,
    SLOMonitor,
    SLOSpec,
    StageDigest,
    Tracer,
    fold_samples,
)
from repro.obs.export import render_prometheus


# --------------------------------------------------------------- streams
def _streams():
    rng = random.Random(42)
    uniform = [rng.uniform(1e-4, 10.0) for _ in range(5000)]
    lognormal = [rng.lognormvariate(-3.0, 1.2) for _ in range(5000)]
    # adversarial: many duplicates, huge dynamic range, exact-boundary
    # values, a zero-bucket cluster, and a few extreme outliers
    adversarial = ([1e-12] * 50 + [0.001] * 500 + [0.001000001] * 500
                   + [1.0] * 100 + [5e3] * 5
                   + [rng.choice([2e-9, 0.25, 0.5, 123.0])
                      for _ in range(1000)])
    rng.shuffle(adversarial)
    return {"uniform": uniform, "lognormal": lognormal,
            "adversarial": adversarial}


@pytest.mark.parametrize("name", ["uniform", "lognormal", "adversarial"])
def test_sketch_relative_error_bound(name):
    xs = _streams()[name]
    sk = LogSketch(0.01)
    sk.extend(xs)
    xs = sorted(xs)
    for q in (0.01, 0.25, 0.5, 0.9, 0.95, 0.99, 0.999):
        # the sketch reports the bucket holding the element at rank
        # floor(q*(n-1)) — compare against that same exact convention,
        # not an interpolated percentile (interpolation invents values
        # between stream points, where no relative-error bound holds)
        exact = xs[int(q * (len(xs) - 1))]
        est = sk.quantile(q)
        if exact <= sk.min_value:
            assert est <= sk.min_value
            continue
        assert abs(est - exact) <= 0.01 * exact + 1e-12, \
            (name, q, est, exact)


@pytest.mark.parametrize("name", ["uniform", "lognormal", "adversarial"])
def test_sketch_merge_order_invariance(name):
    """merge(a, b) over disjoint shards equals the sketch of the whole
    stream, for ANY association order — bucket counts are integers, so
    the equality is exact, not approximate."""
    xs = _streams()[name]
    whole = LogSketch(0.01)
    whole.extend(xs)
    # three different shard trees over the same stream
    for n_shards in (2, 7, 64):
        shards = [LogSketch(0.01) for _ in range(n_shards)]
        for i, x in enumerate(xs):
            shards[i % n_shards].insert(x)
        left = shards[0].copy()
        for s in shards[1:]:
            left.merge(s)
        right = shards[-1].copy()
        for s in reversed(shards[:-1]):
            right.merge(s)
        for q in (0.5, 0.95, 0.99):
            assert left.quantile(q) == whole.quantile(q) \
                == right.quantile(q), (name, n_shards, q)
        assert left.count == whole.count == len(xs)


def test_sketch_wire_roundtrip_and_merge_guard():
    sk = LogSketch(0.02)
    sk.extend([0.001, 0.5, 2.0, 2.0, 1e4])
    back = LogSketch.from_wire(sk.to_wire())
    for q in (0.0, 0.5, 0.99, 1.0):
        assert back.quantile(q) == sk.quantile(q)
    assert back.count == sk.count and back.sum == sk.sum
    with pytest.raises(ValueError):
        sk.merge(LogSketch(0.01))        # mismatched resolution
    with pytest.raises(ValueError):
        LogSketch(0.0)                    # accuracy out of range


def test_sketch_size_bound_collapses_low_buckets():
    sk = LogSketch(0.001, max_bins=64)
    for i in range(5000):
        sk.insert(1e-6 * (1.01 ** i))
    assert len(sk._buckets) <= 64
    assert sk.collapsed > 0
    # tail quantiles survive the low-bucket collapse at full accuracy
    assert sk.quantile(0.99) > sk.quantile(0.5)


def test_sketch_empty_and_singleton():
    sk = LogSketch()
    assert sk.quantile(0.99) == 0.0 and sk.mean() == 0.0
    sk.insert(0.25)
    assert abs(sk.quantile(0.5) - 0.25) <= 0.01 * 0.25 + 1e-12


# --------------------------------------------------------------- digests
def _mk_samples(n, seed=0, with_sketches=True):
    rng = random.Random(seed)
    out = []
    for i in range(n):
        tsk = dsk = None
        if with_sketches:
            tsk, dsk = LogSketch(), LogSketch()
            for _ in range(8):
                tsk.insert(rng.lognormvariate(-4.0, 0.7))
                dsk.insert(rng.lognormvariate(-5.0, 0.7))
        out.append(ReplicaSample(
            worker_id=f"w{i}", stage=0, alive=True, draining=(i == n - 1),
            queue_depth=rng.randrange(8), inflight=rng.randrange(3),
            processed=rng.randrange(1000),
            throughput=rng.uniform(1, 10), latency_s=rng.uniform(0.01, 0.1),
            tokens_per_s=rng.uniform(50, 500),
            open_sessions=rng.randrange(5), expired=rng.randrange(3),
            role="both", ttft_s=rng.uniform(0.005, 0.05),
            decode_lat_s=rng.uniform(0.001, 0.02),
            ttft_sketch=tsk, decode_sketch=dsk))
    return out


def test_digest_flat_vs_sharded_fold_identical_quantiles():
    samples = _mk_samples(50, seed=3)
    failed = {"w3", "w17"}
    flat = fold_samples(samples, failed, stage=2, t=1.0)
    for shard in (1, 4, 7, 50, 200):
        hier = fold_samples(samples, failed, stage=2, t=1.0, shard=shard)
        assert hier.n_replicas == flat.n_replicas
        assert hier.n_failed == flat.n_failed == 2
        assert hier.queue_total == flat.queue_total
        assert hier.expired == flat.expired
        # sketch quantiles are exactly equal (integer bucket counts);
        # float sums agree to ulp-level tolerance
        assert hier.p95_ttft_s == flat.p95_ttft_s
        assert hier.p99_decode_s == flat.p99_decode_s
        assert hier.throughput == pytest.approx(flat.throughput, rel=1e-12)
        assert hier.ttft_s == pytest.approx(flat.ttft_s, rel=1e-12)


def test_digest_vs_raw_policy_decision_parity():
    """The tentpole invariant: replaying identical samples through the
    flat (raw) fold and the sharded hierarchical fold yields identical
    scaling-decision records on every tick."""
    def snap(d):
        return StageSnapshot(
            stage=d.stage, t=d.t, n_replicas=d.n_replicas,
            n_failed=d.n_failed, queue_total=d.queue_total,
            queue_per_replica=d.queue_per_replica,
            throughput=d.throughput, latency_s=d.latency_s,
            tokens_per_s=d.tokens_per_s, open_sessions=d.open_sessions,
            expired=d.expired, ttft_s=d.ttft_s,
            decode_latency_s=d.decode_latency_s,
            p95_ttft_s=d.p95_ttft_s, p99_decode_s=d.p99_decode_s)

    policies = [TargetQueueDepthPolicy(target=3.0),
                TTFTSLOPolicy(slo_s=0.03),
                TokenRatePolicy(target_tokens_per_s=300.0),
                TailLatencySLOPolicy(ttft_slo_s=0.04, decode_slo_s=0.03)]
    for tick in range(25):
        samples = _mk_samples(40, seed=100 + tick)
        failed = {f"w{i}" for i in range(tick % 5)}
        flat = fold_samples(samples, failed, stage=0, t=float(tick))
        hier = fold_samples(samples, failed, stage=0, t=float(tick),
                            shard=8)
        for pol in policies:
            assert pol.decide(snap(flat)).as_record() \
                == pol.decide(snap(hier)).as_record(), (tick, pol)


def test_digest_wire_roundtrip_and_merge_semantics():
    a = fold_samples(_mk_samples(10, seed=1), stage=0, t=1.0)
    b = fold_samples(_mk_samples(10, seed=2), stage=1, t=2.0)
    back = StageDigest.from_wire(a.to_wire())
    assert back.summary() == a.summary()
    merged = StageDigest().merge(a).merge(b)
    assert merged.stage == -1                 # cross-stage = fleet view
    assert merged.n_samples == a.n_samples + b.n_samples
    assert merged.t == 2.0
    assert merged.ttft_sketch.count == (a.ttft_sketch.count
                                        + b.ttft_sketch.count)


def test_digest_handles_sketchless_samples():
    """obs/ duck-types samples; EWMA-only deployments carry no sketches
    and the digest must degrade to zero tails, not crash."""
    d = fold_samples(_mk_samples(5, with_sketches=False), stage=0, t=0.0)
    assert d.p95_ttft_s == 0.0 and d.p99_decode_s == 0.0
    assert d.n_replicas == 4                  # one sample was draining
    pol = TailLatencySLOPolicy(ttft_slo_s=0.01, decode_slo_s=0.01,
                               min_replicas=1)
    # no tail signal: the policy must hold, not shrink on absent data
    assert pol.decide(StageSnapshot(
        stage=0, t=0.0, n_replicas=4, n_failed=0, queue_total=0,
        queue_per_replica=0.0, throughput=1.0, latency_s=0.01)).hold


# ------------------------------------------------------------------- SLO
def test_slo_burn_rate_fires_and_clears():
    mon = SLOMonitor((SLOSpec("ttft_p99", "ttft", 0.1, objective=0.99),),
                     bucket_s=1.0)
    events = []
    # steady: 0.2% bad -> burn 0.2, quiet
    rng = random.Random(1)
    for t in range(40):
        for _ in range(50):
            mon.observe("ttft", 0.5 if rng.random() < 0.002 else 0.02,
                        float(t))
        events += mon.evaluate(float(t))
    assert not [e for e in events if e["kind"] == "slo_alert"]
    # regression: 60% bad -> burn 60 >> 14.4, both windows
    for t in range(40, 60):
        for _ in range(50):
            mon.observe("ttft", 0.5 if rng.random() < 0.6 else 0.02,
                        float(t))
        events += mon.evaluate(float(t))
    fired = [e for e in events if e["kind"] == "slo_alert"]
    assert fired and mon.firing()
    assert {"slo", "severity", "burn_long", "burn_short"} \
        <= set(fired[0])
    # recovery: the short window clears the alert (run past the ticket
    # policy's 30s short window so every short window is regression-free)
    for t in range(60, 95):
        for _ in range(50):
            mon.observe("ttft", 0.02, float(t))
        events += mon.evaluate(float(t))
    assert [e for e in events if e["kind"] == "slo_clear"]
    assert not mon.firing()
    m = mon.metrics(95.0)
    assert m["ttft_p99_alerts_fired_total"] >= 1
    assert m["ttft_p99_firing"] == 0


def test_slo_spec_validation_and_empty_window():
    with pytest.raises(ValueError):
        SLOSpec("bad", "ttft", 0.1, objective=1.0)
    mon = SLOMonitor((SLOSpec("a", "ttft", 0.1),))
    assert mon.evaluate(0.0) == []            # empty windows: burn 0
    with pytest.raises(ValueError):
        mon.add_spec(SLOSpec("a", "decode", 0.1))   # duplicate name


# -------------------------------------------------------------- sampling
def _close_trace(tr, root, kinds_details):
    for kind, dt, detail in kinds_details:
        ch = tr.begin(root)
        tr.record(ch, kind, 0.0, dt, "", detail)
    tr.record(root, "session", 0.0, 0.1)


def test_head_sampling_drops_boring_traces():
    tr = Tracer(1024, sample_rate=0.0, seed=0)
    for _ in range(20):
        root = tr.begin()
        assert not root.sampled
        _close_trace(tr, root, [("ttft", 0.01, ""),
                                ("decode_step", 0.005, "")])
    assert tr.recorded == 0
    assert tr.sampled_out == 20
    assert len(tr._pending) == 0              # nothing leaks after close


@pytest.mark.parametrize("trigger", [
    ("heal", 0.01, ""),                       # keep-kind span
    ("decode_step", 0.01, "error=boom"),      # error detail
    ("ttft", 0.01, "retry"),                  # RETRY bounce
    ("decode_step", 5.0, ""),                 # slow outlier
])
def test_tail_keep_promotes_interesting_traces(trigger):
    tr = Tracer(1024, sample_rate=0.0, slow_keep_s=1.0, seed=0)
    root = tr.begin()
    _close_trace(tr, root, [("ttft", 0.01, ""), trigger])
    assert tr.tail_kept == 1, trigger
    # the WHOLE tree is promoted, not just the triggering span
    kinds = {s["kind"] for s in tr.spans(root.trace_id)}
    assert "session" in kinds and "ttft" in kinds
    # a late span of the kept trace (post root close) still lands
    late = tr.begin(root)
    tr.record(late, "snapshot", 0.0, 0.01)
    assert "snapshot" in {s["kind"] for s in tr.spans(root.trace_id)}


def test_sampling_rate_and_inheritance():
    tr = Tracer(1 << 14, sample_rate=0.25, seed=7)
    sampled = 0
    for _ in range(2000):
        root = tr.begin()
        child = tr.begin(root)
        assert child.sampled == root.sampled      # verdict inherited
        sampled += root.sampled
    assert 0.18 < sampled / 2000 < 0.32
    # full-rate tracer never consults the rng (hot-path invariant)
    tr2 = Tracer(16, sample_rate=1.0)
    assert all(tr2.begin().sampled for _ in range(10))


def test_pending_buffer_is_bounded():
    tr = Tracer(64, sample_rate=0.0, max_pending_traces=8, pending_cap=4)
    roots = [tr.begin() for _ in range(30)]
    for r in roots:                    # open spans, roots never close
        for _ in range(10):
            ch = tr.begin(r)
            tr.record(ch, "decode_step", 0.0, 0.01)
    assert len(tr._pending) <= 8
    assert all(len(ent[1]) <= 4 for ent in tr._pending.values())
    tr.clear()
    assert not tr._pending and not tr._resolved


# ------------------------------------------------- recorder + exporter
def test_flight_recorder_dump_rotation(tmp_path):
    rec = FlightRecorder(16, dump_dir=str(tmp_path), name="rot",
                         max_dumps=3)
    for i in range(8):
        rec.record("tick", i=i)
        rec.dump(f"reason{i}")
    files = sorted(tmp_path.glob("flightrec_rot_*.json"))
    assert len(files) == 3
    # newest survive: uids 6, 7, 8
    assert [f.name for f in files] == [
        "flightrec_rot_6.json", "flightrec_rot_7.json",
        "flightrec_rot_8.json"]
    assert rec.dumps_rotated == 5
    assert rec.dumps_total == 8


def test_render_prometheus_help_type_and_escaping():
    out = render_prometheus({
        "stage": {"throughput": {'pipe"1\n\\x': 2.5}},
        "obs": {"breaks": 1, "flag": True, "skip": "str"},
    })
    lines = out.splitlines()
    # every emitted metric has HELP before TYPE before samples
    for i, line in enumerate(lines):
        if line.startswith("# TYPE"):
            assert lines[i - 1].startswith("# HELP")
    assert '# HELP repro_stage_throughput' in out
    assert 'id="pipe\\"1\\n\\\\x"' in out
    assert "repro_obs_flag 1" in out           # bools become ints
    assert "skip" not in out                   # non-numerics skipped


# ------------------------------------------------------------ workload
def test_percentile_edge_cases():
    assert math.isnan(percentile([], 50))
    assert percentile([7.0], 0) == percentile([7.0], 100) == 7.0
    assert percentile([1.0, 3.0], 50) == 2.0
    xs = sorted(random.Random(0).uniform(0, 1) for _ in range(101))
    assert percentile(xs, 0) == xs[0]
    assert percentile(xs, 100) == xs[-1]
    assert percentile(xs, 150) == xs[-1]       # clamped, never IndexError
    assert percentile(xs, -5) == xs[0]


def test_openloop_summary_never_raises_on_empty_or_singleton():
    from repro.control import ConstantProfile, OpenLoopGenerator

    gen = OpenLoopGenerator(lambda: None, ConstantProfile(1.0), seed=9)
    s = gen.summary()                          # zero records
    assert math.isnan(s["p99_s"]) and s["seed"] == 9
    gen.records.append(type("R", (), {"latency_s": 0.5, "ok": True})())
    s = gen.summary()                          # singleton
    assert s["p50_s"] == s["p99_s"] == 0.5

"""Store (TCPStore analogue): KV, atomic add, wait, TTL expiry."""
import threading
import time

from repro.core import Store


def test_set_get_delete():
    s = Store()
    s.set("a", 1)
    assert s.get("a") == 1
    assert s.get("missing", "dflt") == "dflt"
    assert s.delete("a") is True
    assert s.delete("a") is False
    assert s.get("a") is None


def test_add_is_atomic_under_threads():
    s = Store()
    n_threads, n_incr = 8, 200

    def worker():
        for _ in range(n_incr):
            s.add("ctr")

    ts = [threading.Thread(target=worker) for _ in range(n_threads)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert s.get("ctr") == n_threads * n_incr


def test_keys_prefix():
    s = Store()
    s.set("world/w1/members/0", "a")
    s.set("world/w1/members/1", "b")
    s.set("world/w2/members/0", "c")
    assert s.keys("world/w1/") == ["world/w1/members/0", "world/w1/members/1"]


def test_ttl_expiry():
    s = Store()
    s.set("hb", time.monotonic(), ttl=0.05)
    assert s.get("hb") is not None
    assert 0 < s.ttl_remaining("hb") <= 0.05
    time.sleep(0.08)
    assert s.get("hb") is None
    assert s.ttl_remaining("hb") is None


def test_ttl_refresh_keeps_key_alive():
    s = Store()
    for _ in range(5):
        s.set("hb", 1, ttl=0.08)
        time.sleep(0.04)
        assert s.get("hb") is not None


def test_wait_success_and_timeout():
    s = Store()

    def later():
        time.sleep(0.05)
        s.set("k1", 1)
        s.set("k2", 2)

    t = threading.Thread(target=later)
    t.start()
    assert s.wait(["k1", "k2"], timeout=2.0) is True
    t.join()
    assert s.wait(["never"], timeout=0.05) is False

"""Paged KV cache: pool mechanics, kernel parity, page-granular state
transfer, and the paged serving path end to end.

The acceptance bar (ISSUE 7): the Pallas paged decode-attention kernel
matches the gather-then-contiguous oracle across page sizes and
occupancies; the PagePool shares prompt-prefix pages across sessions with
refcount/COW discipline and degrades (never crashes) on exhaustion; paged
handoffs and snapshots move strictly fewer bytes than contiguous ones; and
the paged pipeline keeps exact greedy parity with the single engine across
prefill, fused decode, prefill->decode handoff, and kill + page-granular
snapshot restore.
"""
import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.control import MetricsHub
from repro.core import Cluster, FailureKind
from repro.kernels import ops, ref
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import (
    PagedCacheHandle,
    PagePool,
    PipelineServer,
    ServeEngine,
    StageExecutor,
    prefix_chunk_keys,
)
from repro.serving.partition import (
    split_stages,
    stage_init_cache,
    stage_params,
)
from repro.statexfer import (
    apply_paged_delta,
    as_paged_payload,
    materialize_paged,
    paged_payload_delta,
)

CFG = get_smoke("llama3.2-1b").with_(num_layers=2,
                                     groups=(BlockGroup(DENSE, 2),))
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
ENGINE = ServeEngine(MODEL, PARAMS, max_len=64)
SPEC = split_stages(CFG, 1)[0]
SPARAMS = stage_params(CFG, PARAMS, SPEC)


def _shared_prompts(n, *, system=8, tail=4, seed=0):
    rng = np.random.default_rng(seed)
    sys_ids = rng.integers(0, CFG.vocab_size, (1, system))
    return [np.concatenate(
        [sys_ids, rng.integers(0, CFG.vocab_size, (1, tail))], axis=1)
        for _ in range(n)]


async def _wait_open(server, stage, n, timeout=20.0):
    deadline = time.monotonic() + timeout
    while sum(r.open_sessions() for r in server.replicas[stage]) < n:
        assert time.monotonic() < deadline, "sessions never all opened"
        await asyncio.sleep(0.005)


async def _wait_drained(executors, timeout=10.0):
    """FINISH envelopes are fire-and-forget: poll for page release."""
    deadline = time.monotonic() + timeout
    while True:
        used = sum(ex.pool_stats().get("kv_pages_used", 0)
                   for ex in executors)
        if used == 0:
            return
        assert time.monotonic() < deadline, "pool never drained"
        await asyncio.sleep(0.01)


# ------------------------------------------------------------- kernel parity

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("page,h,kv,hd", [
    (8, 4, 4, 32),       # MHA
    (16, 8, 2, 64),      # GQA 4:1
    (8, 4, 1, 64),       # MQA
])
def test_paged_decode_attention_parity(page, h, kv, hd, dtype):
    """Kernel vs gather-to-contiguous oracle, with rows at mixed
    occupancies: partial last page, page-exact, single-page, pool-shared
    pages between rows, and pad table slots pointing at scratch page 0."""
    bsz, n_pages, pool_pages = 4, 4, 10
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (bsz, 1, h, hd), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (pool_pages, page, kv, hd),
                           jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (pool_pages, page, kv, hd),
                           jnp.float32).astype(dtype)
    table = np.zeros((bsz, n_pages), np.int32)
    table[0] = [1, 2, 3, 4]          # partial last page
    table[1] = [1, 2, 5, 6]          # shares pages 1,2 with row 0
    table[2, :2] = [7, 8]            # page-exact, rest scratch
    table[3, :1] = [9]               # single partial page
    lengths = jnp.asarray([4 * page - 3, 4 * page - 3, 2 * page, page // 2],
                          jnp.int32)
    out = ops.paged_decode_attention(q, kp, vp, jnp.asarray(table), lengths)
    want = ref.paged_decode_attention_ref(q, kp, vp, jnp.asarray(table),
                                          lengths)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_paged_decode_attention_softcap():
    bsz, n_pages, pool_pages, page, h, kv, hd = 2, 2, 6, 8, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (bsz, 1, h, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (pool_pages, page, kv, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (pool_pages, page, kv, hd), jnp.float32)
    table = jnp.asarray([[1, 2], [3, 0]], jnp.int32)
    lengths = jnp.asarray([11, 5], jnp.int32)
    out = ops.paged_decode_attention(q, kp, vp, table, lengths, softcap=30.0)
    want = ref.paged_decode_attention_ref(q, kp, vp, table, lengths,
                                          softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- prefix keys

def test_prefix_chunk_keys_chain_diverges_at_edit():
    rng = np.random.default_rng(2)
    x = rng.integers(0, CFG.vocab_size, (1, 24))
    a = prefix_chunk_keys(x, 24, 8)
    assert len(a) == 3 and a == prefix_chunk_keys(x.copy(), 24, 8)
    y = x.copy()
    y[0, 9] = (y[0, 9] + 1) % CFG.vocab_size   # edit inside page 1
    b = prefix_chunk_keys(y, 24, 8)
    assert b[0] == a[0]
    # the chain digest poisons everything downstream of the edit
    assert b[1] != a[1] and b[2] != a[2]
    # page 2's *content* beyond the edit is identical, but its chain differs
    assert b[2][0] == a[2][0] and b[2][1] != a[2][1]


# ------------------------------------------------------------- pool lifecycle

def _rand_cache(seed, max_len=32):
    cache = stage_init_cache(CFG, SPEC, 1, max_len)
    leaves, treedef = jax.tree.flatten(cache)
    rng = np.random.default_rng(seed)
    leaves = [jnp.asarray(rng.normal(size=leaf.shape), leaf.dtype)
              for leaf in leaves]
    return jax.tree.unflatten(treedef, leaves)


def _pool(num_pages=16, max_len=32, page_size=8, **kw):
    return PagePool(CFG, SPEC, max_len=max_len, page_size=page_size,
                    num_pages=num_pages, **kw)


def _seq_take(tree, axes, lo, hi):
    return [np.take(np.asarray(leaf), np.arange(lo, hi), axis=ax)
            for leaf, ax in zip(jax.tree.leaves(tree), axes)]


def test_pool_prefix_sharing_refcount_lifecycle():
    pool = _pool()
    x = _shared_prompts(2, system=16, tail=4, seed=3)
    keys = [prefix_chunk_keys(p, 20, 8) for p in x]
    h1 = pool.install_prefill(_rand_cache(1), 20, keys[0])
    h2 = pool.install_prefill(_rand_cache(2), 20, keys[1])
    s = pool.stats()
    # 2 shared full prefix pages + each session's private partial tail
    assert s["prefix_pages_reused"] == 2
    assert s["kv_pages_used"] == 4 and s["kv_pages_shared"] == 2
    assert h1.pages[:2] == h2.pages[:2] and h1.pages[2] != h2.pages[2]
    # the shared prefix reads back identically through either table
    np.testing.assert_array_equal(
        np.concatenate([leaf.ravel() for leaf in
                        _seq_take(pool.materialize(h1), pool.axes, 0, 16)]),
        np.concatenate([leaf.ravel() for leaf in
                        _seq_take(pool.materialize(h2), pool.axes, 0, 16)]))
    pool.release(h1)
    assert pool.stats()["kv_pages_used"] == 3   # shared pages survive h1
    pool.release(h2)
    s = pool.stats()
    assert s["kv_pages_used"] == 0 and s["paged_sessions"] == 0
    # trie fully pruned: a fresh same-prefix install re-stores the pages
    h3 = pool.install_prefill(_rand_cache(3), 20, keys[0])
    assert pool.stats()["prefix_pages_reused"] == 2    # unchanged counter
    pool.release(h3)


def test_pool_fork_copy_on_write_isolation():
    pool = _pool()
    x = _shared_prompts(1, system=16, tail=4, seed=4)[0]
    h1 = pool.install_prefill(_rand_cache(5), 20, prefix_chunk_keys(x, 20, 8))
    h2 = pool.fork(h1)
    assert h2.pages == h1.pages
    before = _seq_take(pool.materialize(h1), pool.axes, 16, 20)
    assert pool.prepare_write(h2, 20)      # first diverging write on h2
    assert pool.cow_splits == 1
    assert h2.pages[2] != h1.pages[2] and h2.pages[:2] == h1.pages[:2]
    # scribble over h2's private copy; h1 must not see it
    idx = jnp.asarray([h2.pages[2]])
    pool.leaves[0] = pool.leaves[0].at[idx].set(1.0)
    after = _seq_take(pool.materialize(h1), pool.axes, 16, 20)
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b, a)
    pool.release(h1)
    pool.release(h2)
    assert pool.stats()["kv_pages_used"] == 0


def test_pool_exhaustion_degrades_with_flight_event():
    events = []
    # minimum clamp: pages_per_seq + 2 physical = 5 usable
    pool = _pool(num_pages=0, on_event=lambda k, **f: events.append((k, f)))
    rng = np.random.default_rng(6)
    xs = [rng.integers(0, CFG.vocab_size, (1, 32)) for _ in range(2)]
    h1 = pool.install_prefill(_rand_cache(7), 32, prefix_chunk_keys(xs[0], 32, 8))
    assert h1 is not None and len(h1.pages) == 4
    h2 = pool.install_prefill(_rand_cache(8), 32, prefix_chunk_keys(xs[1], 32, 8))
    assert h2 is None                     # 1 page free < 4 needed: degrade
    assert pool.stats()["page_alloc_failures"] == 1
    assert [k for k, _ in events] == ["page_alloc_failure"]
    assert events[0][1]["where"] == "prefill"
    # the failed install must have rolled its partial allocation back
    assert pool.stats()["kv_pages_used"] == 4
    pool.release(h1)
    assert pool.stats()["kv_pages_free"] == pool.stats()["kv_pages_total"]


# ---------------------------------------------------- page-granular transfer

def test_paged_payload_roundtrip_and_delta_merge():
    pool = _pool()
    x = _shared_prompts(1, system=16, tail=4, seed=9)[0]
    h = pool.install_prefill(_rand_cache(10), 20, prefix_chunk_keys(x, 20, 8))
    base = as_paged_payload(h.freeze())
    assert base.nbytes < pool.pages_per_seq * pool.page_nbytes  # < max_len
    # materialized payload == pool view on every written position
    mat = materialize_paged(base)
    for got, want in zip(_seq_take(mat, pool.axes, 0, 20),
                         _seq_take(pool.materialize(h), pool.axes, 0, 20)):
        np.testing.assert_array_equal(got, want)
    # simulate decode dirtying the tail page + one fresh page
    assert pool.prepare_write(h, 20) and pool.prepare_write(h, 24)
    h.length = 25
    full = as_paged_payload(h.freeze())
    delta = paged_payload_delta(full, base_step=19, step=24)
    assert delta.logical == [2, 3]        # dirty pages only
    assert delta.nbytes < full.nbytes
    merged = apply_paged_delta(base, delta)
    assert merged.logical == full.logical and merged.length == full.length
    for a, b in zip(merged.pages, full.pages):
        np.testing.assert_array_equal(a, b)
    pool.release(h)


def test_install_payload_reshares_prefix_across_pools():
    src = _pool()
    xs = _shared_prompts(2, system=16, tail=4, seed=11)
    hs = [src.install_prefill(_rand_cache(12 + i), 20,
                              prefix_chunk_keys(x, 20, 8))
          for i, x in enumerate(xs)]
    dst = _pool()
    d1 = dst.install_payload(as_paged_payload(hs[0].freeze()))
    d2 = dst.install_payload(as_paged_payload(hs[1].freeze()))
    assert d1 is not None and d2 is not None
    # the handed-off sessions share the prefix in the *destination* pool too
    assert d1.pages[:2] == d2.pages[:2]
    assert dst.stats()["prefix_pages_reused"] == 2
    for got, want in zip(_seq_take(dst.materialize(d2), dst.axes, 0, 20),
                         _seq_take(src.materialize(hs[1]), src.axes, 0, 20)):
        np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------- executor paging

def test_executor_paged_greedy_parity_with_engine():
    """Paged prefill + fused paged decode == single-engine greedy tokens,
    across sessions sharing a prompt prefix (width>1 convoy)."""
    ex = StageExecutor(CFG, SPEC, SPARAMS, max_len=64, paged=True,
                       page_size=8)
    ps = _shared_prompts(3, system=8, tail=4, seed=13)
    wants = [np.asarray(ENGINE.generate(p, 5)).ravel() for p in ps]
    handles, toks, ts = [], [], []
    for p in ps:
        out, cache = ex.prefill(jnp.asarray(p))
        assert isinstance(cache, PagedCacheHandle)
        handles.append(cache)
        toks.append(np.asarray(out)[:, -1].argmax(-1)
                    .astype(np.int32).reshape(1, 1))
        ts.append(p.shape[1])
    got = [[int(t[0, 0])] for t in toks]
    for _ in range(4):
        res = ex.decode_many(handles, [jnp.asarray(t) for t in toks], ts)
        for i, (out, cache) in enumerate(res):
            handles[i] = cache
            toks[i] = np.asarray(out).argmax(-1) \
                .astype(np.int32).reshape(1, 1)
            ts[i] += 1
            got[i].append(int(toks[i][0, 0]))
    for want, g in zip(wants, got):
        np.testing.assert_array_equal(want, np.asarray(g))
    assert ex.stats["paged_decode_batches"] > 0
    assert ex.stats["paged_degrades"] == 0
    assert ex.pool_stats()["prefix_pages_reused"] == 2   # 8-token prefix
    for h in handles:
        ex.release_cache(h)
    assert ex.pool_stats()["kv_pages_used"] == 0


def test_pad_slot_donor_is_zeros_and_cached():
    """Convoy pad lanes ride an all-zeros donor cache, built once per leaf
    signature — not a replicated copy of session 0's cache."""
    ex = StageExecutor(CFG, SPEC, SPARAMS, max_len=64)
    like = _rand_cache(14, max_len=64)
    donor = ex._pad_cache(like)
    for leaf in jax.tree.leaves(donor):
        assert not np.any(np.asarray(leaf))
    assert ex._pad_cache(_rand_cache(15, max_len=64)) is donor


# ------------------------------------------------------------- paged pipeline

def test_pipeline_paged_colocated_parity_and_metrics(arun):
    """Greedy parity through the paged pipeline, pool drain after FINISH,
    and the kvpool group in the Prometheus export."""
    async def scenario():
        cluster = Cluster()
        server = PipelineServer(cluster, MODEL, PARAMS, [1, 2], max_len=64,
                                paged=True, page_size=8)
        await server.start()
        ps = _shared_prompts(3, system=8, tail=4, seed=16)
        wants = [ENGINE.generate(p, 6) for p in ps]
        outs = await asyncio.gather(
            *(server.generate(p, 6, step_timeout=120.0) for p in ps))
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(want, got)
        execs = {id(r.executor): r.executor
                 for stage in server.replicas for r in stage}
        assert any(ex.stats["paged_decode_batches"] > 0
                   for ex in execs.values())
        assert all(ex.stats["paged_degrades"] == 0 for ex in execs.values())
        text = MetricsHub(server).export_prometheus()
        assert "kv_pages_total" in text and "cow_splits_total" in text
        await _wait_drained(execs.values())
        cluster.shutdown()

    arun(scenario(), timeout=300.0)


def test_pipeline_paged_handoff_smaller_and_parity(arun):
    """Split prefill/decode pools in both modes: exact parity across the
    handoff, and the paged handoff moves strictly fewer bytes."""
    async def one(paged):
        cluster = Cluster()
        server = PipelineServer(cluster, MODEL, PARAMS,
                                [{"prefill": 1, "decode": 1}], max_len=64,
                                paged=paged, page_size=8)
        await server.start()
        ps = _shared_prompts(2, system=8, tail=4, seed=17)
        wants = [ENGINE.generate(p, 4) for p in ps]
        outs = await asyncio.gather(
            *(server.generate(p, 4, step_timeout=120.0) for p in ps))
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(want, got)
        m = server.migrations.stats()
        assert m["handoffs_total"] >= 2 and m["handoff_failures"] == 0
        cluster.shutdown()
        return m["handoff_bytes_total"] / m["handoffs_total"]

    async def scenario():
        paged_bytes = await one(True)
        contig_bytes = await one(False)
        assert paged_bytes < contig_bytes, (paged_bytes, contig_bytes)

    arun(scenario(), timeout=300.0)


def test_pipeline_paged_kill_restores_from_page_snapshots(arun):
    """Unplanned kill in paged mode: sessions restore from page-granular
    snapshots into the survivor's pool and finish token-exact."""
    async def scenario():
        cluster = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        server = PipelineServer(cluster, MODEL, PARAMS, [1, 2], max_len=64,
                                paged=True, page_size=8,
                                snapshot_interval_s=0.05)
        await server.start()
        ps = _shared_prompts(3, system=8, tail=4, seed=18)
        for _ in range(2):      # warm both compile paths off-clock
            await asyncio.gather(*(server.generate(p, 3, step_timeout=120.0)
                                   for p in ps))
        wants = [ENGINE.generate(p, 16) for p in ps]
        tasks = [asyncio.ensure_future(
            server.generate(p, 16, step_timeout=3.0)) for p in ps]
        await _wait_open(server, 1, len(ps))
        await server.snapshots.sweep()
        victim = max((r for r in server.replicas[1] if r.worker.alive),
                     key=lambda r: r.open_sessions())
        cluster.kill(victim.worker_id, FailureKind.SILENT_HANG)
        outs = await asyncio.gather(*tasks)
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(want, got)
        assert server.migrations.stats()["restores_total"] >= 1
        cluster.shutdown()

    arun(scenario(), timeout=300.0)

"""The paper's 8 collective operations, plus ordering and non-blocking props."""
import asyncio

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Cluster


async def make_world(c: Cluster, name: str, workers: list[str]):
    await asyncio.gather(*[
        c.worker(w).manager.initialize_world(name, r, len(workers))
        for r, w in enumerate(workers)
    ])


def t(v):
    return jnp.asarray(v, dtype=jnp.float32)


def test_send_recv(arun):
    async def scenario():
        c = Cluster()
        await make_world(c, "w", ["A", "B"])
        x = t([1.0, 2.0, 3.0])

        async def sender():
            await c.worker("A").comm.send(x, dst=1, world_name="w")

        async def receiver():
            return await c.worker("B").comm.recv(src=0, world_name="w")

        _, got = await asyncio.gather(sender(), receiver())
        np.testing.assert_allclose(got, x)
        c.shutdown()

    arun(scenario())


def test_p2p_fifo_ordering(arun):
    async def scenario():
        c = Cluster()
        await make_world(c, "w", ["A", "B"])
        for i in range(20):
            await c.worker("A").comm.send(t([float(i)]), 1, "w")
        got = [float((await c.worker("B").comm.recv(0, "w"))[0]) for _ in range(20)]
        assert got == [float(i) for i in range(20)]
        c.shutdown()

    arun(scenario())


@pytest.mark.parametrize("op,expect", [
    ("sum", 0 + 1 + 2), ("prod", 0), ("max", 2), ("min", 0),
])
def test_all_reduce_ops(arun, op, expect):
    async def scenario():
        c = Cluster()
        ws = ["A", "B", "C"]
        await make_world(c, "w", ws)
        outs = await asyncio.gather(*[
            c.worker(w).comm.all_reduce(t([float(r)]), "w", op=op)
            for r, w in enumerate(ws)
        ])
        for o in outs:
            np.testing.assert_allclose(o, [float(expect)])
        c.shutdown()

    arun(scenario())


def test_broadcast(arun):
    async def scenario():
        c = Cluster()
        ws = ["A", "B", "C"]
        await make_world(c, "w", ws)
        payload = t([7.0, 8.0])
        outs = await asyncio.gather(
            c.worker("A").comm.broadcast(payload, 0, "w"),
            c.worker("B").comm.broadcast(None, 0, "w"),
            c.worker("C").comm.broadcast(None, 0, "w"),
        )
        for o in outs:
            np.testing.assert_allclose(o, payload)
        c.shutdown()

    arun(scenario())


def test_reduce_only_root_gets_result(arun):
    async def scenario():
        c = Cluster()
        ws = ["A", "B", "C"]
        await make_world(c, "w", ws)
        outs = await asyncio.gather(*[
            c.worker(w).comm.reduce(t([1.0]), root=1, world_name="w")
            for r, w in enumerate(ws)
        ])
        np.testing.assert_allclose(outs[1], [3.0])  # root accumulated
        c.shutdown()

    arun(scenario())


def test_gather_and_all_gather(arun):
    async def scenario():
        c = Cluster()
        ws = ["A", "B", "C"]
        await make_world(c, "w", ws)
        gathered = await asyncio.gather(*[
            c.worker(w).comm.gather(t([float(r)]), root=0, world_name="w")
            for r, w in enumerate(ws)
        ])
        assert gathered[1] is None and gathered[2] is None
        np.testing.assert_allclose(jnp.concatenate(gathered[0]), [0.0, 1.0, 2.0])

        all_g = await asyncio.gather(*[
            c.worker(w).comm.all_gather(t([float(r) * 10]), "w")
            for r, w in enumerate(ws)
        ])
        for lst in all_g:
            np.testing.assert_allclose(jnp.concatenate(lst), [0.0, 10.0, 20.0])
        c.shutdown()

    arun(scenario())


def test_scatter(arun):
    async def scenario():
        c = Cluster()
        ws = ["A", "B", "C"]
        await make_world(c, "w", ws)
        chunks = [t([float(i)]) for i in range(3)]
        outs = await asyncio.gather(
            c.worker("A").comm.scatter(chunks, 0, "w"),
            c.worker("B").comm.scatter(None, 0, "w"),
            c.worker("C").comm.scatter(None, 0, "w"),
        )
        for i, o in enumerate(outs):
            np.testing.assert_allclose(o, [float(i)])
        c.shutdown()

    arun(scenario())


def test_nonblocking_interleave_rhombus(arun):
    """Fig. 2 deadlock-freedom: P4 receives from P2 and P3 in arbitrary order.

    P4 posts recv(P2-world) *first* but P3's tensor arrives first; the pending
    recv must not block the other world's recv (async + busy-wait polling)."""
    async def scenario():
        c = Cluster()
        await make_world(c, "e24", ["P2", "P4"])
        await make_world(c, "e34", ["P3", "P4"])
        p4 = c.worker("P4").comm
        order = []

        async def recv_from(world, tag):
            got = await p4.recv(0, world)
            order.append((tag, float(got[0])))
            return got

        r2 = asyncio.ensure_future(recv_from("e24", "p2"))
        r3 = asyncio.ensure_future(recv_from("e34", "p3"))
        await asyncio.sleep(0.01)  # both recvs pending now
        await c.worker("P3").comm.send(t([3.0]), 1, "e34")
        await asyncio.sleep(0.01)
        await c.worker("P2").comm.send(t([2.0]), 1, "e24")
        await asyncio.gather(r2, r3)
        assert order[0] == ("p3", 3.0), "late sender must not deadlock early recv"
        c.shutdown()

    arun(scenario())


def test_recv_timeout(arun):
    async def scenario():
        c = Cluster()
        await make_world(c, "w", ["A", "B"])
        with pytest.raises(TimeoutError):
            await c.worker("B").comm.recv(0, "w", timeout=0.05)
        c.shutdown()

    arun(scenario())


def test_big_tensor_roundtrip_multiple_dtypes(arun):
    async def scenario():
        c = Cluster()
        await make_world(c, "w", ["A", "B"])
        for dtype in (jnp.float32, jnp.bfloat16, jnp.int32):
            x = jnp.arange(1 << 12, dtype=dtype).reshape(64, 64)
            await c.worker("A").comm.send(x, 1, "w")
            got = await c.worker("B").comm.recv(0, "w")
            assert got.dtype == dtype
            np.testing.assert_allclose(np.asarray(got, np.float64),
                                       np.asarray(x, np.float64))
        c.shutdown()

    arun(scenario())

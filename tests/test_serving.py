"""Serving substrate: engine generate, stage partitioning, pipeline e2e
with fault tolerance + online scaling (paper Fig. 2 with a real model)."""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import Cluster, FailureKind
from repro.models import build_model
from repro.serving import (
    PipelineServer,
    ReplicaRouter,
    ServeEngine,
    split_stages,
    stage_forward,
    stage_params,
)

from repro.models import DENSE, BlockGroup

# 4 layers so 3-stage pipelines have enough scan units to split
CFG = get_smoke("llama3.2-1b").with_(num_layers=4,
                                     groups=(BlockGroup(DENSE, 4),))
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))


# ------------------------------------------------------------------- engine

def test_engine_generate_deterministic():
    eng = ServeEngine(MODEL, PARAMS, max_len=48, temperature=0.0)
    prompts = np.random.default_rng(0).integers(0, CFG.vocab_size, (2, 8))
    out1 = eng.generate(prompts, 8)
    out2 = eng.generate(prompts, 8)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(out1, out2)


def test_engine_prefill_cache_matches_stepwise():
    """generate() with prefill cache == pure decode_step replay."""
    eng = ServeEngine(MODEL, PARAMS, max_len=32, temperature=0.0)
    prompts = np.random.default_rng(1).integers(0, CFG.vocab_size, (1, 6))
    out = eng.generate(prompts, 4)

    # replay with decode_step from scratch
    cache = MODEL.init_cache(1, 32, jnp.float32)
    toks = jnp.asarray(prompts, jnp.int32)
    for t in range(6):
        logits, cache = MODEL.decode_step(PARAMS, cache, toks[:, t:t + 1],
                                          jnp.int32(t))
    want = [int(jnp.argmax(logits[0]))]
    for t in range(6, 9):
        nxt = jnp.asarray([[want[-1]]], jnp.int32)
        logits, cache = MODEL.decode_step(PARAMS, cache, nxt, jnp.int32(t))
        want.append(int(jnp.argmax(logits[0])))
    np.testing.assert_array_equal(out[0], np.asarray(want))


# ------------------------------------------------------------------ partition

@pytest.mark.parametrize("n_stages", [1, 2, 3])
def test_stage_partition_matches_monolith(n_stages):
    toks = jnp.asarray(
        np.random.default_rng(2).integers(0, CFG.vocab_size, (2, 16)))
    want, _ = MODEL.forward(PARAMS, toks)
    specs = split_stages(CFG, n_stages)
    x = toks
    for spec in specs:
        sp = stage_params(CFG, PARAMS, spec)
        x = stage_forward(CFG, spec, sp, x, tokens_in=spec.first)
    np.testing.assert_allclose(np.asarray(x), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_stage_partition_hybrid_arch():
    cfg = get_smoke("zamba2-2.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    toks = jnp.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab_size, (1, 16)))
    want, _ = model.forward(params, toks)
    specs = split_stages(cfg, 2)
    x = toks
    for spec in specs:
        sp = stage_params(cfg, params, spec)
        x = stage_forward(cfg, spec, sp, x, tokens_in=spec.first)
    np.testing.assert_allclose(np.asarray(x), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_router_rotation_and_health():
    r = ReplicaRouter(["a", "b", "c"])
    picks = [r.pick() for _ in range(6)]
    assert picks == ["a", "b", "c", "a", "b", "c"]
    r.mark_broken("b")
    assert set(r.pick() for _ in range(4)) == {"a", "c"}
    r.add("d")
    assert "d" in r.healthy()
    with pytest.raises(RuntimeError):
        for w in list(r.healthy()):
            r.mark_broken(w)
        r.pick()


# ------------------------------------------------------------------ pipeline

def _tokens(batch=1, seq=12, seed=0):
    return np.random.default_rng(seed).integers(0, CFG.vocab_size,
                                                (batch, seq))


def test_pipeline_end_to_end(arun):
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 2, 1])
        await server.start()
        toks = _tokens()
        want, _ = MODEL.forward(PARAMS, jnp.asarray(toks))
        got = await server.submit(toks)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        # middle-stage replicas share load over repeated requests
        for _ in range(5):
            await server.submit(toks)
        counts = [r.processed for r in server.replicas[1]]
        assert sum(counts) == 6 and min(counts) >= 1
        c.shutdown()

    arun(scenario())


def test_pipeline_survives_replica_death(arun):
    """Fig. 2b: kill one replica of the replicated stage; serving continues."""
    async def scenario():
        c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        server = PipelineServer(c, MODEL, PARAMS, [1, 2, 1])
        await server.start()
        toks = _tokens(seed=4)
        want, _ = MODEL.forward(PARAMS, jnp.asarray(toks))
        await server.submit(toks)

        victim = server.replicas[1][0]
        c.kill(victim.worker_id, FailureKind.SILENT_HANG)
        await asyncio.sleep(0.3)   # watchdogs fence the broken worlds

        for seed in range(3):      # requests keep succeeding
            got = await server.submit(_tokens(seed=4), timeout=5.0)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-5)
        survivor = server.replicas[1][1]
        assert survivor.processed >= 3
        c.shutdown()

    arun(scenario())


def test_pipeline_online_scale_out(arun):
    """Fig. 2c: add a replica to a live pipeline; it absorbs traffic."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 1, 1])
        await server.start()
        toks = _tokens(seed=5)
        await server.submit(toks)

        new_id = await server.add_replica(1)
        assert new_id in server.healthy_replicas(1)
        for _ in range(6):
            await server.submit(toks)
        counts = {r.worker_id: r.processed for r in server.replicas[1]}
        assert counts[new_id] >= 2, counts
        c.shutdown()

    arun(scenario())


def test_pipeline_fail_then_online_replace(arun):
    """Full cycle: death -> degraded serving -> online replacement -> healthy."""
    async def scenario():
        c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        server = PipelineServer(c, MODEL, PARAMS, [1, 2, 1])
        await server.start()
        toks = _tokens(seed=6)
        want, _ = MODEL.forward(PARAMS, jnp.asarray(toks))

        c.kill(server.replicas[1][0].worker_id, FailureKind.SILENT_HANG)
        await asyncio.sleep(0.3)
        got = await server.submit(toks, timeout=5.0)   # degraded but alive
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

        new_id = await server.add_replica(1)           # heal
        for _ in range(4):
            await server.submit(toks)
        counts = {r.worker_id: r.processed for r in server.replicas[1]
                  if r.worker.alive}
        assert counts.get(new_id, 0) >= 1, counts
        c.shutdown()

    arun(scenario())

"""Elastic control plane: policies, healing, drain-and-remove, plus
regressions for the empty-router park fix and the store-key leak fix."""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.control import (
    ElasticController,
    HysteresisPolicy,
    LatencySLOPolicy,
    MetricsHub,
    ScaleDecision,
    StageSnapshot,
    TargetQueueDepthPolicy,
)
from repro.core import Cluster, FailureKind
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import PipelineServer, ReplicaRouter

CFG = get_smoke("llama3.2-1b").with_(num_layers=2,
                                     groups=(BlockGroup(DENSE, 2),))
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))


def _snap(stage=0, n=1, queue_total=0, queue_per_replica=0.0,
          latency_s=0.0, throughput=0.0):
    return StageSnapshot(stage=stage, t=0.0, n_replicas=n, n_failed=0,
                         queue_total=queue_total,
                         queue_per_replica=queue_per_replica,
                         throughput=throughput, latency_s=latency_s)


def _tokens(seed=0):
    return np.random.default_rng(seed).integers(0, CFG.vocab_size, (1, 12))


# ------------------------------------------------------------------ policies

def test_target_queue_policy_up_down_hold():
    p = TargetQueueDepthPolicy(target=4.0, scale_down_at=0.5,
                               min_replicas=1, max_replicas=8)
    up = p.decide(_snap(n=1, queue_total=12, queue_per_replica=12.0))
    assert up.delta == 2   # ceil(12/4) = 3 desired
    hold = p.decide(_snap(n=2, queue_total=4, queue_per_replica=2.0))
    assert hold.delta == 0
    down = p.decide(_snap(n=2, queue_total=0, queue_per_replica=0.1))
    assert down.delta == -1
    floor = p.decide(_snap(n=1, queue_total=0, queue_per_replica=0.0))
    assert floor.delta == 0   # never below min_replicas


def test_target_queue_policy_respects_max():
    p = TargetQueueDepthPolicy(target=1.0, max_replicas=3)
    d = p.decide(_snap(n=2, queue_total=50, queue_per_replica=25.0))
    assert d.delta == 1   # desired clamped to max_replicas=3


def test_latency_slo_policy():
    p = LatencySLOPolicy(slo_s=0.1, shrink_frac=0.3, max_replicas=4)
    assert p.decide(_snap(n=1, latency_s=0.25)).delta == 1
    # low latency alone is not enough to shrink — queue must be idle too
    busy = _snap(n=2, latency_s=0.01, queue_per_replica=3.0)
    assert p.decide(busy).delta == 0
    idle = _snap(n=2, latency_s=0.01, queue_per_replica=0.0)
    assert p.decide(idle).delta == -1


def test_hysteresis_confirmation_and_cooldown():
    clock = [0.0]

    class AlwaysUp:
        def decide(self, snap):
            return ScaleDecision(snap.stage, 1, "up")

    p = HysteresisPolicy(AlwaysUp(), confirm=3, cooldown_s=5.0,
                         clock=lambda: clock[0])
    s = _snap()
    assert p.decide(s).delta == 0      # vote 1/3
    assert p.decide(s).delta == 0      # vote 2/3
    assert p.decide(s).delta == 1      # confirmed
    clock[0] = 1.0
    for _ in range(4):                 # cooldown blocks even confirmed votes
        assert p.decide(s).delta == 0
    clock[0] = 6.0
    # demand persisted through cooldown, so action fires on expiry
    assert p.decide(s).delta == 1
    assert p.decide(s).delta == 0      # streak reset + fresh cooldown


def test_hysteresis_direction_flip_resets_streak():
    votes = [1, -1, 1, 1, 1]

    class Scripted:
        def decide(self, snap):
            return ScaleDecision(snap.stage, votes.pop(0), "v")

    p = HysteresisPolicy(Scripted(), confirm=2, cooldown_s=0.0)
    s = _snap()
    got = [p.decide(s).delta for _ in range(5)]
    # flips reset the streak; the action at vote 4 resets it again, so the
    # fifth +1 vote is only 1/2 confirmed
    assert got == [0, 0, 0, 1, 0]


# ------------------------------------------------- router empty-safe (regression)

def test_router_try_pick_and_wait(arun):
    async def scenario():
        r = ReplicaRouter(["a"])
        r.mark_broken("a")
        assert r.try_pick() is None
        with pytest.raises(RuntimeError):
            r.pick()

        async def waiter():
            await r.wait_healthy()
            return r.try_pick()

        task = asyncio.ensure_future(waiter())
        await asyncio.sleep(0.01)
        assert not task.done()     # parked, not crashed
        r.add("b")
        assert await asyncio.wait_for(task, 1.0) == "b"

    arun(scenario())


def test_router_least_loaded():
    r = ReplicaRouter(["a", "b"])
    loads = {"a": 5.0, "b": 1.0}
    r.set_load_probe(lambda w: loads[w])
    assert r.pick_least_loaded() == "b"
    loads["b"] = 9.0
    assert r.pick_least_loaded() == "a"


def test_replica_parks_payload_until_world_added(arun):
    """A replica whose entire downstream rotation broke must hold the
    in-flight payload and deliver it once a replacement world appears
    (previously: RuntimeError killed the serve loop and dropped the
    request)."""
    async def scenario():
        c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        server = PipelineServer(c, MODEL, PARAMS, [1, 1])
        await server.start()
        toks = _tokens(seed=1)
        want, _ = MODEL.forward(PARAMS, jnp.asarray(toks))
        await server.submit(toks)                      # warm compile

        c.kill(server.replicas[1][0].worker_id, FailureKind.SILENT_HANG)
        await asyncio.sleep(0.3)                       # watchdog fences

        # the request reaches stage 0, computes, then has nowhere to go
        req = asyncio.ensure_future(server.submit(toks, timeout=10.0))
        await asyncio.sleep(0.3)
        stage0 = server.replicas[0][0]
        assert not stage0._run_task.done()             # serve loop survived
        assert stage0.parked >= 1

        await server.add_replica(1)                    # manual heal
        got = await asyncio.wait_for(req, 10.0)        # parked payload lands
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        c.shutdown()

    arun(scenario())


# ------------------------------------------------- store-key leak (regression)

def test_remove_world_leaves_no_store_keys(arun):
    async def scenario():
        c = Cluster()
        a, b = c.worker("a"), c.worker("b")
        await asyncio.gather(a.manager.initialize_world("w", 0, 2),
                             b.manager.initialize_world("w", 1, 2))
        assert c.store.keys("world/w")
        a.manager.remove_world("w")
        b.manager.remove_world("w")
        assert c.store.keys("world/w") == []   # config + member keys purged
        c.shutdown()

    arun(scenario())


def test_remove_broken_world_purges_dead_peer_keys(arun):
    async def scenario():
        c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        a, b = c.worker("a"), c.worker("b")
        await asyncio.gather(a.manager.initialize_world("w", 0, 2),
                            b.manager.initialize_world("w", 1, 2))
        c.kill("b", FailureKind.SILENT_HANG)
        await asyncio.sleep(0.3)               # a's watchdog fences w
        assert not a.manager.worlds["w"].healthy
        a.manager.remove_world("w")            # survivor cleans up for both
        assert c.store.keys("world/w") == []
        c.shutdown()

    arun(scenario())


def test_remove_world_purge_spares_prefix_sibling(arun):
    """Purging world "w" must not touch world "w2" — world names are
    routinely string-prefixes of each other (replica uid 1 vs 10)."""
    async def scenario():
        c = Cluster()
        a, b = c.worker("a"), c.worker("b")
        await asyncio.gather(a.manager.initialize_world("w", 0, 2),
                             b.manager.initialize_world("w", 1, 2),
                             a.manager.initialize_world("w2", 0, 2),
                             b.manager.initialize_world("w2", 1, 2))
        a.manager.remove_world("w")
        b.manager.remove_world("w")
        assert c.store.keys("world/w/") == []
        assert c.store.keys("world/w2/")       # sibling untouched
        assert a.manager.worlds["w2"].healthy
        c.shutdown()

    arun(scenario())


def test_pipeline_drain_leaves_no_world_keys(arun):
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 2])
        await server.start()
        await server.submit(_tokens())
        victim = server.replicas[1][0].worker_id
        n_keys_before = len(c.store.keys("world/"))
        await server.remove_replica(1, victim)
        # every key of the removed replica's worlds is gone
        assert not [k for k in c.store.keys("world/") if victim in k]
        assert len(c.store.keys("world/")) < n_keys_before
        c.shutdown()

    arun(scenario())


# ------------------------------------------------------------- drain-and-remove

def test_drain_and_remove_zero_loss(arun):
    """Scale down a replicated stage while a burst of requests is in flight:
    every request must complete correctly."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 2])
        await server.start()
        toks = _tokens(seed=7)
        want, _ = MODEL.forward(PARAMS, jnp.asarray(toks))
        await server.submit(toks)                      # warm compile

        reqs = [asyncio.ensure_future(server.submit(toks, timeout=15.0))
                for _ in range(10)]
        await asyncio.sleep(0.01)                      # let some dispatch
        removed = await server.remove_replica(1)       # least-loaded victim
        results = await asyncio.gather(*reqs)
        for got in results:                            # zero in-flight losses
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-5)
        assert len(server.replicas[1]) == 1
        assert removed not in server.healthy_replicas(1)
        # survivor still serves
        await server.submit(toks)
        c.shutdown()

    arun(scenario())


def test_remove_replica_refuses_last_healthy(arun):
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 1])
        await server.start()
        with pytest.raises(RuntimeError):
            await server.remove_replica(1)
        c.shutdown()

    arun(scenario())


# ------------------------------------------------------------------ controller

def test_controller_heals_killed_replica(arun):
    """Fig. 2c closed-loop: the watchdog fences a killed replica's worlds and
    the controller replaces it without operator involvement."""
    async def scenario():
        c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        server = PipelineServer(c, MODEL, PARAMS, [1, 1])
        await server.start()
        toks = _tokens(seed=9)
        want, _ = MODEL.forward(PARAMS, jnp.asarray(toks))
        await server.submit(toks)

        ctrl = ElasticController(server, interval=0.05)
        victim = server.replicas[1][0].worker_id
        c.kill(victim, FailureKind.SILENT_HANG)
        await asyncio.sleep(0.3)                       # watchdog fences
        assert server.broken_worlds                    # detection happened
        assert victim in server.failed_replicas(1)

        await ctrl.step()              # one control tick schedules the heal
        await ctrl.wait_heals()        # heals run as bounded background tasks
        assert ctrl.heals == 1
        assert any(e.kind == "heal" for e in ctrl.timeline)
        healed = server.healthy_replicas(1)
        assert healed and victim not in healed

        got = await server.submit(toks, timeout=10.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        c.shutdown()

    arun(scenario())


def test_controller_heal_replaces_alive_cutoff_replica(arun):
    """An alive replica reported as failed (all upstream edges fenced) is
    replaced add-first (capacity never dips) and drained, not discarded."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 2])
        await server.start()
        await server.submit(_tokens())
        victim = server.replicas[1][0].worker_id
        ctrl = ElasticController(server, interval=0.05)

        orig = server.failed_replicas

        def fake(stage):
            if stage == 1 and any(r.worker_id == victim
                                  for r in server.replicas[1]):
                return [victim]
            return orig(stage)

        server.failed_replicas = fake
        await ctrl.step()
        await ctrl.wait_heals()
        assert ctrl.heals == 1
        ids = server.healthy_replicas(1)
        assert victim not in ids and len(ids) == 2
        await server.submit(_tokens())
        c.shutdown()

    arun(scenario())


def test_controller_executes_scale_decisions(arun):
    """Policy deltas drive add_replica / drain-and-remove end to end."""
    class Scripted:
        def __init__(self):
            self.votes = {1: [1, -1]}    # stage 1: up once, then down once

        def decide(self, snap):
            votes = self.votes.get(snap.stage, [])
            delta = votes.pop(0) if votes else 0
            return ScaleDecision(snap.stage, delta, "scripted")

    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 1])
        await server.start()
        await server.submit(_tokens())
        policy = Scripted()
        ctrl = ElasticController(server, [policy, policy], interval=0.05)

        await ctrl.step()
        assert len(server.healthy_replicas(1)) == 2 and ctrl.scale_ups == 1
        await ctrl.step()
        assert len(server.healthy_replicas(1)) == 1 and ctrl.scale_downs == 1
        await server.submit(_tokens())
        c.shutdown()

    arun(scenario())


def test_metrics_hub_polls_load_and_events(arun):
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 1])
        await server.start()
        hub = MetricsHub(server)
        await server.submit(_tokens())
        await asyncio.sleep(0.05)
        hub.poll()
        await server.submit(_tokens())
        snaps = hub.poll()
        assert len(snaps) == 2
        assert all(s.n_replicas == 1 for s in snaps)
        assert sum(s.replicas[0].processed for s in snaps) == 4
        assert any(k == "init_done" for _, k, _w in hub.world_events)
        c.shutdown()

    arun(scenario())

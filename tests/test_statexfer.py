"""State-transfer subsystem: codec round-trips, live KV-session migration,
snapshot restore, warm bootstrap, deadline enforcement, and store GC.

The acceptance bar (ISSUE 3): a planned drain with open mid-decode sessions
completes via live handoff with zero re-prefill and greedy token parity; an
unplanned kill with background snapshots replays only the suffix since the
latest snapshot; a torn transfer falls back to re-prefill without losing a
token.
"""
import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.control import MetricsHub
from repro.core import Cluster, FailureKind, Store
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import Envelope, Kind, PipelineServer, ServeEngine
from repro.statexfer import (
    SessionSnapshot,
    SnapshotChunk,
    SnapshotTransferError,
    snapshot_assemble,
    snapshot_encode,
    tree_equal,
)

CFG = get_smoke("llama3.2-1b").with_(num_layers=4,
                                     groups=(BlockGroup(DENSE, 4),))
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
ENGINE = ServeEngine(MODEL, PARAMS, max_len=64)


def _prompts(n, seq=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, (1, seq)) for _ in range(n)]


async def _warm(server, sessions=8):
    """Compile everything the scenario can touch off-clock: decode convoy
    widths (two rounds, like bench_generate) and the re-prefill history
    buckets (16/32) the fallback paths land in."""
    ps = _prompts(sessions, seed=99)
    for _ in range(2):
        await asyncio.gather(*(server.generate(p, 3, step_timeout=120.0)
                               for p in ps))
    for seq in (12, 20):
        await server.generate(_prompts(1, seq=seq, seed=90 + seq)[0], 2,
                              step_timeout=120.0)


async def _wait_open(server, stage, n, timeout=15.0):
    """Park until ``n`` sessions hold KV state at ``stage`` (all prefills
    landed) — fixed sleeps flake when a compile sneaks into the scenario."""
    deadline = time.monotonic() + timeout
    while sum(r.open_sessions() for r in server.replicas[stage]) < n:
        assert time.monotonic() < deadline, "sessions never all opened"
        await asyncio.sleep(0.005)


# ------------------------------------------------------------------- codec

def _mid_decode_session(new_tokens=3, seed=11):
    sess = ENGINE.start_session(_prompts(1, seed=seed)[0])
    toks = [ENGINE.step_session(sess) for _ in range(new_tokens)]
    return sess, toks


def test_snapshot_codec_fp_roundtrip_chunked():
    """fp chunks reassemble byte-identically, in any arrival order."""
    sess, _ = _mid_decode_session()
    snap = SessionSnapshot(session_id=7, stage=1, step=sess.t, batch=1,
                           cache=sess.cache)
    chunks = snapshot_encode(snap, codec="fp", chunk_bytes=4096)
    assert len(chunks) > 3                      # actually exercises chunking
    assert all(c.bulk for c in chunks)          # bulk byte accounting tag
    back = snapshot_assemble(list(reversed(chunks)))   # arbitrary order
    assert back.step == sess.t and back.session_id == 7
    assert tree_equal(back.cache, sess.cache)   # byte-identical restore


def test_snapshot_codec_rejects_torn_transfers():
    sess, _ = _mid_decode_session()
    snap = SessionSnapshot(session_id=1, stage=0, step=sess.t, batch=1,
                           cache=sess.cache)
    chunks = snapshot_encode(snap, chunk_bytes=4096)
    with pytest.raises(SnapshotTransferError):
        snapshot_assemble(chunks[1:])                   # header chunk lost
    with pytest.raises(SnapshotTransferError):
        snapshot_assemble(chunks[:-1])                  # tail chunk lost
    with pytest.raises(SnapshotTransferError):
        snapshot_assemble(chunks[:1] + chunks[1:2] + chunks[1:])  # duplicate
    corrupt = [SnapshotChunk(c.session_id, c.stage, c.seq,
                             (bytes([c.data[0] ^ 0xFF]) + c.data[1:]
                              if c.seq == 1 else c.data), c.header)
               for c in chunks]
    with pytest.raises(SnapshotTransferError):          # CRC mismatch
        snapshot_assemble(corrupt)


@pytest.mark.parametrize("codec", ["fp", "int8"])
def test_session_restores_across_engine_restart(codec):
    """A mid-decode session exported, moved across an engine restart, and
    resumed is token-identical (greedy) to the uninterrupted run — exactly
    (fp) or by argmax margin (int8)."""
    total, cut = 8, 3
    p = _prompts(1, seed=21)[0]
    want = ENGINE.generate(p, total)

    sess, toks = _mid_decode_session(new_tokens=cut, seed=21)
    blob = ENGINE.export_session(sess, codec=codec)
    fresh = ServeEngine(MODEL, PARAMS, max_len=64)      # "restarted" engine
    resumed = fresh.import_session(blob)
    if codec == "fp":
        assert tree_equal(resumed.cache, sess.cache)    # byte-identical
    toks += [fresh.step_session(resumed) for _ in range(total - cut)]
    got = np.stack(toks, axis=1)
    np.testing.assert_array_equal(got, want)


def test_store_delete_prefix_gc():
    s = Store()
    s.set("snap/p/1/0", b"a")
    s.set("snap/p/1/1", b"b")
    s.set("snap/p/12/0", b"c")      # sibling namespace sharing a prefix
    assert s.delete_prefix("snap/p/1/") == 2
    assert s.get("snap/p/12/0") == b"c"     # sibling untouched
    assert s.keys("snap/p/1/") == []


# -------------------------------------------------------- planned handoff

def test_drain_live_handoff_zero_reprefill(arun):
    """Planned drain with >=4 open mid-decode sessions: every session moves
    via live handoff — zero re-prefill, zero RETRY, token parity."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 2, 1], max_len=64)
        await server.start()
        await _warm(server)
        ps = _prompts(8, seed=4)
        wants = [ENGINE.generate(p, 16) for p in ps]
        tasks = [asyncio.ensure_future(
            server.generate(p, 16, step_timeout=30.0)) for p in ps]
        await _wait_open(server, 1, len(ps))
        victims = [r for r in server.replicas[1]
                   if r.worker.alive and not r.draining]
        victim = max(victims, key=lambda r: r.open_sessions())
        n_open = victim.open_sessions()
        assert n_open >= 4, f"unbalanced pins: only {n_open} open sessions"
        await server.remove_replica(1, victim.worker_id, drain=True,
                                    timeout=60.0)
        outs = await asyncio.gather(*tasks)
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(got, want)
        m = server.migrations.stats()
        stats = server.replica_stats()
        assert m["migrations_total"] >= n_open - 1, m
        assert m["migrations_total"] >= 4, m
        assert m["reprefills_total"] == 0, m            # zero re-prefill
        assert sum(s["retries_sent"] for s in stats.values()) == 0, stats
        assert c.transport.bulk_bytes_sent > 0          # moved over the wire
        c.shutdown()

    arun(scenario(), timeout=300.0)


def test_partial_transfer_falls_back_to_reprefill(arun):
    """A torn chunk stream must not install torn state: the handoff fails
    closed and the drained sessions recover via the re-prefill path."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 2, 1], max_len=64)
        await server.start()
        await _warm(server, sessions=4)

        real = server.migrations._stream

        async def lossy(src, dst, world, chunks):
            received = await real(src, dst, world, chunks)
            return received[:-1] if len(received) > 1 else []  # drop tail

        server.migrations._stream = lossy
        server.migrations.chunk_bytes = 4096    # force multi-chunk transfers
        ps = _prompts(4, seed=6)
        wants = [ENGINE.generate(p, 16) for p in ps]
        tasks = [asyncio.ensure_future(
            server.generate(p, 16, step_timeout=30.0)) for p in ps]
        await _wait_open(server, 1, len(ps))
        victims = [r for r in server.replicas[1]
                   if r.worker.alive and not r.draining]
        victim = max(victims, key=lambda r: r.open_sessions())
        n_open = victim.open_sessions()
        assert n_open >= 1
        await server.remove_replica(1, victim.worker_id, drain=True,
                                    timeout=60.0)
        outs = await asyncio.gather(*tasks)
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(got, want)    # no token lost
        m = server.migrations.stats()
        assert m["migrations_total"] == 0, m
        assert m["migration_failures"] >= n_open, m
        assert m["reprefills_total"] + m["restores_total"] >= 1, m
        c.shutdown()

    arun(scenario(), timeout=300.0)


# ------------------------------------------------------- snapshot restore

def test_kill_restore_replays_only_suffix(arun):
    """Unplanned kill with background snapshots: sessions rebuild from the
    latest snapshot and replay only the tokens since it — strictly less
    than the full history the PR 2 path would recompute."""
    async def scenario():
        c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        server = PipelineServer(c, MODEL, PARAMS, [1, 2, 1], max_len=64,
                                snapshot_interval_s=5.0)   # manual sweeps
        await server.start()
        await _warm(server, sessions=5)
        ps = _prompts(5, seed=3)
        wants = [ENGINE.generate(p, 16) for p in ps]
        # in-flight steps at the hung replica are only detected by the
        # client timeout; keep it short (everything is pre-warmed) so the
        # test measures recovery, not the timeout
        tasks = [asyncio.ensure_future(
            server.generate(p, 16, step_timeout=5.0)) for p in ps]
        await _wait_open(server, 1, len(ps))
        # deterministic coverage: snapshot every open session, then kill
        await server.snapshots.sweep()
        victims = [r for r in server.replicas[1] if r.worker.alive]
        victim = max(victims, key=lambda r: r.open_sessions())
        assert victim.open_sessions() >= 1
        c.kill(victim.worker_id, FailureKind.SILENT_HANG)
        outs = await asyncio.gather(*tasks)
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(got, want)
        m = server.migrations.stats()
        assert m["restores_total"] >= 1, m
        assert m["reprefills_total"] == 0, m    # snapshots covered everyone
        # replay strictly cheaper than recomputing the histories
        full_history = sum(8 + 16 for _ in ps)
        assert 0 <= m["recomputed_tokens"] < full_history, m
        assert m["recovered_tokens"] > 0, m
        c.shutdown()

    arun(scenario(), timeout=300.0)


def test_snapshot_store_gc_on_finish(arun):
    """Finished sessions leave no snapshot keys behind (eager drop +
    sweep), mirroring the PR 1 world-state key-leak fix."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 1], max_len=64,
                                snapshot_interval_s=5.0)
        await server.start()
        task = asyncio.ensure_future(
            server.generate(_prompts(1, seed=5)[0], 6, step_timeout=30.0))
        await asyncio.sleep(0.03)
        taken = await server.snapshots.sweep()
        await task
        await asyncio.sleep(0.05)               # let FINISHes land
        await server.snapshots.sweep()          # GC pass
        assert c.store.keys("snap/") == [], c.store.keys("snap/")
        assert server.snapshots.snapshots_taken >= taken
        c.shutdown()

    arun(scenario())


# ----------------------------------------------------- deadline enforcement

def test_expired_envelope_finishes_with_error(arun):
    """A deadline-expired step is dropped at the stage boundary and the
    client is told via FINISH(error) instead of being served late."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 1], max_len=64)
        await server.start()
        await server.generate(_prompts(1, seed=5)[0], 2, step_timeout=30.0)
        world = server.client_router.try_pick()
        env = Envelope(next(server._req_ids), 12345, Kind.DECODE, step=9,
                       deadline=time.monotonic() - 1.0,   # already expired
                       payload=jnp.zeros((1, 1), jnp.int32))
        resp = await server._roundtrip(env, world, timeout=10.0)
        assert resp.kind is Kind.FINISH
        assert resp.error and "deadline" in resp.error
        hub = MetricsHub(server)
        assert hub.migration_metrics()["deadline_expired_total"] >= 1
        assert sum(s.expired for s in hub.poll()) >= 1
        c.shutdown()

    arun(scenario(), timeout=300.0)


# ----------------------------------------------------------- warm bootstrap

def test_warm_bootstrap_prewarms_fresh_executor(arun):
    """A warm-added replica fetches bit-identical stage weights from a peer
    over the wire and pre-compiles the peer's served shape profile before
    taking traffic."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 1], max_len=64)
        await server.start()
        p = _prompts(1, seed=7)[0]
        want = ENGINE.generate(p, 6)
        np.testing.assert_array_equal(
            await server.generate(p, 6, step_timeout=120.0), want)

        bulk0 = c.transport.bulk_bytes_sent
        wid = await server.add_replica(1, warm=True, fresh_executor=True)
        rep = next(r for r in server.replicas[1] if r.worker_id == wid)
        peer = next(r for r in server.replicas[1] if r.worker_id != wid)
        assert rep.executor is not peer.executor            # own jit cache
        assert c.transport.bulk_bytes_sent > bulk0          # weights moved
        assert tree_equal(rep.executor.sparams,
                          server.stage_param_sets[1])       # bit-identical
        assert rep.executor.stats["warmed_dispatches"] > 0
        prof = peer.executor.warm_profile()
        assert set(prof["prefill"]) <= \
            set(rep.executor.warm_profile()["prefill"])
        assert server.bootstrap.bootstraps_total == 1
        # traffic through the warm replica stays token-correct
        np.testing.assert_array_equal(
            await server.generate(p, 6, step_timeout=30.0), want)
        c.shutdown()

    arun(scenario(), timeout=300.0)

"""Fault tolerance: watchdog detection, fencing, fault-domain isolation.

Includes the paper's Fig. 4 scenario as a test (the timed benchmark version
lives in benchmarks/bench_fault.py).
"""
import asyncio

import jax.numpy as jnp
import pytest

from repro.core import (
    Cluster,
    FailureKind,
    WorldBrokenError,
    WorldStatus,
)


def t(v):
    return jnp.asarray(v, dtype=jnp.float32)


async def make_world(c: Cluster, name: str, workers: list[str]):
    await asyncio.gather(*[
        c.worker(w).manager.initialize_world(name, r, len(workers))
        for r, w in enumerate(workers)
    ])


def fast_cluster() -> Cluster:
    return Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)


def test_watchdog_detects_silent_hang(arun):
    """The NCCL shared-memory case: no data-path error, only heartbeat loss."""
    async def scenario():
        c = fast_cluster()
        await make_world(c, "w", ["A", "B"])
        c.kill("B", FailureKind.SILENT_HANG)
        # wait for A's watchdog to fence the world
        for _ in range(200):
            if c.worker("A").manager.worlds["w"].status is WorldStatus.BROKEN:
                break
            await asyncio.sleep(0.01)
        assert c.worker("A").manager.worlds["w"].status is WorldStatus.BROKEN
        c.shutdown()

    arun(scenario())


def test_pending_recv_aborts_on_world_break(arun):
    async def scenario():
        c = fast_cluster()
        await make_world(c, "w", ["A", "B"])
        pending = asyncio.ensure_future(c.worker("A").comm.recv(1, "w"))
        await asyncio.sleep(0.02)
        assert not pending.done()
        c.kill("B", FailureKind.SILENT_HANG)
        with pytest.raises(WorldBrokenError):
            await asyncio.wait_for(pending, timeout=2.0)
        assert c.worker("A").comm.ops_aborted == 1
        c.shutdown()

    arun(scenario())


def test_detectable_crash_fails_fast(arun):
    """ncclRemoteError analogue: data-path op converts to WorldBrokenError
    without waiting a heartbeat timeout."""
    async def scenario():
        c = Cluster(heartbeat_interval=0.02, heartbeat_timeout=10.0)  # slow watchdog
        await make_world(c, "w", ["A", "B"])
        c.kill("B", FailureKind.CRASH_DETECTABLE)
        with pytest.raises(WorldBrokenError):
            await c.worker("A").comm.recv(1, "w")
        assert c.worker("A").manager.worlds["w"].status is WorldStatus.BROKEN
        c.shutdown()

    arun(scenario())


def test_fault_domain_isolation(arun):
    """Paper Fig. 2b: P3 dies; worlds without P3 keep working, and a worker
    sharing no world with P3 never even notices."""
    async def scenario():
        c = fast_cluster()
        # rhombus: P1->P2 (w12), P1->P3 (w13), P2->P4 (w24), P3->P4 (w34)
        await make_world(c, "w12", ["P1", "P2"])
        await make_world(c, "w13", ["P1", "P3"])
        await make_world(c, "w24", ["P2", "P4"])
        await make_world(c, "w34", ["P3", "P4"])
        c.kill("P3", FailureKind.SILENT_HANG)
        await asyncio.sleep(0.3)

        p1, p2, p4 = (c.worker(w).manager for w in ("P1", "P2", "P4"))
        assert p1.worlds["w13"].status is WorldStatus.BROKEN
        assert p4.worlds["w34"].status is WorldStatus.BROKEN
        # healthy worlds untouched
        assert p1.worlds["w12"].status is WorldStatus.HEALTHY
        assert p2.worlds["w24"].status is WorldStatus.HEALTHY
        # P2 shares no world with P3: completely unaffected
        assert set(p2.healthy_worlds()) == {"w12", "w24"}

        # traffic still flows end-to-end through the surviving path
        await c.worker("P1").comm.send(t([1.0]), 1, "w12")
        x = await c.worker("P2").comm.recv(0, "w12")
        await c.worker("P2").comm.send(x + 1, 1, "w24")
        y = await c.worker("P4").comm.recv(0, "w24")
        assert float(y[0]) == 2.0
        c.shutdown()

    arun(scenario())


def test_fig4_leader_continues_with_surviving_worker(arun):
    """Paper Fig. 4b: leader is W1-R0 and W2-R0; W1-R1 keeps sending, W2-R1
    dies after its 10th tensor; leader keeps receiving from W1-R1."""
    async def scenario():
        c = fast_cluster()
        await make_world(c, "w1", ["L", "S1"])
        await make_world(c, "w2", ["L", "S2"])
        leader = c.worker("L").comm
        received = {"w1": 0, "w2": 0}

        async def sender(worker, world, n, die_after=None):
            for i in range(n):
                await c.worker(worker).comm.send(t([float(i)]), 0, world)
                await asyncio.sleep(0.002)
            if die_after is not None:
                c.kill(worker, FailureKind.SILENT_HANG)

        async def leader_recv(world, n):
            for _ in range(n):
                try:
                    await leader.recv(1, world)
                    received[world] += 1
                except WorldBrokenError:
                    return

        await asyncio.gather(
            sender("S1", "w1", 30),
            sender("S2", "w2", 10, die_after=True),
            leader_recv("w1", 30),
            leader_recv("w2", 30),
        )
        assert received["w1"] == 30          # unaffected world drained fully
        assert received["w2"] <= 10          # broken world aborted cleanly
        assert c.worker("L").manager.worlds["w2"].status is WorldStatus.BROKEN
        assert c.worker("L").manager.worlds["w1"].status is WorldStatus.HEALTHY
        c.shutdown()

    arun(scenario())


def test_break_listener_fires_once(arun):
    async def scenario():
        c = fast_cluster()
        await make_world(c, "w", ["A", "B"])
        hits = []
        c.worker("A").manager.on_world_broken(lambda n, r: hits.append((n, r)))
        c.kill("B")
        await asyncio.sleep(0.3)
        assert len(hits) == 1 and hits[0][0] == "w"
        c.shutdown()

    arun(scenario())


def test_node_failure_as_multiple_worker_failures(arun):
    """Paper §3.1: 'node failure can be translated into failures of workers
    running in the node'."""
    async def scenario():
        c = fast_cluster()
        # node X hosts B and C; A is elsewhere
        await make_world(c, "wab", ["A", "B"])
        await make_world(c, "wac", ["A", "C"])
        for w in ("B", "C"):
            c.kill(w, FailureKind.SILENT_HANG)
        await asyncio.sleep(0.3)
        mgr = c.worker("A").manager
        assert mgr.worlds["wab"].status is WorldStatus.BROKEN
        assert mgr.worlds["wac"].status is WorldStatus.BROKEN
        c.shutdown()

    arun(scenario())

"""Multi-model, multi-tenant serving on one elastic pool.

Covers the residency bookkeeper (refcounts, LRU eviction, refusal while
sessions pin weights), model-tagged routing, hot load/swap over the
LOAD/UNLOAD/SWAP wire protocol (greedy parity before/after, zero
client-visible failures under traffic), heal-with-residency after a kill,
the weighted-deficit fair scheduler's slot arithmetic, the per-tenant SLO
policy's votes (swap > grow > shrink), and the multi-tenant traffic
generator's per-tenant accounting.
"""
import asyncio
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, get_smoke
from repro.control import (
    ConstantProfile,
    ElasticController,
    MetricsHub,
    MultiTenantGenerator,
    PerTenantSLOPolicy,
    ScaleDecision,
    StageSnapshot,
    TenantProfile,
    TenantSpec,
)
from repro.core import Cluster, FailureKind
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import (
    Envelope,
    Kind,
    ModelRegistry,
    PipelineServer,
    ReplicaRouter,
    ResidencyError,
    ServeEngine,
)
from repro.serving.pipeline import _Replica, _Session

CFG_A = get_smoke("llama3.2-1b").with_(num_layers=4,
                                       groups=(BlockGroup(DENSE, 4),))
MODEL_A = build_model(CFG_A)
PARAMS_A = MODEL_A.init(jax.random.PRNGKey(0))
CFG_B = get_smoke("llama3.2-1b").with_(num_layers=2,
                                       groups=(BlockGroup(DENSE, 2),))
MODEL_B = build_model(CFG_B)
PARAMS_B = MODEL_B.init(jax.random.PRNGKey(1))

ENG_A = ServeEngine(MODEL_A, PARAMS_A, max_len=64)
ENG_B = ServeEngine(MODEL_B, PARAMS_B, max_len=64)


def _prompts(n, seq=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG_A.vocab_size, (1, seq)) for _ in range(n)]


# --------------------------------------------------------------- registry
def test_registry_refcount_blocks_unload():
    reg = ModelRegistry()
    reg.register("a", MODEL_A, PARAMS_A)
    reg.register("b", MODEL_B, PARAMS_B)
    assert reg.load("w0", "a") == []
    assert reg.load("w0", "b") == []
    assert reg.resident_counts() == {"a": 1, "b": 1}

    reg.acquire("w0", "b")
    reg.acquire("w0", "b")
    assert reg.refcount("w0", "b") == 2
    with pytest.raises(ResidencyError):
        reg.unload("w0", "b")
    assert reg.is_resident("w0", "b")

    reg.release("w0", "b")
    with pytest.raises(ResidencyError):
        reg.unload("w0", "b")        # one session still pins it
    reg.release("w0", "b")
    reg.unload("w0", "b")            # refcount hit zero: allowed
    assert not reg.is_resident("w0", "b")
    assert reg.unloads_total == 1

    # forced unload is the kill/teardown path: refs are already lost
    reg.load("w0", "b")
    reg.acquire("w0", "b")
    reg.unload("w0", "b", force=True)
    assert not reg.is_resident("w0", "b")


def test_registry_lru_eviction_order():
    reg = ModelRegistry(max_resident=2)
    for name in ("a", "b", "c"):
        reg.register(name, MODEL_B, PARAMS_B)
    reg.load("w0", "a")
    reg.load("w0", "b")
    reg.touch("w0", "a")             # "a" just served traffic: "b" is LRU
    assert reg.load("w0", "c") == ["b"]
    assert reg.resident("w0") == ["a", "c"]
    assert reg.evictions_total == 1
    # re-loading a resident model is a touch, never an eviction
    assert reg.load("w0", "a") == []
    assert reg.resident("w0") == ["c", "a"]


def test_registry_eviction_refusal_when_all_pinned():
    reg = ModelRegistry(max_resident=1)
    reg.register("a", MODEL_B, PARAMS_B)
    reg.register("b", MODEL_B, PARAMS_B)
    reg.load("w0", "a")
    reg.acquire("w0", "a")
    with pytest.raises(ResidencyError):
        reg.load("w0", "b")          # the only evictable slot is pinned
    assert reg.eviction_refusals == 1
    reg.release("w0", "a")
    assert reg.load("w0", "b") == ["a"]

    reg.load("w1", "a")
    reg.drop_worker("w1")
    assert reg.resident("w1") == []


def test_registry_unknown_model_suggestion():
    reg = ModelRegistry()
    reg.register("summarizer", MODEL_B, PARAMS_B)
    with pytest.raises(KeyError, match="did you mean 'summarizer'"):
        reg.get("sumarizer")


def test_config_unknown_arch_suggestion():
    with pytest.raises(KeyError, match="did you mean 'qwen3-8b'"):
        get_config("qwen-8b")


# ----------------------------------------------------------------- router
def test_router_model_tag_filtering():
    r = ReplicaRouter()
    r.add("w_ab", models={"a", "b"})
    r.add("w_a", models={"a"})
    r.add("w_any")                   # untagged: serves any model
    assert set(r.healthy(model="a")) == {"w_ab", "w_a", "w_any"}
    assert set(r.healthy(model="b")) == {"w_ab", "w_any"}
    assert set(r.healthy(model=None)) == {"w_ab", "w_a", "w_any"}
    for _ in range(6):
        assert r.pick(model="b") in {"w_ab", "w_any"}
    assert r.try_pick(model="zz") == "w_any"

    # live residency update: the swap protocol retags without re-adding
    r.set_models("w_a", {"b"})
    assert set(r.healthy(model="a")) == {"w_ab", "w_any"}
    assert set(r.healthy(model="b")) == {"w_ab", "w_a", "w_any"}
    r.set_models("w_ab", None)       # clearing the tag = serves any model
    assert set(r.healthy(model="zz")) == {"w_ab", "w_any"}
    r.remove("w_any")
    r.remove("w_ab")
    assert r.try_pick(model="a") is None
    with pytest.raises(RuntimeError, match="model 'a'"):
        r.pick(model="a")


# -------------------------------------------------- fair decode scheduler
def test_wdrr_fair_scheduler_slot_shares(arun):
    """Direct arbitration arithmetic of ``_Replica._pull_compatible``:
    with 8 batch slots and both tenants backlogged, weights 3:1 must yield
    exactly 6:2 slots; equal weights 4:4; a single tenant takes it all."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL_B, PARAMS_B, [1], max_len=64,
                                microbatch_max=9,
                                tenant_weights={"gold": 3.0, "bronze": 1.0})

        def fill(rep, tenants):
            now = time.monotonic()
            sid = 100
            for tenant, count in tenants:
                for _ in range(count):
                    sid += 1
                    rep.sessions[sid] = _Session(
                        cache=None, batch=1, step=0, touched=now,
                        tenant=tenant)
                    rep.inbox.put_nowait((Envelope(
                        req_id=sid, session_id=sid, kind=Kind.DECODE,
                        payload=np.zeros((1, 1), np.int32), tenant=tenant),
                        now))
            # arbitration lead: a step already in hand consumes no credit
            rep.sessions[99] = _Session(cache=None, batch=1, step=0,
                                        touched=now, tenant=tenants[0][0])
            return Envelope(req_id=99, session_id=99, kind=Kind.DECODE,
                            payload=np.zeros((1, 1), np.int32),
                            tenant=tenants[0][0])

        def shares(rep, lead, n):
            batch = [lead]
            pulled = rep._pull_compatible(lead, n, batch)
            out: dict = {}
            for env in batch[1:]:
                out[env.tenant] = out.get(env.tenant, 0) + 1
            return pulled, out

        # 3:1 weights, both tenants flooded -> exact 6:2 slot split
        rep = _Replica(server, "w_fair0", 0)
        lead = fill(rep, [("gold", 8), ("bronze", 8)])
        pulled, got = shares(rep, lead, 8)
        assert pulled == 8
        assert got == {"gold": 6, "bronze": 2}
        # arbitration losers wait in the stash, none dropped
        assert len(rep._stash) == 8

        # unweighted tenants (not in tenant_weights) split evenly
        rep2 = _Replica(server, "w_fair1", 0)
        lead2 = fill(rep2, [("x", 8), ("y", 8)])
        _, got2 = shares(rep2, lead2, 8)
        assert got2 == {"x": 4, "y": 4}

        # single (untagged) tenant: full batch, nothing withheld
        rep3 = _Replica(server, "w_fair2", 0)
        lead3 = fill(rep3, [(None, 8)])
        pulled3, got3 = shares(rep3, lead3, 8)
        assert pulled3 == 8 and got3 == {None: 8}
        assert not rep3._stash
        c.shutdown()

    arun(scenario())


# ------------------------------------------------- hot load + generation
def test_multimodel_load_and_generate_parity(arun):
    """Cold-load a second model from the registry store, generate against
    it (greedy parity with a dedicated engine), then warm-load the same
    model onto a peer replica over the LOAD wire, and serve both models'
    traffic concurrently on the shared pool."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL_A, PARAMS_A, [2], max_len=64,
                                default_model="A")
        server.register_model("B", MODEL_B, PARAMS_B)
        await server.start()

        p = _prompts(1, seed=1)[0]
        got = await server.generate(p, 5, step_timeout=30.0)
        np.testing.assert_array_equal(got, ENG_A.generate(p, 5))

        # unknown tags fail fast with the known names, not a routing stall
        with pytest.raises(KeyError, match=r"registered: \['A', 'B'\]"):
            await server.generate(p, 2, model="b")

        # cold load: no peer hosts B yet, weights come from the store
        rep0 = server.replicas[0][0]
        r0 = await server.load_model(rep0.worker_id, "B")
        assert r0["source"] == "store" and r0["bytes"] == 0
        assert "B" in rep0.resident

        p2 = _prompts(1, seed=2)[0]
        got_b = await server.generate(p2, 5, step_timeout=30.0, model="B",
                                      tenant="t1")
        np.testing.assert_array_equal(got_b, ENG_B.generate(p2, 5))

        # warm load: rep0 is now a resident peer, weights move as LOAD
        # envelopes on the accounted wire
        rep1 = server.replicas[0][1]
        r1 = await server.load_model(rep1.worker_id, "B")
        assert r1["source"] == "peer" and r1["bytes"] > 0
        assert r1["peer"] == rep0.worker_id
        assert server.bootstrap.model_loads_total == 2
        assert server.bootstrap.model_loads_cold == 1
        # idempotent: already-resident load moves nothing
        again = await server.load_model(rep1.worker_id, "B")
        assert again["source"] == "resident" and again["bytes"] == 0

        # both models share the pool: concurrent tagged traffic, exact
        # greedy parity for every client
        ps = _prompts(4, seed=3)
        wants = [ENG_A.generate(q, 4) for q in ps[:2]] + \
                [ENG_B.generate(q, 4) for q in ps[2:]]
        outs = await asyncio.gather(
            server.generate(ps[0], 4, step_timeout=30.0, tenant="t0"),
            server.generate(ps[1], 4, step_timeout=30.0, tenant="t0"),
            server.generate(ps[2], 4, step_timeout=30.0, model="B",
                            tenant="t1"),
            server.generate(ps[3], 4, step_timeout=30.0, model="B",
                            tenant="t1"),
        )
        for want, out in zip(wants, outs):
            np.testing.assert_array_equal(out, want)
        assert server.tenant_tokens["t0"] == 8
        assert server.tenant_tokens["t1"] == 13   # 5 solo + 8 mixed

        # metrics plumbing: model/tenant dimensions reach the exporter
        hub = MetricsHub(server, alpha=1.0)
        snaps = hub.poll()
        assert snaps[0].model_replicas.get("B") == 2
        assert set(snaps[0].tenant_tails) == {"t0", "t1"}
        text = hub.export_prometheus(snaps)
        assert "repro_tenant_p95_ttft_s" in text
        assert "repro_model_replicas" in text
        c.shutdown()

    arun(scenario(), 300)


def test_swap_under_traffic_zero_failures_and_parity(arun):
    """Swap a replica's residency B -> A while B sessions are decoding on
    it: incumbents live-migrate to the other B host, every client finishes
    with exact greedy parity, and the registry retires the residency."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL_A, PARAMS_A, [2], max_len=64,
                                default_model="A")
        server.register_model("B", MODEL_B, PARAMS_B)
        await server.start()
        rep0, rep1 = server.replicas[0]
        await server.load_model(rep0.worker_id, "B")
        await server.load_model(rep1.worker_id, "B")

        ps = _prompts(4, seed=7)
        wants = [ENG_B.generate(q, 12) for q in ps]
        tasks = [asyncio.ensure_future(
            server.generate(q, 12, step_timeout=30.0, model="B",
                            tenant="t"))
                 for q in ps]
        # wait until B sessions are actually open on the swap victim
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            if any(s.model == "B" for s in rep1.sessions.values()):
                break
            await asyncio.sleep(0.01)
        assert any(s.model == "B" for s in rep1.sessions.values())

        report = await server.swap_model(rep1.worker_id, "B", "A")
        assert report["swap_from"] == "B"
        assert "B" not in rep1.resident and "A" in rep1.resident
        assert not server.registry.is_resident(rep1.worker_id, "B")
        assert server.swaps_total == 1
        assert server.bootstrap.model_swaps_total == 1

        outs = await asyncio.gather(*tasks)   # zero client-visible failures
        for want, out in zip(wants, outs):
            np.testing.assert_array_equal(out, want)
        # and the swapped replica still serves the default model
        p = _prompts(1, seed=8)[0]
        np.testing.assert_array_equal(
            await server.generate(p, 4, step_timeout=30.0),
            ENG_A.generate(p, 4))
        c.shutdown()

    arun(scenario(), 300)


def test_kill_after_load_heals_resident_models(arun):
    """A replica dies while hosting a hot-loaded model: the controller's
    heal restores the victim's full resident set on the replacement (cold
    from the store when no peer survives), and tagged traffic serves with
    exact parity afterwards."""
    async def scenario():
        c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        server = PipelineServer(c, MODEL_A, PARAMS_A, [1, 1], max_len=64,
                                default_model="A")
        server.register_model("B", MODEL_B, PARAMS_B)
        await server.start()
        for stage in range(2):
            await server.load_model(
                server.replicas[stage][0].worker_id, "B")
        p = _prompts(1, seed=4)[0]
        want = ENG_B.generate(p, 4)
        np.testing.assert_array_equal(
            await server.generate(p, 4, step_timeout=30.0, model="B"),
            want)

        ctrl = ElasticController(server, interval=0.05)
        victim = server.replicas[1][0].worker_id
        c.kill(victim, FailureKind.SILENT_HANG)
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            if victim in server.failed_replicas(1):
                break
            await asyncio.sleep(0.02)
        assert victim in server.failed_replicas(1)

        await ctrl.step()
        await ctrl.wait_heals()
        assert ctrl.heals == 1
        healed = [r for r in server.replicas[1]
                  if r.worker.alive and not r.draining]
        assert healed and healed[0].worker_id != victim
        # the heal restored the victim's residency, not just the default
        assert "B" in healed[0].resident
        assert server.registry.is_resident(healed[0].worker_id, "B")
        assert not server.registry.resident(victim)

        np.testing.assert_array_equal(
            await server.generate(p, 4, step_timeout=30.0, model="B"),
            want)
        c.shutdown()

    arun(scenario(), 300)


def test_controller_applies_swap_vote(arun):
    """A policy's ``swap_from``/``swap_to`` vote drives ``swap_model`` on
    the least-loaded host of the donor model."""
    class Scripted:
        def __init__(self, src, dst):
            self.src, self.dst = src, dst
            self.fired = False

        def decide(self, snap):
            if self.fired:
                return ScaleDecision(snap.stage, 0, "hold")
            self.fired = True
            return ScaleDecision(snap.stage, 0, "scripted swap",
                                 swap_from=self.src, swap_to=self.dst)

    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL_B, PARAMS_B, [2], max_len=64,
                                default_model="base")
        server.register_model(
            "B", MODEL_B, MODEL_B.init(jax.random.PRNGKey(2)))
        server.register_model(
            "C", MODEL_B, MODEL_B.init(jax.random.PRNGKey(3)))
        await server.start()
        for rep in server.replicas[0]:
            await server.load_model(rep.worker_id, "B")

        ctrl = ElasticController(server, [Scripted("B", "C")],
                                 interval=0.05)
        await ctrl.step()
        assert ctrl.swaps == 1
        assert any(e.kind == "swap" for e in ctrl.timeline)
        counts = server.registry.resident_counts()
        assert counts == {"base": 2, "B": 1, "C": 1}
        hosts_c = [r for r in server.replicas[0] if "C" in r.resident]
        assert len(hosts_c) == 1 and "B" not in hosts_c[0].resident

        # a vote naming a donor no replica hosts is recorded as a hold,
        # never an exception out of the control loop
        ctrl2 = ElasticController(server, [Scripted("missing", "C")],
                                  interval=0.05)
        await ctrl2.step()
        assert ctrl2.swaps == 0
        assert any(e.kind == "swap_hold" for e in ctrl2.timeline)
        c.shutdown()

    arun(scenario(), 300)


# ------------------------------------------------------ per-tenant policy
def _snap(**kw) -> StageSnapshot:
    base = dict(stage=0, t=0.0, n_replicas=2, n_failed=0, queue_total=0,
                queue_per_replica=0.0, throughput=1.0, latency_s=0.01)
    base.update(kw)
    return StageSnapshot(**base)


def test_per_tenant_slo_policy_votes():
    policy = PerTenantSLOPolicy(tenants=[
        TenantSpec("gold", model="B", ttft_slo_s=0.5),
        TenantSpec("bronze", model=None, ttft_slo_s=2.0),
    ])
    tails = {
        "gold": {"p50_ttft_s": 1.0, "p95_ttft_s": 2.0,
                 "p95_decode_s": 0.01, "n": 20},
        "bronze": {"p50_ttft_s": 0.1, "p95_ttft_s": 0.2,
                   "p95_decode_s": 0.01, "n": 20},
    }

    # breach + donor with spare residency -> swap vote at delta 0
    d = policy.decide(_snap(n_replicas=4, tenant_tails=tails,
                            model_replicas={"default": 3, "B": 1},
                            model_sessions={"default": 0}))
    assert d.delta == 0 and not d.hold
    assert d.swap_from == "default" and d.swap_to == "B"

    # breach, no donor (every other model is starved too) -> model-tagged
    # grow, so healed capacity comes up hosting the starved model
    d = policy.decide(_snap(n_replicas=2, tenant_tails=tails,
                            model_replicas={"B": 1},
                            model_sessions={}))
    assert d.delta == 1 and d.model == "B"

    # a single-replica donor pinned by open sessions cannot give up its
    # only residency -> grow, not a stranding swap
    d = policy.decide(_snap(n_replicas=2, tenant_tails=tails,
                            model_replicas={"default": 2, "B": 1},
                            model_sessions={"default": 5, "B": 1}))
    assert d.swap_from == "default"   # 2 replicas: one is spare even loaded
    d = policy.decide(_snap(n_replicas=2, tenant_tails=tails,
                            model_replicas={"A": 1, "B": 1},
                            model_sessions={"A": 5}))
    assert d.delta == 1 and d.swap_to is None

    # every observed tenant comfortably under SLO + idle queue -> shrink
    cold = {
        "gold": {"p50_ttft_s": 0.01, "p95_ttft_s": 0.05,
                 "p95_decode_s": 0.01, "n": 20},
        "bronze": {"p50_ttft_s": 0.01, "p95_ttft_s": 0.05,
                   "p95_decode_s": 0.01, "n": 20},
    }
    d = policy.decide(_snap(n_replicas=2, tenant_tails=cold))
    assert d.delta == -1

    # no tenant dimensions (single-tenant pipeline) -> pure hold
    d = policy.decide(_snap())
    assert d.hold and d.delta == 0


# ------------------------------------------------------ traffic generator
def test_multitenant_generator_summary(arun):
    async def scenario():
        served: dict = {}

        async def submit(tenant, prompt_len):
            lo, hi = tenant.prompt_len
            assert lo <= prompt_len <= hi
            served[tenant.name] = served.get(tenant.name, 0) + 1
            if tenant.name == "bronze":
                raise RuntimeError("bronze shed")
            await asyncio.sleep(0.001)

        tenants = [
            TenantProfile("gold", ConstantProfile(80.0),
                          prompt_len=(4, 8), model="B", weight=3.0),
            TenantProfile("bronze", ConstantProfile(20.0),
                          prompt_len=(2, 4), weight=1.0),
        ]
        gen = MultiTenantGenerator(submit, tenants, seed=3)
        out = await gen.run(0.5)
        assert set(out["tenants"]) == {"gold", "bronze"}
        gold, bronze = out["tenants"]["gold"], out["tenants"]["bronze"]
        # 80 vs 20 rps: the heavy tenant dominates the arrival mix
        assert gold["sent"] > bronze["sent"] > 0
        assert gold["failed"] == 0 and bronze["ok"] == 0
        assert gold["model"] == "B" and gold["weight"] == 3.0
        assert out["sent"] == gold["sent"] + bronze["sent"]
        assert out["ok"] == gold["ok"] and out["failed"] == bronze["failed"]
        assert served["gold"] == gold["sent"]
        # per-tenant RNG streams: same seed reproduces the arrival counts
        gen2 = MultiTenantGenerator(submit, tenants, seed=3)
        out2 = await gen2.run(0.5)
        assert out2["tenants"]["gold"]["sent"] == gold["sent"]
        assert out2["tenants"]["bronze"]["sent"] == bronze["sent"]

    arun(scenario(), 60)

"""Topology-aware placement + snapshot-assisted live heal (ISSUE 4).

The acceptance bar: every byte-moving choice (migration survivor,
warm-bootstrap peer, restore target, heal replacement) prices the bytes it
is about to move against the cluster topology instead of treating all edges
as equally cheap; and healing an alive-but-fenced replica live-migrates its
open sessions to the replacement — zero re-prefilled tokens, greedy token
parity — instead of recomputing every history, with snapshot restore as the
fallback for dead workers.
"""
import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.control import ElasticController, MetricsHub
from repro.core import Cluster, PlacementCost, Topology
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import PipelineServer, ServeEngine
from repro.statexfer import (
    FP,
    INT8,
    SessionSnapshot,
    argmax_margin,
    blob_origin,
    int8_margin_ok,
    quantization_noise,
    snapshot_from_blob,
    snapshot_to_blob_checked,
)

CFG = get_smoke("llama3.2-1b").with_(num_layers=2,
                                     groups=(BlockGroup(DENSE, 2),))
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
ENGINE = ServeEngine(MODEL, PARAMS, max_len=64)


def _prompts(n, seq=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, (1, seq)) for _ in range(n)]


async def _warm(server, sessions=4):
    ps = _prompts(sessions, seed=99)
    for _ in range(2):
        await asyncio.gather(*(server.generate(p, 3, step_timeout=120.0)
                               for p in ps))
    # let the warm-up FINISHes land: a lingering warm-up session would
    # satisfy _wait_open spuriously and the fence/drain would hit orphans
    # instead of the scenario's own mid-decode sessions
    deadline = time.monotonic() + 5.0
    while any(r.sessions for reps in server.replicas for r in reps):
        if time.monotonic() > deadline:
            break
        await asyncio.sleep(0.005)


async def _wait_open(server, stage, n, timeout=15.0):
    deadline = time.monotonic() + timeout
    while sum(r.open_sessions() for r in server.replicas[stage]) < n:
        assert time.monotonic() < deadline, "sessions never all opened"
        await asyncio.sleep(0.005)


def _fence(server, rep):
    """Watchdog-style fencing of every upstream edge of ``rep``: the worlds
    leave their routers' rotations (dropping session pins) and land in
    ``broken_worlds`` — the exact state ``failed_replicas`` reports for an
    alive-but-cut-off replica, with the worker itself still reachable."""
    for world, router in list(rep.upstream_edges):
        router.mark_broken(world)
        server.broken_worlds.add(world)


# ------------------------------------------------------------------ topology

def test_topology_placement_and_cost():
    topo = Topology(hosts=("h0", "h1"), numa_per_host=2, policy="spread")
    cost = PlacementCost(topo)
    a = topo.place("a")          # spread: h0
    b = topo.place("b")          # spread: h1
    assert a.host == "h0" and b.host == "h1"
    assert cost.edge_cost("a", "b") == cost.cross_host
    topo.assign("c", "h0", numa=a.numa)
    topo.assign("d", "h0", numa=1 - a.numa)
    assert cost.edge_cost("a", "c") == cost.same_numa
    assert cost.edge_cost("a", "d") == cost.same_host
    assert cost.same_numa < cost.same_host < cost.cross_host
    # near= pins a new worker to another worker's host (the heal path)
    assert topo.place("e", near="b").host == "h1"
    # unknown endpoints price conservatively as same-host
    assert cost.edge_cost(None, "a") == cost.same_host
    topo.forget("e")
    assert "e" not in topo._placements


def test_placement_score_orders_by_load_then_cost():
    """Equal queue load -> same-host wins; a big enough load gap still
    outranks the placement cost (placement never starves a hot replica)."""
    topo = Topology(hosts=("h0", "h1"))
    topo.assign("src", "h0")
    topo.assign("near", "h0", numa=1)
    topo.assign("far", "h1")
    cost = PlacementCost(topo, bytes_per_load=256 * 1024)
    nbytes = 256 * 1024          # one load-unit of same-host bytes
    same = cost.score(2.0, "src", "near", nbytes)
    cross = cost.score(2.0, "src", "far", nbytes)
    assert same < cross          # equal load: same-host strictly preferred
    # cross-host with a much shorter queue wins over a drowning local peer
    assert cost.score(1.0, "src", "far", nbytes) \
        < cost.score(20.0, "src", "near", nbytes)


def test_migration_rank_prefers_same_host_under_equal_load():
    class Rep:
        def __init__(self, wid):
            self.worker_id = wid

        def open_sessions(self):
            return 2

        def queue_depth(self):
            return 1

    topo = Topology(hosts=("h0", "h1"))
    for wid, host in (("src", "h0"), ("near", "h0"), ("far", "h1")):
        topo.assign(wid, host)
    cluster = Cluster(topology=topo)
    server = PipelineServer(cluster, MODEL, PARAMS, [1], max_len=64)
    near, far = Rep("near"), Rep("far")
    # equal load either way: placement cost must break the tie to same-host
    assert server.migrations._rank("src", [far, near], 128 * 1024) is near
    server.migrations.placement_aware = False     # blind baseline: list order
    assert server.migrations._rank("src", [far, near], 128 * 1024) is far
    cluster.shutdown()


def test_drain_migration_stays_on_host(arun):
    """Two-host topology, a same-host and a cross-host survivor at equal
    load: every drained session's KV bytes stay on-host, and no bulk byte
    crosses the host boundary."""
    async def scenario():
        topo = Topology(hosts=("h0", "h1"))
        # price bytes steeply relative to queue load so the topology term
        # dominates the transient queue wiggle of mid-decode survivors —
        # the deployment knob for "cross-host bandwidth is precious"
        c = Cluster(topology=topo,
                    placement_cost=PlacementCost(topo,
                                                 bytes_per_load=8 * 1024))
        server = PipelineServer(c, MODEL, PARAMS, [1, 3], max_len=64)
        await server.start()
        await _warm(server, 6)
        ps = _prompts(6, seed=4)
        tasks = [asyncio.ensure_future(
            server.generate(p, 12, step_timeout=30.0)) for p in ps]
        await _wait_open(server, 1, len(ps))
        reps = sorted((r for r in server.replicas[1]
                       if r.worker.alive and not r.draining),
                      key=lambda r: -r.open_sessions())
        victim, a, b = reps
        assert victim.open_sessions() >= 1
        # victim + survivor a share h0; survivor b sits across the wire
        topo.assign(victim.worker_id, "h0")
        topo.assign(a.worker_id, "h0")
        topo.assign(b.worker_id, "h1")
        cross0 = c.transport.bulk_cross_host_bytes_sent
        await server.remove_replica(1, victim.worker_id, drain=True,
                                    timeout=60.0)
        await asyncio.gather(*tasks)
        moved = [d for _, k, d in server.events if k == "migrate"]
        assert moved and all(a.worker_id in d for d in moved), moved
        assert c.transport.bulk_cross_host_bytes_sent == cross0
        assert server.migrations.stats()["reprefills_total"] == 0
        c.shutdown()

    arun(scenario(), timeout=300.0)


# ------------------------------------------------------------------ live heal

def test_live_heal_fenced_replica_zero_recompute(arun):
    """Heal of an alive-but-fenced replica with open mid-decode sessions:
    the controller live-migrates its state to the replacement (instantiated
    on the victim's host), bounced clients restore the route from that
    state inside the grace window, and generation finishes with greedy
    token parity and ZERO recomputed tokens — where the PR 3 heal
    re-prefilled every session's full history."""
    async def scenario():
        topo = Topology(hosts=("h0", "h1"), policy="spread")
        c = Cluster(topology=topo)
        server = PipelineServer(c, MODEL, PARAMS, [1, 2], max_len=64)
        await server.start()
        await _warm(server, 4)
        ctrl = ElasticController(server, interval=0.05, scale_stages=[])
        ctrl.start()
        ps = _prompts(4, seed=4)
        wants = [ENGINE.generate(p, 16) for p in ps]
        tasks = [asyncio.ensure_future(
            server.generate(p, 16, step_timeout=30.0)) for p in ps]
        await _wait_open(server, 1, len(ps))
        victim = max((r for r in server.replicas[1]
                      if r.worker.alive and not r.draining),
                     key=lambda r: r.open_sessions())
        n_open = victim.open_sessions()
        victim_host = topo.host_of(victim.worker_id)
        assert n_open >= 1
        _fence(server, victim)
        outs = await asyncio.gather(*tasks)
        await ctrl.stop()
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(got, want)     # greedy parity
        m = server.migrations.stats()
        assert m["heal_migrations_total"] >= n_open, m
        assert m["reprefills_total"] == 0, m             # zero re-prefill
        assert m["recomputed_tokens"] == 0, m            # zero recompute
        assert m["restores_total"] >= n_open, m
        assert ctrl.heals == 1
        # replacement landed on the victim's host (near-placement)
        new = [r.worker_id for r in server.replicas[1]]
        healed = [w for w in new if w != victim.worker_id]
        assert any(topo.host_of(w) == victim_host for w in healed)
        # no session state leaked anywhere after the dust settles
        await asyncio.sleep(0.1)
        assert not any(r.sessions for reps in server.replicas for r in reps)
        c.shutdown()

    arun(scenario(), timeout=300.0)


def test_heal_dead_worker_falls_back_to_snapshot_restore(arun):
    """A dead worker has nothing to hand off: the heal replaces it (same
    host) and the clients' snapshot-restore path replays only the suffix —
    the live-heal change must not regress the PR 3 fallback."""
    async def scenario():
        from repro.core import FailureKind

        c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        server = PipelineServer(c, MODEL, PARAMS, [1, 2], max_len=64,
                                snapshot_interval_s=5.0)
        await server.start()
        await _warm(server, 3)
        ctrl = ElasticController(server, interval=0.05, scale_stages=[])
        ctrl.start()
        ps = _prompts(3, seed=6)
        wants = [ENGINE.generate(p, 12) for p in ps]
        tasks = [asyncio.ensure_future(
            server.generate(p, 12, step_timeout=5.0)) for p in ps]
        await _wait_open(server, 1, len(ps))
        await server.snapshots.sweep()
        victim = max((r for r in server.replicas[1] if r.worker.alive),
                     key=lambda r: r.open_sessions())
        c.kill(victim.worker_id, FailureKind.SILENT_HANG)
        outs = await asyncio.gather(*tasks)
        await ctrl.stop()
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(got, want)
        m = server.migrations.stats()
        assert m["restores_total"] >= 1, m
        assert m["reprefills_total"] == 0, m
        full_history = sum(8 + 12 for _ in ps)
        assert m["recomputed_tokens"] < full_history, m
        assert ctrl.heals >= 1
        c.shutdown()

    arun(scenario(), timeout=300.0)


def test_warm_heal_first_dispatch_beats_cold(arun):
    """A controller heal with fresh executors pre-warms the replacement
    from a peer: its first real dispatch skips the compile the cold path
    pays."""
    async def scenario():
        from repro.core import FailureKind
        from repro.serving.executor import StageExecutor

        c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        server = PipelineServer(c, MODEL, PARAMS, [1, 2], max_len=64)
        await server.start()
        p = _prompts(1, seed=7)[0]
        want = ENGINE.generate(p, 6)
        np.testing.assert_array_equal(
            await server.generate(p, 6, step_timeout=120.0), want)

        ctrl = ElasticController(server, interval=0.05, scale_stages=[],
                                 fresh_executors=True)
        before = {r.worker_id for r in server.replicas[1]}
        victim = server.replicas[1][0].worker_id
        c.kill(victim, FailureKind.SILENT_HANG)
        await asyncio.sleep(0.3)
        await ctrl.step()
        await ctrl.wait_heals()
        assert ctrl.heals == 1
        healed = next(r for r in server.replicas[1]
                      if r.worker_id not in before)
        assert healed.executor is not server.stage_executors[1]
        assert healed.executor.stats["warmed_dispatches"] > 0
        assert server.bootstrap.bootstraps_total == 1

        shape, dtype = healed.executor.warm_profile()["prefill"][0]

        def first_dispatch_s(ex):
            t0 = time.monotonic()
            x = jnp.zeros(shape, jnp.dtype(dtype))
            _, cache = ex.prefill(x)
            step = jnp.zeros((shape[0], 1) + tuple(shape[2:]),
                             jnp.dtype(dtype))
            y, _ = ex.decode(cache, step, min(shape[1], ex.max_len - 1))
            jax.block_until_ready(y)
            return time.monotonic() - t0

        cold = StageExecutor(server.cfg, server.stage_specs[1],
                             server.stage_param_sets[1],
                             max_len=server.max_len)
        cold_s = first_dispatch_s(cold)          # cold heal: full compile
        warm_s = first_dispatch_s(healed.executor)
        assert warm_s < cold_s, (warm_s, cold_s)
        # the warm replica serves token-correct traffic
        np.testing.assert_array_equal(
            await server.generate(p, 6, step_timeout=30.0), want)
        c.shutdown()

    arun(scenario(), timeout=300.0)


def test_heal_warm_falls_back_cold_without_peer(arun):
    """Healing the only replica of a stage has no warm peer: the controller
    must degrade to a cold add, not fail the heal."""
    async def scenario():
        from repro.core import FailureKind

        c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        server = PipelineServer(c, MODEL, PARAMS, [1, 1], max_len=64)
        await server.start()
        toks = _prompts(1, seed=9)[0]
        await server.submit(toks)
        ctrl = ElasticController(server, interval=0.05)
        victim = server.replicas[1][0].worker_id
        c.kill(victim, FailureKind.SILENT_HANG)
        await asyncio.sleep(0.3)
        await ctrl.step()
        await ctrl.wait_heals()
        assert ctrl.heals == 1
        assert server.bootstrap.bootstraps_total == 0    # no peer -> cold
        assert len(server.healthy_replicas(1)) == 1
        await server.submit(toks, timeout=10.0)
        c.shutdown()

    arun(scenario(), timeout=300.0)


def test_concurrent_heals_dont_serialize_on_one_drain(arun):
    """One slow drain must not stall other heals: with a replica whose
    drain can never finish (artificially wedged), a simultaneously fenced
    replica of another stage is still healed promptly."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [2, 2], max_len=64)
        await server.start()
        await server.submit(_prompts(1, seed=1)[0])
        ctrl = ElasticController(server, interval=0.05, scale_stages=[],
                                 heal_drain_timeout_s=2.0)
        slow = server.replicas[0][0]
        fast = server.replicas[1][0]
        _fence(server, slow)
        _fence(server, fast)
        slow.inflight += 1          # wedge: drain can never observe empty
        await ctrl.step()
        # the unwedged heal completes while the wedged drain is still
        # burning its (bounded) timeout
        deadline = time.monotonic() + 1.5
        while ctrl.heals < 1:
            assert time.monotonic() < deadline, "fast heal was stalled"
            await asyncio.sleep(0.01)
        assert any(r.worker_id != fast.worker_id
                   for r in server.replicas[1])
        slow.inflight -= 1          # unwedge; let the slow heal finish too
        await ctrl.wait_heals()
        assert ctrl.heals == 2
        await ctrl.stop()
        c.shutdown()

    arun(scenario(), timeout=300.0)


# ------------------------------------------------------------- int8 margin

def test_int8_margin_check_falls_back_to_fp():
    sess = ENGINE.start_session(_prompts(1, seed=11)[0])
    snap = SessionSnapshot(session_id=3, stage=0, step=sess.t, batch=1,
                           cache=sess.cache, origin="w0")
    noise = quantization_noise(sess.cache)
    assert noise > 0.0
    # thin margin (or no tracked margin at all) -> fp
    blob, used = snapshot_to_blob_checked(snap, codec=INT8, argmax_gap=None)
    assert used == FP
    blob, used = snapshot_to_blob_checked(snap, codec=INT8,
                                          argmax_gap=noise * 0.5)
    assert used == FP
    back = snapshot_from_blob(blob)
    assert back.origin == "w0" and blob_origin(blob) == "w0"
    # comfortable margin -> int8 allowed, and strictly smaller
    wide = noise * 100.0
    assert int8_margin_ok(wide, sess.cache)
    blob8, used = snapshot_to_blob_checked(snap, codec=INT8, argmax_gap=wide)
    assert used == INT8 and len(blob8) < len(blob)


def test_argmax_margin_tracks_tight_logits():
    tight = np.zeros((1, 16), np.float32)
    tight[0, 0] = 1.0
    tight[0, 1] = 1.0 - 1e-6         # near-tie: tiny relative gap
    wide = np.zeros((1, 16), np.float32)
    wide[0, 0] = 10.0
    assert argmax_margin(tight) < 1e-4 < argmax_margin(wide)


def test_int8_snapshots_demote_per_session_and_count(arun):
    """An int8 SnapshotStore demotes thin-margin sessions to fp and the
    counter surfaces in MetricsHub.migration_metrics()."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 1], max_len=64,
                                snapshot_interval_s=5.0,
                                snapshot_codec=INT8)
        await server.start()
        task = asyncio.ensure_future(
            server.generate(_prompts(1, seed=5)[0], 8, step_timeout=30.0))
        await _wait_open(server, 0, 1)
        # the serving layer tracked real margins at the last stage
        assert server._margins_wanted()
        while not server.session_margins:
            await asyncio.sleep(0.005)
        sid = next(iter(server.session_margins))
        # force one thin-margin sweep, then one generous sweep
        server.session_margins[sid] = 0.0
        await server.snapshots.sweep()
        assert server.snapshots.int8_fallbacks >= 1
        hub = MetricsHub(server)
        assert hub.migration_metrics()["int8_fp_fallbacks"] >= 1
        await task
        c.shutdown()

    arun(scenario(), timeout=300.0)


# ------------------------------------------------------------ restore origin

def test_snapshot_store_records_origin(arun):
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 1], max_len=64,
                                snapshot_interval_s=5.0)
        await server.start()
        task = asyncio.ensure_future(
            server.generate(_prompts(1, seed=5)[0], 6, step_timeout=30.0))
        await _wait_open(server, 1, 1)
        await server.snapshots.sweep()
        rep = server.replicas[1][0]
        sid = next(iter(rep.sessions))
        snap = server.snapshots.latest(sid, 1)
        assert snap is not None and snap.origin == rep.worker_id
        await task
        c.shutdown()

    arun(scenario())

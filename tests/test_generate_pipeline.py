"""Generative data plane: typed envelopes, per-stage KV sessions, continuous
microbatched decode, and state-aware fault/drain recovery.

The acceptance bar (ISSUE 2): pipelined greedy ``generate()`` is
token-identical to single-engine ``ServeEngine.generate``; a mid-generation
replica kill and a drain-with-open-sessions both complete every session with
the correct final tokens and zero client-visible failures.
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.control import MetricsHub, StageSnapshot, TokenRatePolicy
from repro.core import Cluster, FailureKind
from repro.core.transport import SerializeCodec, Transport, payload_nbytes
from repro.models import DENSE, BlockGroup, build_model
from repro.serving import (
    Envelope,
    Kind,
    PipelineServer,
    ReplicaRouter,
    ServeEngine,
    StageExecutor,
)

CFG = get_smoke("llama3.2-1b").with_(num_layers=4,
                                     groups=(BlockGroup(DENSE, 4),))
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
ENGINE = ServeEngine(MODEL, PARAMS, max_len=64)


def _prompts(n, seq=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, (1, seq)) for _ in range(n)]


# ---------------------------------------------------------------- envelopes

def test_envelope_byte_accounting():
    x = jnp.ones((4, 8), jnp.float32)
    env = Envelope(1, 2, Kind.DECODE, payload=x)
    assert env.nbytes == x.nbytes
    assert payload_nbytes((7, x)) == x.nbytes            # legacy tuple
    assert payload_nbytes([x, {"a": x}]) == 2 * x.nbytes
    assert payload_nbytes(None) == 0

    t = Transport()
    t.send("w", 0, 1, env)
    t.send("w", 0, 1, (3, x))
    assert t.bytes_sent == 2 * x.nbytes                  # was 0 before

    ser = Transport(codec=SerializeCodec())
    ser.send("w", 0, 1, np.ones(16, np.float32))
    # encoded wire size: pickle bytes, strictly more than the raw tensor
    assert ser.bytes_sent > 16 * 4


def test_router_session_pins():
    r = ReplicaRouter(["a", "b"])
    r.pin(1, "a")
    r.pin(2, "b")
    assert r.pinned(1) == "a" and r.pinned_sessions == 2
    r.mark_broken("a")                   # fenced world drops its pins
    assert r.pinned(1) is None
    r.remove("b")                        # graceful retirement too
    assert r.pinned(2) is None and r.pinned_sessions == 0


def test_communicator_pending_prunes_to_empty(arun):
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 1], max_len=64)
        await server.start()
        await server.generate(_prompts(1)[0], 3, step_timeout=30.0)
        await asyncio.sleep(0.05)
        # every op completed: the pending dict must not retain zero entries
        for worker in c.workers.values():
            assert all(v > 0 for v in worker.comm.pending.values()), \
                worker.comm.pending
        c.shutdown()

    arun(scenario())


# ----------------------------------------------------------------- executor

def test_stage_executor_decode_many_matches_single():
    """Fused multi-session decode at heterogeneous positions == single."""
    ex = StageExecutor.for_model(MODEL, PARAMS, max_len=32)
    rng = np.random.default_rng(7)
    caches, xs, ts, singles = [], [], [], []
    for i, s in enumerate((4, 6)):       # sessions at different positions
        toks = jnp.asarray(rng.integers(0, CFG.vocab_size, (1, s)), jnp.int32)
        logits, cache = ex.prefill(toks)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        caches.append(cache)
        xs.append(nxt)
        ts.append(s)
        singles.append(ex.decode(cache, nxt, s)[0])
    fused = ex.decode_many(caches, xs, ts)
    # vmapped-batch vs single execution reorders float accumulations; the
    # drift is <5e-5 absolute on O(1) logits — the greedy argmax (what the
    # token-parity acceptance actually rides on) must be identical
    for (y, _), want in zip(fused, singles):
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-2, atol=1e-3)
        np.testing.assert_array_equal(np.argmax(np.asarray(y), -1),
                                      np.argmax(np.asarray(want), -1))


# ----------------------------------------------------------------- pipeline

def test_pipeline_generate_matches_engine(arun):
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 2, 1], max_len=64)
        await server.start()
        p = _prompts(1, seed=1)[0]
        want = ENGINE.generate(p, 6)
        got = await server.generate(p, 6, step_timeout=30.0)
        np.testing.assert_array_equal(got, want)
        c.shutdown()

    arun(scenario())


def test_pipeline_generate_concurrent_microbatched(arun):
    """8 concurrent sessions: all token-identical to the single engine, and
    the decode micro-scheduler fuses steps (fewer dispatches than steps)."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 1], max_len=64)
        await server.start()
        ps = _prompts(8, seed=2)
        wants = [ENGINE.generate(p, 5) for p in ps]
        outs = await asyncio.gather(
            *[server.generate(p, 5, step_timeout=30.0) for p in ps])
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(got, want)
        stats = server.replica_stats()
        steps = sum(s["decode_steps"] for s in stats.values())
        batches = sum(s["decode_batches"] for s in stats.values())
        assert steps == 2 * 8 * 4        # 2 stages x 8 sessions x 4 decodes
        assert batches < steps, (batches, steps)
        c.shutdown()

    arun(scenario())


def test_generate_survives_replica_kill(arun):
    """Kill a middle replica mid-generation: every affected session re-prefills
    on the survivor and finishes with the exact greedy tokens."""
    async def scenario():
        c = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        server = PipelineServer(c, MODEL, PARAMS, [1, 2, 1], max_len=64)
        await server.start()
        ps = _prompts(5, seed=3)
        wants = [ENGINE.generate(p, 6) for p in ps]
        tasks = [asyncio.ensure_future(
            server.generate(p, 6, step_timeout=8.0)) for p in ps]
        await asyncio.sleep(0.05)
        c.kill(server.replicas[1][0].worker_id, FailureKind.SILENT_HANG)
        outs = await asyncio.gather(*tasks)   # zero client-visible failures
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(got, want)
        c.shutdown()

    arun(scenario(), timeout=300.0)


def test_generate_drain_with_open_sessions(arun):
    """Scale down a replica holding live KV sessions: drain unpins them, the
    clients relocate via re-prefill, and no token is lost."""
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 2, 1], max_len=64)
        await server.start()
        ps = _prompts(5, seed=4)
        wants = [ENGINE.generate(p, 6) for p in ps]
        tasks = [asyncio.ensure_future(
            server.generate(p, 6, step_timeout=8.0)) for p in ps]
        await asyncio.sleep(0.05)
        gone = await server.remove_replica(1, drain=True, timeout=60.0)
        outs = await asyncio.gather(*tasks)
        for want, got in zip(wants, outs):
            np.testing.assert_array_equal(got, want)
        assert gone not in server.replica_stats()
        assert len(server.healthy_replicas(1)) == 1
        c.shutdown()

    arun(scenario(), timeout=300.0)


def test_metrics_see_tokens_and_sessions(arun):
    async def scenario():
        c = Cluster()
        server = PipelineServer(c, MODEL, PARAMS, [1, 1], max_len=64)
        await server.start()
        hub = MetricsHub(server, alpha=1.0)
        hub.poll()
        await server.generate(_prompts(1, seed=5)[0], 5, step_timeout=30.0)
        await asyncio.sleep(0.05)        # let in-flight FINISHes land
        snaps = hub.poll()
        assert all(s.tokens_per_s > 0 for s in snaps), snaps
        assert all(s.open_sessions == 0 for s in snaps)
        stats = server.replica_stats()
        assert all(s["tokens_out"] == 4 for s in stats.values())
        c.shutdown()

    arun(scenario())


# ------------------------------------------------------------------ policy

def _snap(**kw):
    base = dict(stage=0, t=0.0, n_replicas=2, n_failed=0, queue_total=0,
                queue_per_replica=0.0, throughput=0.0, latency_s=0.0,
                replicas=[], tokens_per_s=0.0, open_sessions=0)
    base.update(kw)
    return StageSnapshot(**base)


def test_token_rate_policy():
    pol = TokenRatePolicy(target_tokens_per_s=100.0, shrink_frac=0.25,
                          shrink_open_sessions=2.0)
    up = pol.decide(_snap(tokens_per_s=500.0))
    assert up.delta > 0
    # under capacity but too many open sessions to displace -> hold
    held = pol.decide(_snap(tokens_per_s=10.0, open_sessions=9))
    assert held.hold
    down = pol.decide(_snap(tokens_per_s=10.0, open_sessions=2))
    assert down.delta == -1

"""Observability subsystem: causal spans, flight recorder, export surface.

The acceptance bar (ISSUE 6): a generate session that loses its decode
replica mid-generation reconstructs as ONE connected trace tree — RETRY
bounce, snapshot restore (or re-prefill), and the resumed decode all parent
back to the client's root span, with no orphans; default-on tracing stays
within the overhead budget (gated in bench_generate); flight-recorder dumps
are schema-versioned; retired replicas leave no per-id state behind.
"""
import asyncio
import json
import os
import sys
import time

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.configs import get_smoke
from repro.control import MetricsHub
from repro.core import Cluster, FailureKind
from repro.models import DENSE, BlockGroup, build_model
from repro.obs import (
    FlightRecorder,
    TraceContext,
    Tracer,
    connected_tree,
    validate_dump,
)
from repro.obs.export import render_prometheus, write_trace_artifact
from repro.serving import PipelineServer

CFG = get_smoke("llama3.2-1b").with_(num_layers=2,
                                     groups=(BlockGroup(DENSE, 2),))
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))


def _prompts(n, seq=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, (1, seq)) for _ in range(n)]


async def _warm(server, sessions=4):
    ps = _prompts(sessions, seed=99)
    for _ in range(2):
        await asyncio.gather(*(server.generate(p, 3, step_timeout=120.0)
                               for p in ps))
    for seq in (12, 20):
        await server.generate(_prompts(1, seq=seq, seed=90 + seq)[0], 2,
                              step_timeout=120.0)


async def _wait_open(server, stage, n, timeout=15.0):
    deadline = time.monotonic() + timeout
    while sum(r.open_sessions() for r in server.replicas[stage]) < n:
        if time.monotonic() > deadline:
            break
        await asyncio.sleep(0.005)


# --------------------------------------------------------------- tracer unit
def test_tracer_ring_summary_and_overflow():
    tr = Tracer(capacity=4)
    root = tr.begin()
    assert (root.trace_id, root.parent_id) == (root.span_id, 0)
    child = tr.begin(root)
    assert child.trace_id == root.trace_id
    assert child.parent_id == root.span_id
    for i in range(6):                       # 6 records through a 4-slot ring
        tr.record(tr.begin(root), "decode_step", 0.0, 0.01 * (i + 1))
    assert tr.recorded == 6 and tr.dropped == 2
    spans = tr.spans()
    assert len(spans) == 4                   # oldest two overwritten
    assert [round(s["dt"], 2) for s in spans] == [0.03, 0.04, 0.05, 0.06]
    s = tr.summary()["decode_step"]
    assert s["count"] == 4 and s["max_s"] == pytest.approx(0.06)
    # spans() filtered to one tree only sees that tree
    assert all(x["trace_id"] == root.trace_id
               for x in tr.spans(root.trace_id))


def test_tracer_disabled_and_orphan_guard():
    tr = Tracer(enabled=False)
    assert tr.begin() is None
    tr.record(None, "session", 0.0, 1.0)     # no-op, no raise
    assert tr.recorded == 0 and tr.spans() == []
    on = Tracer()
    # span() on a None parent must NOT mint an orphan root: untraced
    # envelopes (tracing toggled off upstream) stay invisible
    assert on.span(None, "prefill", time.monotonic()) is None
    assert on.recorded == 0


def test_connected_tree_detects_orphans_and_forests():
    def mk(span, parent, trace=1):
        return {"trace_id": trace, "span_id": span, "parent_id": parent,
                "kind": "x", "worker": "", "t0": 0.0, "dt": 0.0,
                "detail": ""}
    assert connected_tree([mk(1, 0), mk(2, 1), mk(3, 1), mk(4, 2)])
    assert not connected_tree([mk(1, 0), mk(3, 2)])          # orphan parent
    assert not connected_tree([mk(1, 0), mk(2, 0)])          # two roots
    assert not connected_tree([])


# ------------------------------------------------------- flight recorder unit
def test_flight_recorder_dump_schema(tmp_path):
    rec = FlightRecorder(capacity=8, dump_dir=str(tmp_path), name="t")
    for i in range(12):
        rec.record("scale_decision", stage=0, delta=1, reason=f"vote {i}")
    assert len(rec) == 8 and rec.recorded == 12
    d = rec.dump("unhandled_failure", worker="w1",
                 oddball=object())            # coerced to str at dump time
    assert validate_dump(d)
    assert d["dropped"] == 4
    assert d["reason"] == "unhandled_failure"
    assert all(ev["kind"] == "scale_decision" for ev in d["events"])
    assert isinstance(d["context"]["oddball"], str)
    assert rec.dumps_total == 1 and rec.last_dump is d
    assert list(rec.dump_log) == [d]
    # the file landed and round-trips
    with open(d["path"]) as f:
        assert validate_dump(json.load(f))
    # tampering breaks validation
    assert not validate_dump({**d, "schema": "flightrec/v0"})
    assert not validate_dump({k: v for k, v in d.items() if k != "events"})


# ----------------------------------------------------------- export surface
def test_render_prometheus_format():
    text = render_prometheus({
        "latency": {"ttft_s": 0.25, "skip_me": "not-a-number"},
        "stage": {"replicas": {"0": 2, "1": 3}},
    }, prefix="repro")
    assert "# TYPE repro_latency_ttft_s gauge" in text
    assert "repro_latency_ttft_s 0.25" in text
    assert 'repro_stage_replicas{id="0"} 2' in text
    assert 'repro_stage_replicas{id="1"} 3' in text
    assert "skip_me" not in text


def test_trace_artifact_writer(tmp_path):
    tr = Tracer()
    tr.record(tr.begin(), "session", 0.0, 1.0)
    rec = FlightRecorder()
    rec.record("pin_flip", session=7)
    path = str(tmp_path / "TRACE_t.json")
    art = write_trace_artifact(path, suite="t", tracer=tr, recorder=rec,
                               extra={"phases": {"a": {}}})
    with open(path) as f:
        on_disk = json.load(f)
    assert on_disk["schema"] == "trace/v1"
    assert on_disk["suite"] == "t"
    assert on_disk["span_summary"]["session"]["count"] == 1
    assert on_disk["flight_events"] == 1
    assert art["spans_recorded"] == 1


def test_bench_json_schema(tmp_path):
    from benchmarks.common import write_bench_json
    rows = [("x_tokens_per_s", 10.0, "d1"), ("y_p50_ms", 2.0, ""),
            ("z_bytes", 3.0, ""), ("w_speedup", 2.5, ""),
            ("q_recover_s/variant", 0.5, "per-variant row")]
    doc = write_bench_json(str(tmp_path / "BENCH_t.json"), suite="t",
                           rows=rows, raw={"k": "v"}, tiny=True)
    with open(tmp_path / "BENCH_t.json") as f:
        on_disk = json.load(f)
    assert on_disk == json.loads(json.dumps(doc, default=str))
    assert doc["schema"] == "bench/v1" and doc["suite"] == "t"
    assert doc["tiny"] is True and "git_rev" in doc and "wall_clock" in doc
    m = doc["metrics"]
    assert m["x_tokens_per_s"] == {"value": 10.0, "unit": "tokens/s",
                                   "derived": "d1"}
    assert m["y_p50_ms"]["unit"] == "ms"
    assert m["z_bytes"]["unit"] == "bytes"
    assert m["w_speedup"]["unit"] == "ratio"
    assert m["q_recover_s/variant"]["unit"] == "s"   # unit from metric part
    assert doc["raw"] == {"k": "v"}


# ----------------------------------------------- end-to-end: recovery trace
def test_kill_recovery_yields_one_connected_trace(arun):
    """Kill the decode replica mid-generation (snapshots on): every
    session's RETRY bounce, restore (or re-prefill) and resumed decode must
    reconstruct as ONE tree under the client root — no orphan spans."""
    async def scenario():
        cluster = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        server = PipelineServer(cluster, MODEL, PARAMS, [1, 2], max_len=64,
                                snapshot_interval_s=0.05)
        await server.start()
        sessions, new_tokens = 3, 8
        await _warm(server, sessions)
        ps = _prompts(sessions, seed=2)
        tasks = [asyncio.ensure_future(
            server.generate(p, new_tokens, step_timeout=3.0))
            for p in ps]
        await _wait_open(server, 1, sessions)
        await server.snapshots.sweep()
        victim = max((r for r in server.replicas[1] if r.worker.alive),
                     key=lambda r: r.open_sessions())
        cluster.kill(victim.worker_id, FailureKind.SILENT_HANG)
        outs = await asyncio.gather(*tasks)
        assert all(o.shape == (1, new_tokens) for o in outs)

        tracer = server.tracer
        roots = [s for s in tracer.spans() if s["kind"] == "session"]
        # warm-up + measured sessions each own exactly one root
        assert len(roots) >= sessions
        recovery_kinds = {"restore", "restore_replay", "reprefill"}
        recovered_trees = 0
        for root in roots:
            tree = tracer.spans(root["trace_id"])
            assert connected_tree(tree), \
                f"trace {root['trace_id']} has orphans: {tree}"
            kinds = {s["kind"] for s in tree}
            assert {"ttft", "prefill"} <= kinds, kinds
            if kinds & recovery_kinds:
                recovered_trees += 1
                # the resumed decode rides the SAME tree as the recovery
                assert "decode_step" in kinds or "decode" in kinds
        assert recovered_trees >= 1, \
            "kill recovered without any recovery span reaching a trace"
        # bounced steps surface in-tree, not as losses: some client span
        # carries the retry/error detail
        details = {s["detail"] for s in tracer.spans()}
        assert any(d.startswith(("retry", "error=")) for d in details), \
            details
        m = server.migrations.stats()
        assert m["restores_total"] + m["reprefills_total"] >= 1
        cluster.shutdown()

    arun(scenario(), timeout=300.0)


# ------------------------------------------------- retired-state regression
def test_retired_replicas_leave_no_per_id_state(arun):
    """Scale/heal cycles must not grow per-world or per-replica maps:
    hub EWMAs, event mirrors, broken-world sets, manager wiring, and the
    transport's dead-set all evict retired ids."""
    async def scenario():
        cluster = Cluster(heartbeat_interval=0.01, heartbeat_timeout=0.08)
        server = PipelineServer(cluster, MODEL, PARAMS, [1, 1], max_len=64,
                                snapshot_interval_s=0.05)
        await server.start()
        hub = MetricsHub(server)
        await _warm(server, 2)
        hub.poll()
        # two add/drain cycles plus one kill/teardown cycle
        retired = []
        for _ in range(2):
            wid = await server.add_replica(1)
            await server.generate(_prompts(1, seed=5)[0], 3,
                                  step_timeout=120.0)
            hub.poll()
            await server.remove_replica(1, wid, drain=True, timeout=30.0)
            retired.append(wid)
        wid = await server.add_replica(1)
        cluster.kill(wid, FailureKind.SILENT_HANG)
        # let the watchdogs fence it, then tear it down like a heal would
        deadline = time.monotonic() + 10.0
        while wid not in server.failed_replicas(1):
            assert time.monotonic() < deadline, "fence never landed"
            await asyncio.sleep(0.01)
        await server.remove_replica(1, wid, drain=False)
        retired.append(wid)
        hub.poll()

        live = {r.worker_id for reps in server.replicas for r in reps}
        for d in (hub._prev, hub._tput, hub._lat, hub._toks,
                  hub._ttft, hub._declat):
            assert set(d) <= live, f"hub kept retired state: {set(d) - live}"
        assert hub._subscribed <= set(server.cluster.workers)
        for wid in retired:
            assert wid not in server._wired_managers
            assert wid not in server.cluster.transport._dead, \
                "teardown left the transport dead-set entry behind"
        # no fenced world of a torn-down replica lingers
        for world in server.broken_worlds:
            assert any(world in w.manager.worlds
                       for w in cluster.workers.values()), \
                f"broken_worlds kept a removed world {world}"
        # bounded event mirrors: the trim paths engage past the cap
        for _ in range(9000):
            server._event("synthetic", "x")
        assert len(server.events) <= 8192
        mgr = next(iter(cluster.workers.values())).manager
        for _ in range(9000):
            mgr._event("synthetic", "w")
        assert len(mgr.events) <= 8192
        cluster.shutdown()

    arun(scenario(), timeout=300.0)


# ------------------------------------------------------- hub export smoke
def test_metricshub_prometheus_and_trace_summary(arun):
    async def scenario():
        cluster = Cluster()
        server = PipelineServer(cluster, MODEL, PARAMS, [1, 1], max_len=64)
        await server.start()
        hub = MetricsHub(server)
        await server.generate(_prompts(1, seed=7)[0], 4, step_timeout=120.0)
        hub.poll()
        ts = hub.trace_summary()
        assert ts["session"]["count"] >= 1
        assert ts["ttft"]["count"] >= 1 and ts["ttft"]["p50_s"] > 0
        assert ts["decode_step"]["count"] >= 1
        text = hub.export_prometheus()
        assert "# TYPE repro_obs_spans_recorded gauge" in text
        assert 'repro_stage_replicas{id="0"} 1' in text
        assert "repro_span_session_count" in text
        assert "repro_executor_decode_steps" in text
        assert "repro_migration_migrations_total" in text
        cluster.shutdown()

    arun(scenario(), timeout=300.0)

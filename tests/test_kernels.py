"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ flash attention

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kv,hd", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 128, 4, 1, 128),     # MQA, wide head
    (2, 64, 2, 2, 32),       # small, block < 128
])
def test_flash_attention_causal(b, s, h, kv, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (b, s, h, hd), dtype)
    k = rand(ks[1], (b, s, kv, hd), dtype)
    v = rand(ks[2], (b, s, kv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("window", [32, 96])
def test_flash_attention_sliding_window(window):
    b, s, h, kv, hd = 1, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (rand(kk, (b, s, hh, hd), jnp.float32)
               for kk, hh in zip(ks, (h, kv, kv)))
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_softcap():
    b, s, h, kv, hd = 1, 128, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (rand(kk, (b, s, hh, hd), jnp.float32)
               for kk, hh in zip(ks, (h, kv, kv)))
    out = ops.flash_attention(q, k, v, causal=True, softcap=50.0)
    want = ref.flash_attention_ref(q, k, v, causal=True, softcap=50.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    b, s, h, kv, hd = 2, 128, 4, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (rand(kk, (b, s, hh, hd), jnp.float32)
               for kk, hh in zip(ks, (h, kv, kv)))
    out = ops.flash_attention(q, k, v, causal=False)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ decode attention

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,kv,hd", [
    (2, 512, 4, 4, 64),
    (1, 1024, 8, 2, 128),
    (4, 256, 4, 1, 64),
])
def test_decode_attention(b, t, h, kv, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = rand(ks[0], (b, 1, h, hd), dtype)
    k = rand(ks[1], (b, t, kv, hd), dtype)
    v = rand(ks[2], (b, t, kv, hd), dtype)
    # ragged validity: row i valid up to t//(i+2)
    pos = jnp.arange(t)[None, :]
    mask = pos <= jnp.asarray([t // (i + 2) for i in range(b)])[:, None]
    out = ops.decode_attention(q, k, v, mask=mask)
    want = ref.decode_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_decode_attention_ring_occupancy_mask():
    """Ring-buffer style mask: every slot valid (steady-state SWA)."""
    b, t, h, kv, hd = 2, 128, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q, k, v = (rand(kk, (b, tt, hh, hd), jnp.float32)
               for kk, (tt, hh) in zip(ks, ((1, h), (t, kv), (t, kv))))
    mask = jnp.ones((b, t), bool)
    out = ops.decode_attention(q, k, v, mask=mask, softcap=30.0)
    want = ref.decode_attention_ref(q, k, v, mask, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------- ssd scan

@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 32, 16, 16),
    (2, 128, 4, 64, 32, 32),
    (1, 256, 2, 64, 64, 64),
    (2, 64, 8, 64, 128, 16),   # mamba2-like head/state dims
])
def test_ssd_scan(b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    x = rand(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(rand(ks[1], (b, s, h), jnp.float32))
    a = -jnp.exp(rand(ks[2], (h,), jnp.float32) * 0.5)
    bmat = rand(ks[3], (b, s, n), jnp.float32) * 0.5
    cmat = rand(ks[4], (b, s, n), jnp.float32) * 0.5
    y, st = ops.ssd_scan(x, dt, a, bmat, cmat, chunk=chunk)
    y_ref, st_ref = ref.ssd_scan_ref(x, dt, a, bmat, cmat, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_scan_chunk_invariance():
    """y must not depend on the chunking (associativity of the recurrence)."""
    b, s, h, p, n = 1, 128, 2, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = rand(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(rand(ks[1], (b, s, h), jnp.float32))
    a = -jnp.exp(rand(ks[2], (h,), jnp.float32) * 0.5)
    bmat = rand(ks[3], (b, s, n), jnp.float32) * 0.5
    cmat = rand(ks[4], (b, s, n), jnp.float32) * 0.5
    y16, _ = ops.ssd_scan(x, dt, a, bmat, cmat, chunk=16)
    y64, _ = ops.ssd_scan(x, dt, a, bmat, cmat, chunk=64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64),
                               rtol=1e-4, atol=1e-4)


def test_ssd_scan_matches_step_recurrence():
    """Chunked kernel == token-by-token ssd_step recurrence."""
    from repro.models.ssm import ssd_step
    b, s, h, p, n = 1, 32, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    x = rand(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(rand(ks[1], (b, s, h), jnp.float32))
    a = -jnp.exp(rand(ks[2], (h,), jnp.float32) * 0.5)
    bmat = rand(ks[3], (b, s, n), jnp.float32) * 0.5
    cmat = rand(ks[4], (b, s, n), jnp.float32) * 0.5
    y, st = ops.ssd_scan(x, dt, a, bmat, cmat, chunk=8)
    state = jnp.zeros((b, h, p, n), jnp.float32)
    for i in range(s):
        state, yi = ssd_step(state, x[:, i], dt[:, i], a, bmat[:, i],
                             cmat[:, i])
        np.testing.assert_allclose(np.asarray(yi), np.asarray(y[:, i]),
                                   rtol=1e-3, atol=1e-3, err_msg=f"i={i}")
    np.testing.assert_allclose(np.asarray(state), np.asarray(st),
                               rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------------ rmsnorm

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(64, 128), (4, 32, 256), (512, 64)])
@pytest.mark.parametrize("plus_one", [False, True])
def test_rmsnorm(shape, dtype, plus_one):
    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    x = rand(ks[0], shape, dtype)
    w = rand(ks[1], shape[-1:], dtype)
    out = ops.rmsnorm(x, w, plus_one=plus_one)
    want = ref.rmsnorm_ref(x, w, plus_one=plus_one)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))

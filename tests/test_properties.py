"""Hypothesis property tests on system invariants."""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip cleanly if absent
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import Cluster, Store
from repro.models.moe import _local_moe
from repro.models.ssm import ssd_reference
from repro.serving import ReplicaRouter

FAST = settings(max_examples=15, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


# ------------------------------------------------------------------- store

@FAST
@given(st.dictionaries(st.text(min_size=1, max_size=8),
                       st.integers(), max_size=16))
def test_store_set_get_roundtrip(d):
    s = Store()
    for k, v in d.items():
        s.set(k, v)
    for k, v in d.items():
        assert s.get(k) == v
    assert set(s.keys()) == set(d)


@FAST
@given(st.lists(st.integers(min_value=-100, max_value=100), min_size=1,
                max_size=30))
def test_store_add_sums(increments):
    s = Store()
    for inc in increments:
        s.add("ctr", inc)
    assert s.get("ctr") == sum(increments)


# ------------------------------------------------------------- communicator

@FAST
@given(st.lists(st.floats(min_value=-1e3, max_value=1e3,
                          allow_nan=False), min_size=1, max_size=8),
       st.integers(min_value=2, max_value=4))
def test_all_reduce_equals_sum(values, world_size):
    """all_reduce(sum) over any world size == elementwise sum of inputs."""
    async def scenario():
        c = Cluster()
        workers = [c.worker(f"W{i}") for i in range(world_size)]
        await asyncio.gather(*[
            w.manager.initialize_world("w", i, world_size)
            for i, w in enumerate(workers)])
        inputs = [jnp.asarray(values, jnp.float32) * (i + 1)
                  for i in range(world_size)]
        outs = await asyncio.gather(*[
            w.comm.all_reduce(inputs[i], "w")
            for i, w in enumerate(workers)])
        want = sum(np.asarray(x, np.float64) for x in inputs)
        for o in outs:
            np.testing.assert_allclose(np.asarray(o, np.float64), want,
                                       rtol=1e-5)
        c.shutdown()

    asyncio.run(asyncio.wait_for(scenario(), 30))


@FAST
@given(st.integers(min_value=1, max_value=20),
       st.integers(min_value=2, max_value=4))
def test_scatter_gather_inverse(n_per_rank, world_size):
    """gather(scatter(chunks)) == chunks, any sizes."""
    async def scenario():
        c = Cluster()
        workers = [c.worker(f"W{i}") for i in range(world_size)]
        await asyncio.gather(*[
            w.manager.initialize_world("w", i, world_size)
            for i, w in enumerate(workers)])
        chunks = [jnp.full((n_per_rank,), float(i)) for i in range(world_size)]

        async def rank(i):
            got = await workers[i].comm.scatter(
                chunks if i == 0 else None, 0, "w")
            return await workers[i].comm.gather(got, 0, "w")

        results = await asyncio.gather(*[rank(i) for i in range(world_size)])
        for i, chunk in enumerate(chunks):
            np.testing.assert_allclose(results[0][i], chunk)
        c.shutdown()

    asyncio.run(asyncio.wait_for(scenario(), 30))


# ------------------------------------------------------------------ router

@FAST
@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=60))
def test_router_conserves_and_balances(n_replicas, n_requests):
    r = ReplicaRouter([f"w{i}" for i in range(n_replicas)])
    picks = [r.pick() for _ in range(n_requests)]
    assert sum(r.routed.get(f"w{i}", 0) for i in range(n_replicas)) \
        == n_requests
    counts = [picks.count(f"w{i}") for i in range(n_replicas)]
    assert max(counts) - min(counts) <= 1   # round robin fairness


# -------------------------------------------------------------------- moe

@FAST
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_moe_dropless_capacity_processes_every_choice(seed):
    """With capacity >= T*k, no token is dropped: output == dense mixture."""
    key = jax.random.PRNGKey(seed)
    t, d, e, k = 12, 8, 4, 2
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (1, t, d))
    router = jax.random.normal(ks[1], (d, e))
    wg = jax.random.normal(ks[2], (e, d, 16)) * 0.1
    wu = jax.random.normal(ks[3], (e, d, 16)) * 0.1
    wd = jax.random.normal(ks[4], (e, 16, d)) * 0.1

    class Cfg:
        experts_per_token = k
        num_experts = e
        moe_capacity_factor = float(e)

    y, _ = _local_moe(Cfg, x, router, wg, wu, wd, e_offset=0, e_local=e,
                      capacity=t * k, model_axis=None)
    # dense reference: full softmax-top-k mixture
    logits = (x.reshape(t, d) @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    w, ids = jax.lax.top_k(probs, k)
    w = w / w.sum(-1, keepdims=True)
    want = np.zeros((t, d), np.float32)
    xf = np.asarray(x.reshape(t, d))
    for i in range(t):
        for j in range(k):
            eidx = int(ids[i, j])
            h = np.asarray(jax.nn.silu(xf[i] @ wg[eidx]) * (xf[i] @ wu[eidx]))
            want[i] += float(w[i, j]) * (h @ np.asarray(wd[eidx]))
    np.testing.assert_allclose(np.asarray(y.reshape(t, d)), want,
                               rtol=2e-4, atol=2e-4)


# -------------------------------------------------------------------- ssd

@FAST
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.sampled_from([8, 16, 32]))
def test_ssd_chunk_size_invariance(seed, chunk):
    """SSD output must not depend on chunking (recurrence associativity)."""
    key = jax.random.PRNGKey(seed)
    b, s, h, p, n = 1, 32, 2, 8, 4
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    cm = jax.random.normal(ks[4], (b, s, n)) * 0.5
    y1, s1 = ssd_reference(x, dt, a, bm, cm, chunk=chunk)
    y2, s2 = ssd_reference(x, dt, a, bm, cm, chunk=s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)

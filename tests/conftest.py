"""Shared test helpers.

NOTE: do NOT set XLA_FLAGS / host device count here — smoke tests and
benchmarks must see the single real CPU device; only launch/dryrun.py forces
512 placeholder devices (and it does so before importing jax).
"""
import asyncio

import pytest


def run_async(coro, timeout: float = 60.0):
    """Drive a coroutine to completion on a fresh event loop."""
    async def _with_timeout():
        return await asyncio.wait_for(coro, timeout)

    return asyncio.run(_with_timeout())


@pytest.fixture
def arun():
    return run_async

"""Per-architecture smoke tests: reduced config, one forward/train step +
one decode step on CPU; assert output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models import build_model

B, S = 2, 64


def make_batch(cfg, key):
    kt, kf = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(kf, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            kf, (B, cfg.encoder_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["input_embeds"] = jax.random.normal(
            kf, (B, S, cfg.d_model), jnp.float32) * 0.02
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        batch["mrope_positions"] = jnp.stack([pos, pos, pos])
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(metrics["ce"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_grads_finite(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    def loss_only(p):
        return model.loss(p, batch)[0]

    grads = jax.jit(jax.grad(loss_only))(params)
    flat = jax.tree.leaves(grads)
    assert flat, "no grads produced"
    for g in flat:
        assert np.all(np.isfinite(np.asarray(g, np.float64))), \
            f"{arch}: non-finite grad"
    # at least some gradient signal
    total = sum(float(jnp.sum(jnp.abs(g))) for g in flat)
    assert total > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = 32
    cache = model.init_cache(B, max_len, jnp.float32)
    if cfg.family == "audio":
        frames = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_frames, cfg.d_model),
            jnp.float32)
        cache = model.prime_cache(params, cache, frames)

    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(lambda p, c, tk, t: model.decode_step(
        p, c, tk, t,
        **({"mrope_positions": jnp.full((3, B, 1), t, jnp.int32)}
           if cfg.family == "vlm" else {})))

    for t in range(3):
        logits, cache = step(params, cache, tok, jnp.int32(t))
        assert logits.shape == (B, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits, np.float64))), \
            f"{arch}: non-finite logits at t={t}"
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)


def test_decode_matches_prefill_dense():
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = get_smoke("llama3.2-1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, 8), 0, cfg.vocab_size)

    full_logits, _ = model.forward(params, toks)

    cache = model.init_cache(B, 8, jnp.float32)
    for t in range(8):
        logits, cache = model.decode_step(
            params, cache, toks[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3)


def test_decode_matches_prefill_ssm():
    cfg = get_smoke("mamba2-2.7b").with_(ssm_chunk=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, 8), 0, cfg.vocab_size)

    full_logits, _ = model.forward(params, toks)

    cache = model.init_cache(B, 8, jnp.float32)
    for t in range(8):
        logits, cache = model.decode_step(
            params, cache, toks[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            rtol=5e-3, atol=5e-3)


def test_sliding_window_ring_cache_matches_full():
    """Ring-buffer SWA decode == full-cache decode with window mask."""
    cfg = get_smoke("mixtral-8x7b")   # window 32 > test len -> also test short
    # dropless capacity: prefill vs decode parity requires no capacity drops
    cfg = cfg.with_(sliding_window=4, moe_capacity_factor=float(cfg.num_experts),
                    groups=(type(cfg.groups[0])(
                        cfg.groups[0].kind, cfg.groups[0].count, window=4),))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, 10), 0, cfg.vocab_size)

    full_logits, _ = model.forward(params, toks)
    cache = model.init_cache(B, 4, jnp.float32)   # ring cache of window size
    for t in range(10):
        logits, cache = model.decode_step(
            params, cache, toks[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            rtol=5e-3, atol=5e-3, err_msg=f"t={t}")


def test_param_counts_sane():
    cfg = get_smoke("llama3.2-1b")
    n = cfg.param_count()
    assert n > 0
    moe = get_smoke("mixtral-8x7b")
    assert moe.active_param_count() < moe.param_count()

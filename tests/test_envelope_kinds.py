"""The Envelope.Kind numbering contract.

Kind values are frozen wire constants: a rolling upgrade has old and new
binaries decoding each other's envelopes, so renumbering an existing kind
is a silent protocol break (a VERIFY parsed as a SWAP). New kinds append;
nothing is ever renumbered or reused. This test is the contract's
enforcement — it fails the moment someone reorders the enum, and the
pinned table below must only ever *grow*.
"""
from repro.serving.envelope import (
    Kind,
    ROLE_BOTH,
    ROLE_CAPABLE,
    ROLE_DECODE,
    ROLE_DRAFT,
    ROLE_PREFILL,
)

#: append-only — a value in this table may never change
PINNED = {
    "SCORE": 0,
    "PREFILL": 1,
    "DECODE": 2,
    "FINISH": 3,
    "RETRY": 4,
    "HANDOFF": 5,
    "LOAD": 6,
    "UNLOAD": 7,
    "SWAP": 8,
    "PROPOSE": 9,
    "VERIFY": 10,
}


def test_kind_values_are_pinned():
    for name, value in PINNED.items():
        assert Kind[name].value == value, (
            f"Kind.{name} moved from {value} to {Kind[name].value}: "
            "kind values are frozen wire constants")


def test_every_kind_is_in_the_pinned_table():
    # a new kind must land here (appended) in the same change that adds it
    assert {k.name for k in Kind} == set(PINNED), (
        "new Kind member missing from the pinned table — append it, "
        "never renumber")


def test_kind_values_are_unique_and_dense():
    values = sorted(k.value for k in Kind)
    assert values == list(range(len(values))), values


def test_role_capability_map():
    # 'both' worlds hold target-model state: they serve prefill and decode
    # but never draft proposals (draft replicas run the draft model)
    assert ROLE_BOTH in ROLE_CAPABLE[ROLE_PREFILL]
    assert ROLE_BOTH in ROLE_CAPABLE[ROLE_DECODE]
    assert ROLE_CAPABLE[ROLE_DRAFT] == (ROLE_DRAFT,)
    assert ROLE_DRAFT not in ROLE_CAPABLE[ROLE_PREFILL]
    assert ROLE_DRAFT not in ROLE_CAPABLE[ROLE_DECODE]

"""Assigned input shapes + ShapeDtypeStruct input builders for the dry-run.

``input_specs`` returns abstract stand-ins (no allocation) for every model
input of a (config, shape, step-kind) combination — the same pattern the
dry-run uses for params and caches. Decode shapes lower ``serve_step`` (one
token against a seq_len-deep cache); train/prefill lower full sequences.

The audio/vlm frontends are stubs per the assignment: whisper receives frame
embeddings (B, 1500, D); qwen2-vl receives fused token+patch embeddings
(B, S, D) plus (3, B, S) M-RoPE position streams.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

#: archs allowed to run long_500k (sub-quadratic context handling); see
#: DESIGN.md §Shape-skips.
LONG_CONTEXT_OK = {
    "mamba2-2.7b": "SSM O(1) state",
    "zamba2-2.7b": "SSM state + SWA shared attention",
    "gemma2-2b": "native local/global alternation (ring caches on local)",
    "mixtral-8x7b": "native sliding-window attention",
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.arch_id not in LONG_CONTEXT_OK:
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md)"
    return True, ""


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Abstract train/prefill batch for ``loss``/``forward``."""
    b, s = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.activation_dtype)
    batch: dict = {"tokens": _i32(b, s), "targets": _i32(b, s)}
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_frames, cfg.d_model), act)
    if cfg.family == "vlm":
        batch["input_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), act)
        batch["mrope_positions"] = _i32(3, b, s)
    return batch


def decode_specs(cfg: ModelConfig, shape: InputShape, model) -> dict:
    """Abstract one-token decode inputs: tokens, position t, cache."""
    b, s = shape.global_batch, shape.seq_len
    kw: dict = {
        "tokens": _i32(b, 1),
        "t": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": model.abstract_cache(b, s),
    }
    if cfg.family == "vlm":
        kw["mrope_positions"] = _i32(3, b, 1)
    return kw


def batch_logical_axes(cfg: ModelConfig) -> dict:
    axes: dict = {"tokens": ("batch", "seq"), "targets": ("batch", "seq")}
    if cfg.family == "audio":
        axes["frames"] = ("batch", "frames", "act_embed")
    if cfg.family == "vlm":
        axes["input_embeds"] = ("batch", "seq", "act_embed")
        axes["mrope_positions"] = (None, "batch", "seq")
    return axes

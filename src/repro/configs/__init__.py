"""Architecture registry: the 10 assigned architectures + the paper's own
serving-pipeline scenario config.

Usage: ``get_config("qwen3-8b")``, ``get_smoke("qwen3-8b")``,
``--arch <id>`` in launch scripts.
"""
import difflib
from importlib import import_module

from repro.models import ModelConfig

_MODULES = {
    "mamba2-2.7b": "mamba2_2p7b",
    "llama3.2-1b": "llama3p2_1b",
    "qwen3-8b": "qwen3_8b",
    "yi-34b": "yi_34b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "whisper-base": "whisper_base",
    "gemma2-2b": "gemma2_2b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "zamba2-2.7b": "zamba2_2p7b",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        hint = difflib.get_close_matches(arch_id, _MODULES, n=1)
        raise KeyError(
            f"unknown arch '{arch_id}'; known: {sorted(_MODULES)}"
            + (f" — did you mean '{hint[0]}'?" if hint else ""))
    return import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE


def list_archs() -> list[str]:
    return list(ARCH_IDS)

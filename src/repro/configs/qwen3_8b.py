"""qwen3-8b — dense GQA with per-head qk-norm [hf:Qwen/Qwen3-8B]."""
from repro.models import DENSE, BlockGroup, ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    groups=(BlockGroup(DENSE, 36),),
    source_cite="hf:Qwen/Qwen3-8B",
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512, groups=(BlockGroup(DENSE, 2),),
    param_dtype="float32", activation_dtype="float32",
)

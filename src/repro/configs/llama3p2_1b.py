"""llama3.2-1b — small dense llama3, GQA [hf:meta-llama/Llama-3.2-1B]."""
from repro.models import DENSE, BlockGroup, ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500000.0,
    groups=(BlockGroup(DENSE, 16),),
    tie_embeddings=True,
    source_cite="hf:meta-llama/Llama-3.2-1B",
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512, groups=(BlockGroup(DENSE, 2),),
    param_dtype="float32", activation_dtype="float32",
)

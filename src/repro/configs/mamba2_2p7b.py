"""mamba2-2.7b — SSD (state-space duality), attention-free [arXiv:2405.21060]."""
from repro.models import MAMBA2, BlockGroup, ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,          # d_inner = 5120 -> 80 SSD heads of dim 64
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=256,
    groups=(BlockGroup(MAMBA2, 64),),
    tie_embeddings=True,
    source_cite="arXiv:2405.21060 (Mamba2 SSD); 2.7b config",
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, vocab_size=512, ssm_state=32, ssm_chunk=16,
    groups=(BlockGroup(MAMBA2, 2),),
    param_dtype="float32", activation_dtype="float32",
)

"""gemma2-2b — alternating local(SWA-4096)/global attention, logit softcaps,
sandwich norms [arXiv:2408.00118]."""
from repro.models import GEMMA_PAIR, BlockGroup, ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-2b",
    family="dense",
    num_layers=26,           # 13 (local, global) pairs
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    gemma_norm_plus_one=True,
    tie_embeddings=True,
    groups=(BlockGroup(GEMMA_PAIR, 13),),
    source_cite="arXiv:2408.00118 (Gemma 2); 2b config",
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512, sliding_window=16,
    groups=(BlockGroup(GEMMA_PAIR, 1),),
    param_dtype="float32", activation_dtype="float32",
)

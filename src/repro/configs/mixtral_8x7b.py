"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088]."""
from repro.models import MOE, BlockGroup, ModelConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=14336,
    sliding_window=4096,
    rope_theta=1e6,
    groups=(BlockGroup(MOE, 32, window=4096),),
    source_cite="arXiv:2401.04088 (Mixtral of Experts)",
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, moe_d_ff=512, vocab_size=512, num_experts=4,
    experts_per_token=2, sliding_window=32,
    groups=(BlockGroup(MOE, 2, window=32),),
    param_dtype="float32", activation_dtype="float32",
)

"""whisper-base — enc-dec transformer backbone; conv/mel frontend stubbed
[arXiv:2212.04356]."""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-base",
    family="audio",
    num_layers=6,            # decoder layers
    encoder_layers=6,
    encoder_frames=1500,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    tie_embeddings=True,
    source_cite="arXiv:2212.04356 (Whisper); base config",
)

SMOKE = CONFIG.with_(
    num_layers=2, encoder_layers=2, encoder_frames=32, d_model=128,
    num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
    param_dtype="float32", activation_dtype="float32",
)

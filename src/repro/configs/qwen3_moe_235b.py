"""qwen3-moe-235b-a22b — 128-expert top-8 MoE, GQA kv=4, qk-norm
[hf:Qwen/Qwen3-30B-A3B family scaled per assignment]."""
from repro.models import MOE, BlockGroup, ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=1536,
    qk_norm=True,
    rope_theta=1e6,
    groups=(BlockGroup(MOE, 94),),
    source_cite="hf:Qwen/Qwen3-235B-A22B (assignment: Qwen3-30B-A3B card)",
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=128, moe_d_ff=128, vocab_size=512, num_experts=4,
    experts_per_token=2, groups=(BlockGroup(MOE, 2),),
    param_dtype="float32", activation_dtype="float32",
)

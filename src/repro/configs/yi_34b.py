"""yi-34b — llama-architecture dense GQA at 34B [arXiv:2403.04652]."""
from repro.models import DENSE, BlockGroup, ModelConfig

CONFIG = ModelConfig(
    arch_id="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5e6,
    groups=(BlockGroup(DENSE, 60),),
    source_cite="arXiv:2403.04652 (Yi)",
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512, groups=(BlockGroup(DENSE, 2),),
    param_dtype="float32", activation_dtype="float32",
)

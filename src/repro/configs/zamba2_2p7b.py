"""zamba2-2.7b — Mamba2 backbone + shared attention block with per-invocation
LoRA deltas [arXiv:2411.15242].

Adaptations recorded in DESIGN.md: the shared transformer block is invoked
once per 6 mamba layers (9 invocations over the 54-layer backbone) with
rank-32 LoRA q/k/v deltas per invocation; the shared attention uses a 4096
sliding window so the arch qualifies for ``long_500k`` decode with O(window)
attention state on top of the O(1) SSM state.
"""
from repro.models import HYBRID, BlockGroup, ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    num_layers=54,           # mamba2 layers; + 9 shared-attn invocations
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    sliding_window=4096,
    shared_attn_every=6,
    shared_attn_lora_rank=32,
    groups=(BlockGroup(HYBRID, 9, mamba_per_step=6),),
    source_cite="arXiv:2411.15242 (Zamba2)",
)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
    d_ff=512, vocab_size=512, ssm_state=16, ssm_chunk=16, sliding_window=32,
    shared_attn_lora_rank=8,
    groups=(BlockGroup(HYBRID, 2, mamba_per_step=2),),
    param_dtype="float32", activation_dtype="float32",
)

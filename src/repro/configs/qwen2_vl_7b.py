"""qwen2-vl-7b — VLM language backbone with M-RoPE; ViT tower stubbed
[arXiv:2409.12191]."""
from repro.models import DENSE, BlockGroup, ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    mrope_sections=(16, 24, 24),   # (temporal, height, width): sums to hd/2
    rope_theta=1e6,
    groups=(BlockGroup(DENSE, 28),),
    source_cite="arXiv:2409.12191 (Qwen2-VL); 7b config",
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512, mrope_sections=(8, 12, 12),
    groups=(BlockGroup(DENSE, 2),),
    param_dtype="float32", activation_dtype="float32",
)

"""Jitted public wrappers over the Pallas kernels.

Model code calls these with model-layout tensors ((B, S, H, hd) etc.); the
wrappers transpose to kernel layout, choose block sizes, and run the kernel
in interpret mode on CPU (the container target) or compiled on real TPU.
Set ``REPRO_PALLAS_INTERPRET=0`` to force compiled mode.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_bhd
from .flash_attention import flash_attention_bhsd
from .paged_attention import paged_decode_attention_bhd
from .rmsnorm import rmsnorm_rows
from .ssd_scan import ssd_scan_kernel


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (prefer 128-multiples)."""
    best = 1
    for cand in range(1, min(n, target) + 1):
        if n % cand == 0:
            best = cand
    return best


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None) -> jax.Array:
    """q (B,S,H,hd); k,v (B,T,K,hd) -> (B,S,H,hd). Model layout in/out."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq = _pick_block(qt.shape[2], 128)
    bk = _pick_block(kt.shape[2], 128)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               softcap=softcap, scale=scale, block_q=bq,
                               block_k=bk, interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("softcap", "scale"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     mask: jax.Array, softcap: Optional[float] = None,
                     scale: Optional[float] = None) -> jax.Array:
    """q (B,1,H,hd); k,v (B,T,K,hd); mask (B,1,T) or (B,T) -> (B,1,H,hd)."""
    if mask.ndim == 3:
        mask = mask[:, 0, :]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bk = _pick_block(kt.shape[2], 512)
    out = decode_attention_bhd(qt, kt, vt, mask, softcap=softcap, scale=scale,
                               block_k=bk, interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("softcap", "scale"))
def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           lengths: jax.Array, *,
                           softcap: Optional[float] = None,
                           scale: Optional[float] = None) -> jax.Array:
    """q (B,1,H,hd); k_pages,v_pages (P,page,K,hd); page_table (B,NP) int32;
    lengths (B,) -> (B,1,H,hd). Pad table entries should point at the pool's
    reserved scratch page; validity comes from ``lengths`` alone."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k_pages.transpose(0, 2, 1, 3)
    vt = v_pages.transpose(0, 2, 1, 3)
    out = paged_decode_attention_bhd(qt, kt, vt, page_table, lengths,
                                     softcap=softcap, scale=scale,
                                     interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int) -> tuple[jax.Array, jax.Array]:
    """Same contract as models.ssm.ssd_reference: x (B,S,H,P), dt (B,S,H),
    a (H,), b/c (B,S,N) -> (y (B,S,H,P), final_state (B,H,P,N))."""
    xdt = x * dt[..., None]
    da = dt * a[None, None, :]
    return ssd_scan_kernel(xdt.astype(jnp.float32), da.astype(jnp.float32),
                           b, c, chunk=chunk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eps", "plus_one"))
def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            plus_one: bool = False) -> jax.Array:
    """x (..., D), w (D,)."""
    shape = x.shape
    rows = 1
    for dim in shape[:-1]:
        rows *= dim
    x2 = x.reshape(rows, shape[-1])
    br = _pick_block(rows, 256)
    out = rmsnorm_rows(x2, w, eps=eps, plus_one=plus_one, block_rows=br,
                       interpret=_interpret())
    return out.reshape(shape)

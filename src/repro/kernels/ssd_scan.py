"""Mamba2 SSD chunked-scan Pallas TPU kernel.

The SSD decomposition (arXiv:2405.21060) splits the sequence into chunks:
a quadratic intra-chunk term (MXU-friendly (L x N) @ (N x L) and (L x L) @
(L x P) matmuls) plus a linear cross-chunk state recurrence. The recurrence
is inherently sequential, which maps perfectly onto the TPU grid: the
innermost grid axis walks chunks in order while the running (P, N) state
persists in VMEM scratch — the HBM round-trip the CUDA implementation needs
between its parallel chunk pass and its recurrence pass disappears.

grid = (batch, heads, num_chunks); per step the kernel pulls one chunk of
x·dt (L, P), decay logits (L,), and B/C (L, N) into VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

NEG_INF = -1e30


def _kernel(xdt_ref, da_ref, b_ref, c_ref, y_ref, state_out_ref, state_scr, *,
            block_l: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xdt = xdt_ref[0][:, 0, :].astype(jnp.float32)          # (L, P)
    da = da_ref[0][:, 0].astype(jnp.float32)               # (L,)
    b = b_ref[0].astype(jnp.float32)                       # (L, N)
    c = c_ref[0].astype(jnp.float32)                       # (L, N)
    state = state_scr[...]                                 # (P, N)

    da_cum = jnp.cumsum(da)                                # (L,)
    # intra-chunk: scores[i, j] = (c_i . b_j) * exp(da_cum_i - da_cum_j), j <= i
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (L, L)
    seg = da_cum[:, None] - da_cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (block_l, block_l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (block_l, block_l), 1)
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    y = jax.lax.dot(scores * decay, xdt,
                    preferred_element_type=jnp.float32)    # (L, P)

    # cross-chunk: y += exp(da_cum) * (c @ state^T)
    y = y + jnp.exp(da_cum)[:, None] * jax.lax.dot_general(
        c, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # (L, P)

    # state update: S <- exp(da_sum) S + sum_l exp(da_sum - da_cum_l) xdt_l b_l^T
    da_sum = da_cum[-1]
    w = jnp.exp(da_sum - da_cum)                           # (L,)
    state_scr[...] = jnp.exp(da_sum) * state + jax.lax.dot_general(
        xdt * w[:, None], b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # (P, N)

    y_ref[0] = y[:, None, :].astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _finish():
        state_out_ref[0, 0] = state_scr[...].astype(state_out_ref.dtype)


def ssd_scan_kernel(xdt: jax.Array, da: jax.Array, b: jax.Array, c: jax.Array,
                    *, chunk: int, interpret: bool = True
                    ) -> tuple[jax.Array, jax.Array]:
    """xdt (B,S,H,P) = x*dt; da (B,S,H) = dt*a; b,c (B,S,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s, h, p = xdt.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    grid = (bsz, h, nc)
    kernel = functools.partial(_kernel, block_l=chunk)

    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bb, hh, ic: (bb, ic, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bb, hh, ic: (bb, ic, hh)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, ic: (bb, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, ic: (bb, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bb, hh, ic: (bb, ic, hh, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bb, hh, ic: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), xdt.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(xdt, da, b, c)
    return y, state

"""Paged single-token decode attention Pallas TPU kernel.

Same memory-bound regime as ``decode_attention.py`` but the KV cache lives in
a shared page pool instead of one contiguous (B, T, ...) buffer: each session
owns a page table of physical page indices and the kernel gathers K/V blocks
through it. The page table and per-session lengths ride in as scalar-prefetch
operands so the k/v BlockSpec index maps can compute the HBM -> VMEM DMA
source *before* the kernel body runs — the gather costs nothing extra over
the contiguous kernel's sequential streaming.

Grid = (batch, q_heads, pages); innermost axis reduces with the same
online-softmax VMEM scratch discipline as ``decode_attention._kernel``.
Validity is derived in-kernel from ``lengths`` (pos < length), which masks
both the partially-filled last page and any pad table entries (pad slots
point at physical page 0, the pool's reserved scratch page).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

NEG_INF = -1e30


def _kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *,
            scale: float, softcap: Optional[float], page_size: int):
    del pt_ref  # consumed by the BlockSpec index maps
    b = pl.program_id(0)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                   # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                   # (page, hd)
    v = v_ref[0, 0].astype(jnp.float32)                   # (page, hd)

    # Validity from the session length: covers the partial last page and any
    # pad entries in the page table (those gather scratch-page garbage, which
    # is neutralized here before it can touch the softmax).
    pos = ik * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)                     # (1, page)
    valid = pos < len_ref[b]                              # (1, page)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_decode_attention_bhd(q: jax.Array,
                               k_pages: jax.Array, v_pages: jax.Array,
                               page_table: jax.Array, lengths: jax.Array, *,
                               softcap: Optional[float] = None,
                               scale: Optional[float] = None,
                               interpret: bool = True) -> jax.Array:
    """q (B,H,1,hd); k_pages,v_pages (P,K,page,hd); page_table (B,NP) int32;
    lengths (B,) int32. -> (B,H,1,hd)."""
    bsz, h, _, hd = q.shape
    _, kv, page_size, _ = k_pages.shape
    n_pages = page_table.shape[1]
    group = h // kv
    scale = hd ** -0.5 if scale is None else scale
    page_table = page_table.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)

    grid = (bsz, h, n_pages)
    kernel = functools.partial(_kernel, scale=scale, softcap=softcap,
                               page_size=page_size)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd),
                         lambda b, hh, ik, pt, ln: (b, hh, 0, 0)),
            pl.BlockSpec((1, 1, page_size, hd),
                         lambda b, hh, ik, pt, ln, g=group:
                         (pt[b, ik], hh // g, 0, 0)),
            pl.BlockSpec((1, 1, page_size, hd),
                         lambda b, hh, ik, pt, ln, g=group:
                         (pt[b, ik], hh // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd),
                               lambda b, hh, ik, pt, ln: (b, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, h, 1, hd), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(page_table, lengths, q, k_pages, v_pages)

"""Pallas API compatibility across jax versions.

Newer jax exposes ``pltpu.CompilerParams``; jax <= 0.4.x ships the same
dataclass as ``pltpu.TPUCompilerParams``. Kernels import the name from here
so they run on either.
"""
from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or _pltpu.TPUCompilerParams

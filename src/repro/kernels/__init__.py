"""Pallas TPU kernels for the serving/training hot spots.

MultiWorld itself is a communication control plane (no kernel contribution);
these kernels are the substrate hot spots of the assigned architectures:
flash attention (prefill), decode attention (KV-cache streaming), the Mamba2
SSD chunked scan, and RMSNorm. Each has a jitted wrapper in ``ops`` and a
pure-jnp oracle in ``ref``; tests sweep shapes/dtypes and assert_allclose.
"""
from . import ops, ref

__all__ = ["ops", "ref"]

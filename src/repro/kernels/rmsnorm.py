"""RMSNorm Pallas TPU kernel.

Rowwise: one grid step normalizes a (BR, D) tile held in VMEM; the scale
vector is broadcast from a single (D,)-tile. Reduction in f32 regardless of
input dtype. Simple, but the densest norm traffic in decode (every layer,
every token) so worth owning the tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float, plus_one: bool):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)
    scale = (1.0 + w) if plus_one else w
    o_ref[...] = (y * scale[None, :]).astype(o_ref.dtype)


def rmsnorm_rows(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
                 plus_one: bool = False, block_rows: int = 256,
                 interpret: bool = True) -> jax.Array:
    """x (R, D), w (D,) -> (R, D)."""
    r, d = x.shape
    block_rows = min(block_rows, r)
    assert r % block_rows == 0, (r, block_rows)

    return pl.pallas_call(
        functools.partial(_kernel, eps=eps, plus_one=plus_one),
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, w)

"""Single-token decode attention Pallas TPU kernel.

Decode is memory-bound: the whole KV cache streams HBM -> VMEM once per step
while compute is O(T·hd) per head. The kernel therefore tiles only the KV
sequence: grid = (batch, q_heads, num_kv_blocks), innermost axis reducing
with the same online-softmax VMEM scratch as the prefill kernel. A validity
mask (B, T) expresses both full-cache (`pos <= t`) and ring-buffer sliding
window occupancy, so one kernel serves all cache layouts.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, softcap: Optional[float]):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                   # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                   # (BK, hd)
    v = v_ref[0, 0].astype(jnp.float32)                   # (BK, hd)
    valid = mask_ref[0] != 0                              # (BK,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (1,BK)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(valid[None, :], jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_bhd(q: jax.Array, k: jax.Array, v: jax.Array,
                         mask: jax.Array, *,
                         softcap: Optional[float] = None,
                         scale: Optional[float] = None,
                         block_k: int = 512,
                         interpret: bool = True) -> jax.Array:
    """q (B,H,1,hd); k,v (B,K,T,hd); mask (B,T) bool/int. -> (B,H,1,hd)."""
    bsz, h, _, hd = q.shape
    _, kv, t, _ = k.shape
    group = h // kv
    block_k = min(block_k, t)
    assert t % block_k == 0, (t, block_k)
    scale = hd ** -0.5 if scale is None else scale
    mask = mask.astype(jnp.int8)

    grid = (bsz, h, t // block_k)
    kernel = functools.partial(_kernel, scale=scale, softcap=softcap)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd), lambda b, hh, ik: (b, hh, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, hh, ik, g=group: (b, hh // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, hh, ik, g=group: (b, hh // g, ik, 0)),
            pl.BlockSpec((1, block_k), lambda b, hh, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, hh, ik: (b, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(q, k, v, mask)

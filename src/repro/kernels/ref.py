"""Pure-jnp oracles for every Pallas kernel (the per-kernel ground truth).

The model code's reference paths reuse the same math (models/attention.py,
models/ssm.py), so kernel == ref == model-reference by construction.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.attention import attend_reference, causal_mask
from repro.models.common import rms_norm
from repro.models.ssm import ssd_reference


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None) -> jax.Array:
    """q (B,S,H,hd); k,v (B,T,K,hd) -> (B,S,H,hd)."""
    s, t = q.shape[1], k.shape[1]
    hd = q.shape[-1]
    scale = hd ** -0.5 if scale is None else scale
    if causal:
        mask = causal_mask(s, t, window)
    else:
        mask = jnp.ones((s, t), bool)
        if window is not None:
            mask &= causal_mask(s, t, window) | ~causal_mask(s, t, None)
    return attend_reference(q, k, v, mask=mask, cap=softcap, scale=scale)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         mask: jax.Array, *,
                         softcap: Optional[float] = None,
                         scale: Optional[float] = None) -> jax.Array:
    """q (B,1,H,hd); k,v (B,T,K,hd); mask (B,T) -> (B,1,H,hd)."""
    hd = q.shape[-1]
    scale = hd ** -0.5 if scale is None else scale
    return attend_reference(q, k, v, mask=mask[:, None, :].astype(bool),
                            cap=softcap, scale=scale)


def paged_decode_attention_ref(q: jax.Array,
                               k_pages: jax.Array, v_pages: jax.Array,
                               page_table: jax.Array, lengths: jax.Array, *,
                               softcap: Optional[float] = None,
                               scale: Optional[float] = None) -> jax.Array:
    """q (B,1,H,hd); k_pages,v_pages (P,page,K,hd); page_table (B,NP) int32;
    lengths (B,) -> (B,1,H,hd). Gathers pages to a contiguous cache and
    delegates to the contiguous decode oracle."""
    bsz = q.shape[0]
    _, page, kv, hd = k_pages.shape
    n_pages = page_table.shape[1]
    k = k_pages[page_table].reshape(bsz, n_pages * page, kv, hd)
    v = v_pages[page_table].reshape(bsz, n_pages * page, kv, hd)
    mask = jnp.arange(n_pages * page)[None, :] < lengths[:, None]
    return decode_attention_ref(q, k, v, mask, softcap=softcap, scale=scale)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                 c: jax.Array, *, chunk: int):
    """Same contract as models.ssm.ssd_reference."""
    return ssd_reference(x, dt, a, b, c, chunk)


def rmsnorm_ref(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
                plus_one: bool = False) -> jax.Array:
    return rms_norm(x, w, eps, plus_one)

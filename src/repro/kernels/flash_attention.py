"""Flash attention (prefill) Pallas TPU kernel.

Online-softmax blockwise attention with explicit VMEM tiling:

* grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the innermost axis is
  the softmax reduction — TPU grids execute sequentially, so the running
  (m, l, acc) state lives in VMEM scratch across kv steps.
* BlockSpecs pull (BQ, hd) of Q and (BK, hd) of K/V into VMEM per step; the
  MXU sees (BQ x hd) @ (hd x BK) and (BQ x BK) @ (BK x hd) matmuls with
  128-aligned tiles by default.
* GQA is expressed in the K/V index_map (q head h reads kv head h // group),
  so no KV broadcast is ever materialized.
* Supports causal masking, sliding windows and gemma-style logit softcap.
  Fully-masked kv blocks are handled by masking the *probabilities* (not
  just the scores), keeping the online-softmax state finite.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], block_q: int, block_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                   # (BQ, hd)
    k = k_ref[0, 0].astype(jnp.float32)                   # (BK, hd)
    v = v_ref[0, 0].astype(jnp.float32)                   # (BK, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # (BQ, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)                           # masked-out -> 0
    alpha = jnp.exp(m_prev - m_new)                       # (BQ, 1)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: Optional[int] = None,
                         softcap: Optional[float] = None,
                         scale: Optional[float] = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True) -> jax.Array:
    """q (B,H,S,hd); k,v (B,K,T,hd) with H % K == 0. Returns (B,H,S,hd)."""
    bsz, h, s, hd = q.shape
    _, kv, t, _ = k.shape
    group = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    scale = hd ** -0.5 if scale is None else scale

    grid = (bsz, h, s // block_q, t // block_k)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, hh, iq, ik: (b, hh, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, hh, iq, ik, g=group: (b, hh // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, hh, iq, ik, g=group: (b, hh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, hh, iq, ik: (b, hh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q, k, v)

"""SnapshotStore: periodic background snapshots of open decode sessions.

Planned transitions (drain, rebalance) move live state directly via
:class:`~repro.statexfer.manager.MigrationManager`; an *unplanned* kill gives
no such window — whatever state the dead replica held is simply gone. The
SnapshotStore bounds that loss: a background task walks every healthy
replica's open sessions and writes each one's stage snapshot into the
cluster :class:`~repro.core.store.Store` under a per-pipeline namespace.
After a kill, restore replays only the tokens generated since the latest
snapshot instead of re-prefilling the whole history.

Key hygiene (the PR 1 store-key leak, snapshot edition): every key carries a
TTL (a dead SnapshotStore can never leak keys forever), finished sessions
are dropped eagerly via :meth:`drop_session`, and each sweep prunes keys for
sessions no longer open on any replica — a replica teardown (world removal)
therefore reclaims its sessions' keys within one sweep once their FINISH
lands, without any teardown-path coupling.

Encoding cost rides on a worker thread (`run_in_executor`): the device→host
copy + pickle of a KV cache must not stall the serve loop. The (cache,
step) pair is captured synchronously before handing off, so a concurrent
decode step — which *replaces* ``sess.cache`` rather than mutating it —
can never tear a snapshot.
"""
from __future__ import annotations

import asyncio
import functools
import time
from typing import Optional

from .codec import (
    FP,
    INT8,
    SessionSnapshot,
    SnapshotTransferError,
    apply_snapshot_delta,
    blob_base_step,
    blob_step,
    snapshot_delta_to_blob,
    snapshot_from_blob,
    snapshot_to_blob_checked,
)


class SnapshotStore:
    def __init__(self, server, *, interval_s: float = 0.05,
                 ttl_s: float = 60.0, codec: str = FP,
                 gc_grace_s: float = 15.0, delta: bool = True,
                 rebase_every: int = 8) -> None:
        self.server = server
        self.store = server.cluster.store
        self.interval_s = interval_s
        self.ttl_s = ttl_s
        self.codec = codec
        #: delta snapshots: once a session-stage has a full base, later
        #: sweeps re-encode only the decode positions since that base
        #: (~seq_len/interval_tokens smaller), refreshed cumulatively
        #: against the same base; fp-only and full-cache-only — anything
        #: else (int8, ring/SSM stages) takes full snapshots as before
        self.delta = delta
        #: write a fresh full base every N delta sweeps: bounds both the
        #: delta's own growth and the blast radius of a torn base
        self.rebase_every = rebase_every
        #: how long a session must be absent from every *alive* replica
        #: before the sweep reclaims its keys. A killed replica's sessions
        #: vanish from the alive view instantly, but the client only learns
        #: of the loss at its step timeout — eager deletion here would
        #: destroy exactly the snapshots restore is about to need. FINISH
        #: still reclaims immediately via drop_session; TTL is the backstop.
        self.gc_grace_s = gc_grace_s
        self._missing_since: dict[int, float] = {}
        self._task: Optional[asyncio.Task] = None
        self._stop = asyncio.Event()
        #: (sid, stage) -> last snapshotted step, to skip unchanged sessions
        self._last_step: dict[tuple[int, int], int] = {}
        #: per-stage tree of cache sequence-axis indices (delta slicing)
        self._seq_axes: dict[int, object] = {}
        #: (sid, stage) -> cursor of the stored full base snapshot
        self._base_step: dict[tuple[int, int], int] = {}
        #: (sid, stage) -> delta sweeps since the last full base
        self._deltas_since_base: dict[tuple[int, int], int] = {}
        # -- counters (MetricsHub reads these) -----------------------------
        self.snapshots_taken = 0
        self.snapshot_bytes_total = 0
        self.delta_snapshots_taken = 0
        self.delta_bytes_total = 0
        #: per-snapshot byte sizes not yet folded into the hub's EWMA
        self.bytes_log: list[int] = []
        self.pruned_keys = 0
        #: int8 snapshots demoted to fp because the session's argmax margin
        #: was too thin against the cache's quantization noise
        self.int8_fallbacks = 0

    # ------------------------------------------------------------- namespace
    def prefix(self) -> str:
        return f"snap/{self.server.name}/"

    def key(self, sid: int, stage: int) -> str:
        return f"{self.prefix()}{sid}/{stage}"

    def delta_key(self, sid: int, stage: int) -> str:
        return f"{self.prefix()}{sid}/{stage}/delta"

    # ------------------------------------------------------------- lifecycle
    def start(self, spawn=None) -> None:
        """Start the background sweep. ``spawn`` lets the owner tie the
        task to a worker's lifecycle (PipelineServer passes the client
        worker's spawn so Cluster.shutdown reaps it)."""
        if self._task is None or self._task.done():
            self._stop = asyncio.Event()
            coro = self.run()
            self._task = (spawn(coro) if spawn is not None
                          else asyncio.ensure_future(coro))

    async def stop(self) -> None:
        self._stop.set()
        if self._task is not None:
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None

    async def run(self) -> None:
        while not self._stop.is_set():
            try:
                await self.sweep()
            except asyncio.CancelledError:
                raise
            except Exception:  # noqa: BLE001 — a torn snapshot pass must not
                pass           # end background snapshotting forever
            try:
                await asyncio.wait_for(self._stop.wait(), self.interval_s)
            except asyncio.TimeoutError:
                pass

    # ----------------------------------------------------------------- sweep
    async def sweep(self) -> int:
        """One snapshot pass over every open session; returns #taken."""
        loop = asyncio.get_event_loop()
        taken = 0
        open_sids: set[int] = set()
        for reps in self.server.replicas:
            for rep in reps:
                if not rep.worker.alive:
                    continue
                for sid, sess in list(rep.sessions.items()):
                    open_sids.add(sid)
                    if self._last_step.get((sid, rep.stage)) == sess.step:
                        continue
                    # capture atomically (no await between reads): a decode
                    # step swaps sess.cache/step as a pair; a paged handle
                    # is frozen to a view for the same reason — the pool
                    # arrays it pins are immutable, decode swaps in new ones
                    cache = sess.cache
                    if hasattr(cache, "freeze"):
                        cache = cache.freeze()
                    snap = SessionSnapshot(
                        session_id=sid, stage=rep.stage, step=sess.step,
                        batch=sess.batch, cache=cache,
                        origin=rep.worker_id)
                    await self._write_one(loop, snap,
                                          trace=getattr(sess, "trace", None))
                    self._last_step[(sid, rep.stage)] = sess.step
                    self.snapshots_taken += 1
                    taken += 1
        # bytes_log is drained by MetricsHub when one is polling; without a
        # hub it must not grow for the process lifetime — keep the tail
        if len(self.bytes_log) > 1024:
            del self.bytes_log[:len(self.bytes_log) - 512]
        self._gc(open_sids)
        return taken

    def _stage_seq_axes(self, stage: int):
        """Structural sequence-axis tree for the stage's cache (the delta
        codec must not guess the axis from sizes — head_dim can collide
        with max_len)."""
        axes = self._seq_axes.get(stage)
        if axes is None:
            from repro.serving.partition import stage_cache_seq_axes

            axes = stage_cache_seq_axes(self.server.cfg,
                                        self.server.stage_specs[stage])
            self._seq_axes[stage] = axes
        return axes

    def _delta_eligible(self, snap: SessionSnapshot) -> bool:
        key = (snap.session_id, snap.stage)
        base = self._base_step.get(key)
        return (self.delta and self.codec == FP
                and base is not None and snap.step > base
                and self._deltas_since_base.get(key, 0) < self.rebase_every
                and self.server.stage_executors[snap.stage].full_cache
                # the cursor bookkeeping can outlive the blob (TTL expiry
                # while the session idled): a delta against a vanished base
                # restores nothing — write a fresh full base instead
                and self.store.get(self.key(*key)) is not None)

    async def _write_one(self, loop, snap: SessionSnapshot,
                         trace=None) -> None:
        """Write one session-stage snapshot: a delta against the stored
        base when eligible, a fresh full base otherwise."""
        t0 = time.monotonic()
        key = (snap.session_id, snap.stage)
        # a delta was due — base present, cursor advanced, rebase not yet
        # scheduled — so falling through to a full base below is the
        # fail-closed delta->base path (vanished base blob, non-full cache)
        wanted_delta = (self.delta and self.codec == FP
                        and self._base_step.get(key) is not None
                        and snap.step > self._base_step.get(key, 0)
                        and self._deltas_since_base.get(key, 0)
                        < self.rebase_every)
        if self._delta_eligible(snap):
            blob = await loop.run_in_executor(
                None, functools.partial(
                    snapshot_delta_to_blob, snap,
                    base_step=self._base_step[key],
                    seq_len=self.server.max_len,
                    seq_axes=self._stage_seq_axes(snap.stage)))
            self.store.set(self.delta_key(*key), blob, ttl=self.ttl_s)
            self._deltas_since_base[key] = \
                self._deltas_since_base.get(key, 0) + 1
            self.delta_snapshots_taken += 1
            self.delta_bytes_total += len(blob)
        else:
            gap = (getattr(self.server, "session_margins", {})
                   .get(snap.session_id) if self.codec == INT8 else None)
            blob, used = await loop.run_in_executor(
                None, functools.partial(
                    snapshot_to_blob_checked, snap, codec=self.codec,
                    argmax_gap=gap))
            if self.codec == INT8 and used == FP:
                self.int8_fallbacks += 1
                self.server.recorder.record(
                    "codec_fallback", path="int8->fp",
                    session=snap.session_id, where="snapshot")
            if wanted_delta:
                self.server.recorder.record(
                    "codec_fallback", path="delta->base",
                    session=snap.session_id, where="snapshot")
            self.store.set(self.key(*key), blob, ttl=self.ttl_s)
            # a stale delta against the old base would fail its base-cursor
            # check anyway; delete it so restore never pays the failed probe
            self.store.delete(self.delta_key(*key))
            self._base_step[key] = snap.step
            self._deltas_since_base[key] = 0
        self.snapshot_bytes_total += len(blob)
        self.bytes_log.append(len(blob))
        self.server.tracer.span(trace, "snapshot", t0, snap.origin,
                                f"stage={snap.stage}")

    def _gc(self, open_sids: set[int]) -> None:
        """Prune keys (and cursor state) for sessions gone from every alive
        replica for longer than the grace window — FINISHed sessions are
        reclaimed eagerly by drop_session; this sweep handles reaped and
        torn-down sessions without racing a kill-recovery restore."""
        now = time.monotonic()
        for sid in open_sids:
            self._missing_since.pop(sid, None)
        for sid in {s for s, _ in self._last_step} - open_sids:
            first = self._missing_since.setdefault(sid, now)
            if now - first > self.gc_grace_s:
                self.drop_session(sid)

    # ----------------------------------------------------------------- reads
    def latest(self, sid: int, stage: int) -> Optional[SessionSnapshot]:
        """Newest restorable snapshot: base + delta when a valid delta
        extends the stored base, the base alone when the delta is absent or
        fails any check (an older but intact cursor beats no restore)."""
        blob = self.store.get(self.key(sid, stage))
        if blob is None:
            return None
        try:
            base = snapshot_from_blob(blob)
        except SnapshotTransferError:
            return None
        dblob = self.store.get(self.delta_key(sid, stage))
        if dblob is not None:
            try:
                return apply_snapshot_delta(base, dblob)
            except SnapshotTransferError:
                pass
        return base

    def latest_step(self, sid: int, stage: int) -> Optional[int]:
        blob = self.store.get(self.key(sid, stage))
        if blob is None:
            return None
        try:
            step = blob_step(blob)
        except Exception:  # noqa: BLE001 — torn blob == no snapshot
            return None
        dblob = self.store.get(self.delta_key(sid, stage))
        if dblob is not None:
            try:
                if blob_base_step(dblob) == step:
                    return blob_step(dblob)
            except Exception:  # noqa: BLE001 — torn delta == base only
                pass
        return step

    # -------------------------------------------------------------------- GC
    def drop_session(self, sid: int) -> int:
        """Eager reclamation when a session FINISHes (or is reaped)."""
        n = self.store.delete_prefix(f"{self.prefix()}{sid}/")
        self.pruned_keys += n
        self._missing_since.pop(sid, None)
        for d in (self._last_step, self._base_step, self._deltas_since_base):
            for key in [k for k in d if k[0] == sid]:
                del d[key]
        return n

    def drop_all(self) -> int:
        n = self.store.delete_prefix(self.prefix())
        self.pruned_keys += n
        self._last_step.clear()
        self._base_step.clear()
        self._deltas_since_base.clear()
        self._missing_since.clear()
        return n

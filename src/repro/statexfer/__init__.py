"""State-transfer subsystem: move decode state, don't recompute it.

PR 2's only recovery path was RETRY + full-history re-prefill: every planned
drain and unplanned kill paid O(prompt + generated) recompute. This package
makes state itself a first-class transferable object, next to send/recv:

* :mod:`codec`     — SessionSnapshot wire format: chunked, versioned,
  CRC-validated blobs of per-stage KV cache + decode cursor (fp exact /
  int8 quantized).
* :mod:`manager`   — MigrationManager: planned live handoff (pause at a
  step boundary, stream to a survivor, flip pins, resume — zero re-prefill)
  and snapshot restore (rebuild a killed session's route and replay only
  the suffix).
* :mod:`snapstore` — SnapshotStore: periodic background snapshots into the
  cluster store with TTL + eager GC, bounding unplanned-kill replay.
* :mod:`bootstrap` — WarmBootstrap: new replicas fetch stage weights from a
  peer and pre-compile the peer's served shape profile before entering
  rotation.
"""
from .bootstrap import WarmBootstrap
from .codec import (
    FP,
    INT8,
    SessionSnapshot,
    SnapshotChunk,
    SnapshotHeader,
    SnapshotTransferError,
    argmax_margin,
    blob_origin,
    blob_step,
    decode_cache,
    encode_cache,
    encode_cache_checked,
    int8_margin_ok,
    params_assemble,
    params_encode,
    quantization_noise,
    snapshot_assemble,
    snapshot_encode,
    snapshot_from_blob,
    snapshot_to_blob,
    snapshot_to_blob_checked,
    tree_equal,
)
from .codec import (
    apply_snapshot_delta,
    blob_base_step,
    encode_cache_delta,
    snapshot_delta_to_blob,
)
from .codec import (
    PagedCachePayload,
    apply_paged_delta,
    as_paged_payload,
    materialize_paged,
    paged_payload_delta,
)
from .manager import MigrationManager, cache_nbytes
from .snapstore import SnapshotStore

__all__ = [
    "FP", "INT8",
    "SessionSnapshot", "SnapshotChunk", "SnapshotHeader",
    "SnapshotTransferError",
    "argmax_margin", "blob_origin", "blob_step",
    "decode_cache", "encode_cache", "encode_cache_checked",
    "int8_margin_ok", "params_assemble", "params_encode",
    "quantization_noise", "snapshot_assemble", "snapshot_encode",
    "snapshot_from_blob", "snapshot_to_blob", "snapshot_to_blob_checked",
    "tree_equal",
    "apply_snapshot_delta", "blob_base_step", "encode_cache_delta",
    "snapshot_delta_to_blob",
    "PagedCachePayload", "apply_paged_delta", "as_paged_payload",
    "materialize_paged", "paged_payload_delta",
    "MigrationManager", "SnapshotStore", "WarmBootstrap", "cache_nbytes",
]

"""SessionSnapshot codec: per-stage decode state as chunked, versioned blobs.

The state-transfer subsystem moves a live generation session between
replicas (planned handoff), into the snapshot store (periodic background
snapshots), or across engine restarts (export/import). All three paths share
one wire format produced here:

* a :class:`SessionSnapshot` captures one stage's per-session decode state —
  the stage-slice KV cache pytree plus the decode cursor (last processed
  position), session batch, and identity;
* :func:`snapshot_encode` serializes it into an ordered list of
  :class:`SnapshotChunk` wire messages sized for streaming with backpressure
  (the header rides on chunk 0: version, codec, byte count, CRC);
* :func:`snapshot_assemble` validates and reassembles — out-of-order chunks
  are re-sorted by sequence number, while missing/duplicated/corrupted
  chunks raise :class:`SnapshotTransferError` so the caller can fall back to
  the re-prefill recovery path instead of resuming from torn state.

Two cache codecs:

* ``fp``   — exact: leaves cross the wire bit-identically (the token-parity
  path; fp restore is byte-identical, so greedy decode continues exactly).
* ``int8`` — per-last-axis absmax quantization of float leaves, ~4x smaller
  snapshots for bf16/fp32 KV caches. Greedy continuation is token-identical
  in practice for well-margined logits but not guaranteed bit-exact — use
  fp wherever parity is asserted.
"""
from __future__ import annotations

import dataclasses
import pickle
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

SNAPSHOT_VERSION = 1
DEFAULT_CHUNK_BYTES = 256 * 1024

FP = "fp"
INT8 = "int8"


class SnapshotTransferError(RuntimeError):
    """A snapshot could not be (re)assembled: missing/duplicate/corrupt
    chunks, version skew, or CRC mismatch. Callers fall back to re-prefill."""


@dataclasses.dataclass(frozen=True)
class SnapshotHeader:
    """Chunk-0 metadata describing the whole transfer."""

    version: int
    session_id: int
    stage: int
    step: int            # last decode position integrated into the cache
    batch: int           # per-session batch dimension
    codec: str           # FP | INT8
    nbytes: int          # total payload bytes across all chunks
    n_chunks: int
    crc32: int           # over the full reassembled payload
    #: worker that captured the state — restore targets are ranked by
    #: placement cost *from here*, so the bytes prefer to stay on-host
    origin: Optional[str] = None
    #: delta blobs only: decode cursor of the base snapshot this delta
    #: extends (a delta against any other base fails closed); None for
    #: full snapshots
    base_step: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class SnapshotChunk:
    """One wire message of a streamed snapshot. ``header`` rides on seq 0."""

    session_id: int
    stage: int
    seq: int
    data: bytes
    header: Optional[SnapshotHeader] = None
    #: transport marks bulk-tagged payloads in its bulk byte counters
    bulk: bool = True

    @property
    def nbytes(self) -> int:
        return len(self.data)


@dataclasses.dataclass
class SessionSnapshot:
    """One stage's decode state for one session, host-side and codec-free."""

    session_id: int
    stage: int
    step: int
    batch: int
    cache: Any           # stage-slice cache pytree (numpy or jax leaves)
    origin: Optional[str] = None   # worker the state was captured on


@dataclasses.dataclass(frozen=True)
class _QLeaf:
    """An int8-quantized float leaf: q * scale reconstructs the original,
    scale is per-last-axis absmax/127."""

    q: np.ndarray        # int8
    scale: np.ndarray    # float32, shape = leaf.shape[:-1] + (1,)
    dtype: Any           # original np.dtype (dtype objects pickle cleanly;
    #                      string round-trips break for ml_dtypes like bf16)


def _quantize_leaf(leaf: np.ndarray) -> Any:
    # jnp.issubdtype also recognizes ml_dtypes floats (bf16), which numpy
    # classifies as void — those are exactly the KV dtypes worth compressing
    if not jnp.issubdtype(leaf.dtype, jnp.floating):
        return leaf
    x = leaf.astype(np.float32)
    scale = np.abs(x).max(axis=-1, keepdims=True) / 127.0
    scale = np.where(scale == 0.0, 1.0, scale).astype(np.float32)
    q = np.clip(np.rint(x / scale), -127, 127).astype(np.int8)
    return _QLeaf(q=q, scale=scale, dtype=leaf.dtype)


def _dequantize_leaf(leaf: Any) -> np.ndarray:
    if isinstance(leaf, _QLeaf):
        return (leaf.q.astype(np.float32) * leaf.scale).astype(leaf.dtype)
    return leaf


def _host_cache(cache: Any) -> Any:
    return jax.tree.map(lambda a: np.asarray(a), cache)


# ---------------------------------------------------------- paged payloads
@dataclasses.dataclass
class PagedCachePayload:
    """Page-granular wire form of one session's stage cache.

    A paged session's cache lives in a shared :class:`~repro.serving.kvpool.
    PagePool`; its wire form enumerates only the pages the session actually
    uses (``ceil(length / page_size)`` of them) instead of the whole
    ``max_len`` buffer — handoffs and snapshots of a paged session are
    therefore strictly smaller than the contiguous encoding whenever
    ``length < max_len``. Leaves are host numpy; the tree structure rides as
    a ``skeleton`` (the cache tree with integer leaf indices), so no pytree
    registration or treedef pickling is needed on the wire.

    ``keys`` carries the prefix-trie identity of each *full* page (a
    ``(chunk_digest, chain_digest)`` pair; ``None`` for the partial last
    page and decode-written pages) so the receiving pool can re-share
    matching prefix pages instead of storing duplicates.

    ``base_step`` is set on delta payloads only: the entries then cover just
    the pages dirtied since the base cursor.
    """

    page_size: int
    length: int                    # valid tokens (decode cursor + 1)
    max_len: int
    skeleton: Any                  # cache tree shape with int leaf indices
    axes: list                     # per flat leaf: seq axis of the template
    shapes: list                   # per flat leaf: contiguous template shape
    dtypes: list                   # per flat leaf: numpy dtype
    logical: list                  # logical page index per entry (sorted)
    pages: list                    # per flat leaf: (n_entries, ..page..) array
    keys: list                     # per entry: (digest, chain) | None
    base_step: Optional[int] = None

    @property
    def nbytes(self) -> int:
        """Bytes this payload moves (page data only — the metadata is noise)."""
        return int(sum(int(p.nbytes) for p in self.pages))

    def page_entry(self, pos: int) -> list:
        """Flat per-leaf list of one entry's page arrays."""
        return [p[pos] for p in self.pages]


def as_paged_payload(cache: Any) -> Optional[PagedCachePayload]:
    """The paged wire form of ``cache`` if it has one (a pool handle, a
    frozen pool view, or an already-built payload), else None."""
    if isinstance(cache, PagedCachePayload):
        return cache
    fn = getattr(cache, "paged_payload", None)
    return fn() if callable(fn) else None


def materialize_paged(payload: PagedCachePayload, *,
                      device: bool = True) -> Any:
    """Expand a paged payload to a contiguous ``max_len`` cache pytree (the
    adopt-path for executors running without a page pool). Positions beyond
    the payload's pages are zero, matching a freshly-initialized cache."""
    page = payload.page_size
    flats = [np.zeros(shape, dtype)
             for shape, dtype in zip(payload.shapes, payload.dtypes)]
    for pos, li in enumerate(payload.logical):
        for leaf, arr, ax in zip(flats, payload.pages, payload.axes):
            sl = [slice(None)] * leaf.ndim
            sl[ax] = slice(li * page, (li + 1) * page)
            leaf[tuple(sl)] = arr[pos]
    if device:
        flats = [jnp.asarray(leaf) for leaf in flats]
    structure = jax.tree.structure(payload.skeleton)
    return jax.tree.unflatten(structure, flats)


def paged_payload_delta(payload: PagedCachePayload, *, base_step: int,
                        step: int) -> PagedCachePayload:
    """Dirty-page subset of a paged payload: only the pages covering
    positions ``base_step+1 .. step`` (prefill/decode never rewrite earlier
    positions of a full cache, so earlier pages are bit-identical to the
    base snapshot's)."""
    page = payload.page_size
    lo, hi = (base_step + 1) // page, step // page
    keep = [i for i, li in enumerate(payload.logical) if lo <= li <= hi]
    return PagedCachePayload(
        page_size=page, length=payload.length, max_len=payload.max_len,
        skeleton=payload.skeleton, axes=payload.axes, shapes=payload.shapes,
        dtypes=payload.dtypes,
        logical=[payload.logical[i] for i in keep],
        pages=[p[keep] for p in payload.pages],
        keys=[payload.keys[i] for i in keep],
        base_step=base_step)


def apply_paged_delta(base: PagedCachePayload, delta: PagedCachePayload
                      ) -> PagedCachePayload:
    """Merge a dirty-page delta into its paged base payload."""
    by_logical = {li: (base.page_entry(pos), base.keys[pos])
                  for pos, li in enumerate(base.logical)}
    for pos, li in enumerate(delta.logical):
        by_logical[li] = (delta.page_entry(pos), delta.keys[pos])
    logical = sorted(by_logical)
    pages = [np.stack([by_logical[li][0][leaf_i] for li in logical])
             for leaf_i in range(len(base.pages))]
    return PagedCachePayload(
        page_size=base.page_size, length=delta.length, max_len=base.max_len,
        skeleton=base.skeleton, axes=base.axes, shapes=base.shapes,
        dtypes=base.dtypes, logical=logical, pages=pages,
        keys=[by_logical[li][1] for li in logical])


def encode_cache(cache: Any, codec: str = FP) -> bytes:
    """Serialize a cache pytree to one payload byte string."""
    paged = as_paged_payload(cache)
    if paged is not None:
        # pages always ship fp: pool pages must splice back bit-exactly, and
        # re-quantizing a page would break that regardless of session margin
        return pickle.dumps(paged, protocol=pickle.HIGHEST_PROTOCOL)
    host = _host_cache(cache)
    if codec == INT8:
        host = jax.tree.map(_quantize_leaf, host)
    elif codec != FP:
        raise ValueError(f"unknown snapshot codec {codec!r}")
    return pickle.dumps(host, protocol=pickle.HIGHEST_PROTOCOL)


def decode_cache(payload: bytes, codec: str = FP, *,
                 device: bool = True) -> Any:
    """Inverse of :func:`encode_cache`; returns jax leaves when ``device``.
    Paged payloads come back as :class:`PagedCachePayload` (host-side) —
    the installer decides whether they enter a pool or materialize."""
    host = pickle.loads(payload)
    if isinstance(host, PagedCachePayload):
        return host
    if codec == INT8:
        host = jax.tree.map(_dequantize_leaf, host,
                            is_leaf=lambda x: isinstance(x, _QLeaf))
    if device:
        return jax.tree.map(jnp.asarray, host)
    return host


def snapshot_encode(snap: SessionSnapshot, *, codec: str = FP,
                    chunk_bytes: int = DEFAULT_CHUNK_BYTES
                    ) -> list[SnapshotChunk]:
    """Serialize one session-stage snapshot into ordered wire chunks."""
    payload = encode_cache(snap.cache, codec)
    n = max(1, -(-len(payload) // chunk_bytes))
    header = SnapshotHeader(
        version=SNAPSHOT_VERSION, session_id=snap.session_id,
        stage=snap.stage, step=snap.step, batch=snap.batch, codec=codec,
        nbytes=len(payload), n_chunks=n, crc32=zlib.crc32(payload),
        origin=snap.origin)
    return [
        SnapshotChunk(
            session_id=snap.session_id, stage=snap.stage, seq=i,
            data=payload[i * chunk_bytes:(i + 1) * chunk_bytes],
            header=header if i == 0 else None)
        for i in range(n)
    ]


def snapshot_assemble(chunks: list[SnapshotChunk]) -> SessionSnapshot:
    """Validate + reassemble chunks (any arrival order) into a snapshot.

    Raises :class:`SnapshotTransferError` on anything short of a perfect
    transfer: no header, version skew, missing/duplicate sequence numbers,
    truncated payload, or CRC mismatch.
    """
    if not chunks:
        raise SnapshotTransferError("empty transfer")
    ordered = sorted(chunks, key=lambda c: c.seq)
    header = ordered[0].header
    if header is None or ordered[0].seq != 0:
        raise SnapshotTransferError("transfer lost its header chunk")
    if header.version != SNAPSHOT_VERSION:
        raise SnapshotTransferError(
            f"snapshot version {header.version} != {SNAPSHOT_VERSION}")
    seqs = [c.seq for c in ordered]
    if seqs != list(range(header.n_chunks)):
        raise SnapshotTransferError(
            f"chunk sequence {seqs} != 0..{header.n_chunks - 1}")
    payload = b"".join(c.data for c in ordered)
    if len(payload) != header.nbytes:
        raise SnapshotTransferError(
            f"payload {len(payload)}B != header {header.nbytes}B")
    if zlib.crc32(payload) != header.crc32:
        raise SnapshotTransferError("payload CRC mismatch")
    return SessionSnapshot(
        session_id=header.session_id, stage=header.stage, step=header.step,
        batch=header.batch, cache=decode_cache(payload, header.codec),
        origin=getattr(header, "origin", None))


# ---------------------------------------------------------------- blob form
def snapshot_to_blob(snap: SessionSnapshot, *, codec: str = FP) -> bytes:
    """Single-buffer form (header || payload) for the snapshot store, where
    chunk streaming adds nothing — the store is already in-memory."""
    payload = encode_cache(snap.cache, codec)
    header = SnapshotHeader(
        version=SNAPSHOT_VERSION, session_id=snap.session_id,
        stage=snap.stage, step=snap.step, batch=snap.batch, codec=codec,
        nbytes=len(payload), n_chunks=1, crc32=zlib.crc32(payload),
        origin=snap.origin)
    return pickle.dumps((header, payload), protocol=pickle.HIGHEST_PROTOCOL)


def snapshot_from_blob(blob: bytes) -> SessionSnapshot:
    try:
        header, payload = pickle.loads(blob)
    except Exception as e:  # noqa: BLE001 — any unpickle failure is torn state
        raise SnapshotTransferError(f"undecodable snapshot blob: {e!r}") from e
    if header.version != SNAPSHOT_VERSION:
        raise SnapshotTransferError(
            f"snapshot version {header.version} != {SNAPSHOT_VERSION}")
    if len(payload) != header.nbytes or zlib.crc32(payload) != header.crc32:
        raise SnapshotTransferError("snapshot blob failed integrity check")
    return SessionSnapshot(
        session_id=header.session_id, stage=header.stage, step=header.step,
        batch=header.batch, cache=decode_cache(payload, header.codec),
        origin=getattr(header, "origin", None))


def blob_step(blob: bytes) -> int:
    """Decode cursor of a stored blob without materializing the cache."""
    header, _ = pickle.loads(blob)
    return header.step


def blob_base_step(blob: bytes) -> Optional[int]:
    """Base cursor a delta blob extends (None for full snapshots)."""
    header, _ = pickle.loads(blob)
    return getattr(header, "base_step", None)


def blob_origin(blob: bytes) -> Optional[str]:
    """Capturing worker of a stored blob, without materializing the cache."""
    header, _ = pickle.loads(blob)
    return getattr(header, "origin", None)


# ------------------------------------------------------- delta snapshots
# A full-attention cache at decode position t differs from the same
# session's cache at position t0 < t only in positions t0+1..t of each
# leaf's sequence axis — prefill writes positions 0..s0-1 once, each decode
# step writes exactly its own slot, and earlier slots are immutable. A
# *delta* snapshot therefore re-encodes only that slice, cutting
# steady-state background-snapshot bandwidth by ~seq_len/interval_tokens.
# The sequence axis is identified structurally (the axis sized ``seq_len``);
# a leaf with zero or several matching axes ships whole — correct, merely
# uncompressed. Deltas are fp-only (an int8 re-quantized slice would not
# splice bit-exactly into its base) and only valid for full caches —
# ring-buffer and SSM state mutate old positions, so those stages take full
# snapshots. A delta that fails any integrity check, or whose recorded
# ``base_step`` does not match the base it is applied to, raises and the
# caller falls back to the base snapshot alone (an older but valid cursor).

@dataclasses.dataclass(frozen=True)
class _DeltaLeaf:
    """One leaf of a delta tree: either a slice of the sequence axis
    (``axis`` set, covering base positions ``t0+1 .. t0+data.shape[axis]``)
    or a full replacement (``axis`` None)."""

    axis: Optional[int]
    data: np.ndarray


def _seq_axis(shape: tuple, seq_len: int) -> Optional[int]:
    axes = [i for i, n in enumerate(shape) if n == seq_len]
    return axes[0] if len(axes) == 1 else None


def encode_cache_delta(cache: Any, *, base_step: int, step: int,
                       seq_len: int, seq_axes: Any = None) -> bytes:
    """Serialize only positions ``base_step+1 .. step`` of each leaf's
    sequence axis. ``seq_axes`` is an optional tree matching ``cache``
    whose leaves name each leaf's sequence axis (-1 = none; see
    ``stage_cache_seq_axes``) — the structural ground truth. Without it a
    unique-size heuristic is used, and any leaf whose sequence axis cannot
    be determined unambiguously ships whole (correct, just uncompressed).

    Paged caches delta at page granularity instead: the payload carries the
    pages covering the dirty positions whole (``seq_axes`` is moot — the
    paged payload knows its own layout)."""
    paged = as_paged_payload(cache)
    if paged is not None:
        delta = paged_payload_delta(paged, base_step=base_step, step=step)
        return pickle.dumps(delta, protocol=pickle.HIGHEST_PROTOCOL)
    host = _host_cache(cache)

    def enc(leaf, ax) -> _DeltaLeaf:
        arr = np.asarray(leaf)
        if ax is None or ax < 0 or ax >= arr.ndim:
            return _DeltaLeaf(axis=None, data=arr)
        sl = [slice(None)] * arr.ndim
        sl[ax] = slice(base_step + 1, step + 1)
        return _DeltaLeaf(axis=ax, data=np.ascontiguousarray(arr[tuple(sl)]))

    if seq_axes is not None:
        tree = jax.tree.map(enc, host, seq_axes)
    else:
        tree = jax.tree.map(
            lambda leaf: enc(leaf, _seq_axis(np.asarray(leaf).shape,
                                             seq_len)), host)
    return pickle.dumps(tree, protocol=pickle.HIGHEST_PROTOCOL)


def snapshot_delta_to_blob(snap: SessionSnapshot, *, base_step: int,
                           seq_len: int, seq_axes: Any = None) -> bytes:
    """Single-buffer delta form for the snapshot store: (header || payload)
    with ``base_step`` recording the base cursor this delta extends."""
    payload = encode_cache_delta(snap.cache, base_step=base_step,
                                 step=snap.step, seq_len=seq_len,
                                 seq_axes=seq_axes)
    header = SnapshotHeader(
        version=SNAPSHOT_VERSION, session_id=snap.session_id,
        stage=snap.stage, step=snap.step, batch=snap.batch, codec=FP,
        nbytes=len(payload), n_chunks=1, crc32=zlib.crc32(payload),
        origin=snap.origin, base_step=base_step)
    return pickle.dumps((header, payload), protocol=pickle.HIGHEST_PROTOCOL)


def apply_snapshot_delta(base: SessionSnapshot,
                         blob: bytes) -> SessionSnapshot:
    """Reconstruct the newer snapshot from ``base`` + a delta blob. Fails
    closed (:class:`SnapshotTransferError`) on any integrity or base-cursor
    mismatch — the caller then restores from the base alone."""
    try:
        header, payload = pickle.loads(blob)
    except Exception as e:  # noqa: BLE001 — any unpickle failure is torn state
        raise SnapshotTransferError(f"undecodable delta blob: {e!r}") from e
    if header.version != SNAPSHOT_VERSION:
        raise SnapshotTransferError(
            f"snapshot version {header.version} != {SNAPSHOT_VERSION}")
    if len(payload) != header.nbytes or zlib.crc32(payload) != header.crc32:
        raise SnapshotTransferError("delta blob failed integrity check")
    base_step = getattr(header, "base_step", None)
    if base_step is None or base_step != base.step:
        raise SnapshotTransferError(
            f"delta base cursor {base_step} != base snapshot {base.step}")
    if header.session_id != base.session_id or header.stage != base.stage:
        raise SnapshotTransferError("delta applied to the wrong session")
    tree = pickle.loads(payload)

    if isinstance(tree, PagedCachePayload):
        base_paged = as_paged_payload(base.cache)
        if base_paged is None:
            # the session flipped contiguous -> paged between base and delta
            # (e.g. a pool-exhaustion degrade ran the other way); a page
            # delta cannot splice into a contiguous base — fail closed, the
            # caller restores from the base cursor alone
            raise SnapshotTransferError(
                "paged delta over a contiguous base snapshot")
        return SessionSnapshot(
            session_id=header.session_id, stage=header.stage,
            step=header.step, batch=header.batch,
            cache=apply_paged_delta(base_paged, tree),
            origin=getattr(header, "origin", None))
    if as_paged_payload(base.cache) is not None:
        raise SnapshotTransferError(
            "contiguous delta over a paged base snapshot")

    def merge(b, d: _DeltaLeaf):
        if d.axis is None:
            return d.data
        out = np.array(np.asarray(b))
        sl = [slice(None)] * out.ndim
        sl[d.axis] = slice(base_step + 1, base_step + 1 + d.data.shape[d.axis])
        out[tuple(sl)] = d.data
        return out

    merged = jax.tree.map(merge, _host_cache(base.cache), tree)
    return SessionSnapshot(
        session_id=header.session_id, stage=header.stage, step=header.step,
        batch=header.batch, cache=jax.tree.map(jnp.asarray, merged),
        origin=getattr(header, "origin", None))


# ------------------------------------------------------- int8 margin check
# int8 restore is token-identical in practice but unproven: quantization
# perturbs the KV cache, the perturbed cache perturbs the logits, and a
# session whose greedy argmax is decided by a hair can flip. The check below
# is the pragmatic bound: compare the session's observed *relative argmax
# gap* (top-1 minus top-2 logit, normalized by the logits' RMS — tracked by
# the serving layer as a running minimum over the session's steps) against
# the cache's *relative quantization noise* (worst per-leaf dequantization
# error over leaf RMS). When the gap is not comfortably wider than the
# noise, the session's snapshot falls back to the fp codec — correctness is
# per-session, bandwidth savings are kept for the well-margined majority.

#: gap must exceed noise by this factor before int8 is trusted
DEFAULT_MARGIN_FACTOR = 4.0


def argmax_margin(logits: Any) -> float:
    """Relative argmax gap of one step's logits: min over batch rows of
    (top1 - top2) / rms(row). Dimensionless, comparable across models."""
    a = np.asarray(logits, dtype=np.float32)
    a = a.reshape(-1, a.shape[-1])
    top2 = np.partition(a, -2, axis=-1)[:, -2:]
    gap = top2[:, 1] - top2[:, 0]
    rms = np.sqrt(np.mean(a * a, axis=-1)) + 1e-9
    return float(np.min(gap / rms))


def quantization_noise(cache: Any) -> float:
    """Relative int8 quantization noise of a cache pytree: max over float
    leaves of (worst-case dequantization error / leaf RMS). The worst-case
    per-element error of per-last-axis absmax quantization is scale/2."""
    if as_paged_payload(cache) is not None:
        return 0.0               # paged payloads always ship fp (bit-exact)
    worst = 0.0
    for leaf in jax.tree.leaves(_host_cache(cache)):
        if not jnp.issubdtype(np.asarray(leaf).dtype, jnp.floating):
            continue
        x = np.asarray(leaf, dtype=np.float32)
        scale = np.abs(x).max(axis=-1, keepdims=True) / 127.0
        rms = np.sqrt(np.mean(x * x)) + 1e-9
        worst = max(worst, float(scale.max()) / 2.0 / rms)
    return worst


def int8_margin_ok(argmax_gap: Optional[float], cache: Any, *,
                   margin_factor: float = DEFAULT_MARGIN_FACTOR) -> bool:
    """True when the session's argmax gap comfortably dominates the cache's
    quantization noise. An untracked gap (None) is treated as thin — no
    evidence means no int8."""
    if argmax_gap is None:
        return False
    return argmax_gap > margin_factor * quantization_noise(cache)


def encode_cache_checked(cache: Any, codec: str, *,
                         argmax_gap: Optional[float] = None,
                         margin_factor: float = DEFAULT_MARGIN_FACTOR
                         ) -> tuple[bytes, str]:
    """Like :func:`encode_cache`, but int8 demotes itself to fp when the
    argmax-gap-vs-quantization-noise margin is too thin. Returns
    ``(payload, codec_actually_used)``."""
    if as_paged_payload(cache) is not None:
        codec = FP               # pages are fp-only (must splice bit-exactly)
    elif codec == INT8 and not int8_margin_ok(argmax_gap, cache,
                                              margin_factor=margin_factor):
        codec = FP
    return encode_cache(cache, codec), codec


def snapshot_to_blob_checked(snap: SessionSnapshot, *, codec: str = FP,
                             argmax_gap: Optional[float] = None,
                             margin_factor: float = DEFAULT_MARGIN_FACTOR
                             ) -> tuple[bytes, str]:
    """Margin-checked :func:`snapshot_to_blob`: int8 falls back to fp per
    session when its parity margin is too thin. Returns ``(blob, codec)``."""
    payload, used = encode_cache_checked(snap.cache, codec,
                                         argmax_gap=argmax_gap,
                                         margin_factor=margin_factor)
    header = SnapshotHeader(
        version=SNAPSHOT_VERSION, session_id=snap.session_id,
        stage=snap.stage, step=snap.step, batch=snap.batch, codec=used,
        nbytes=len(payload), n_chunks=1, crc32=zlib.crc32(payload),
        origin=snap.origin)
    return (pickle.dumps((header, payload),
                         protocol=pickle.HIGHEST_PROTOCOL), used)


# ------------------------------------------------------------ param transfer
def params_encode(params: Any, *, chunk_bytes: int = DEFAULT_CHUNK_BYTES
                  ) -> list[SnapshotChunk]:
    """Chunk a stage's weight pytree for the warm-bootstrap transfer. Reuses
    the snapshot wire format with the reserved session id -1 (weights are a
    'session' of stage state that never decodes)."""
    return snapshot_encode(
        SessionSnapshot(session_id=-1, stage=-1, step=-1, batch=0,
                        cache=params),
        codec=FP, chunk_bytes=chunk_bytes)


def params_assemble(chunks: list[SnapshotChunk]) -> Any:
    return snapshot_assemble(chunks).cache


def tree_equal(a: Any, b: Any) -> bool:
    """Exact structural + bitwise equality of two pytrees (test helper)."""
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    if ta != tb or len(la) != len(lb):
        return False
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))

"""WarmBootstrap: fast first-token for a freshly added replica.

A cold scale-up pays twice before its first token: the stage weights have to
reach the new worker, and every (shape, width) executable its traffic will
hit has to compile. On real hardware both costs are material (the paper's
NCCL lazy-init dip is the same phenomenon one layer down). This module
front-loads both, *before* the replica enters the routing rotation:

* **weight fetch**: the stage's parameter pytree is streamed from a peer
  replica over a fresh pairwise world using the snapshot chunk format (bulk
  byte-accounted, backpressured) — the peer, not a central coordinator, is
  the source, so scale-up bandwidth scales with the fleet;
* **compiled-shape warmup**: the peer's executor reports which prefill
  shapes and fused decode widths it has served (its *warm profile*), and
  the new executor replays dummy dispatches over exactly that profile, so
  the first real request hits a warm jit cache.

With the default shared per-stage executor the compile warmup is a no-op by
construction (replicas share one jit cache); ``fresh_executor=True`` models
the real-deployment case of a new worker process with its own caches.

The same machinery generalizes into the multi-model residency protocol
(:meth:`WarmBootstrap.load_model`): when a replica is directed to host
another registered model, the new model's *stage* weights stream from a
same-stage peer that already hosts it — as typed ``LOAD`` envelopes over a
fresh pairwise world, headed by a ``SWAP`` marker when the load is one leg
of an A->B swap and trailed by an ``UNLOAD`` marker naming the outgoing
model — or install cold from the registry store when no peer is resident
(zero wire bytes; the first replica to host a model always loads cold).
Either way the replica never leaves rotation: the serve loop keeps
dispatching its resident models while the stream lands, and the caller
(``PipelineServer.load_model``/``swap_model``) flips registry residency
and router tags only after the weights are installed.
"""
from __future__ import annotations

import asyncio
import functools
import itertools
import time

from .codec import (
    DEFAULT_CHUNK_BYTES,
    SnapshotTransferError,
    params_assemble,
    params_encode,
)
from .manager import cache_nbytes, stream_chunks


class WarmBootstrap:
    def __init__(self, server, *,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 backpressure_bytes: int = 4 * 1024 * 1024,
                 transfer_timeout_s: float = 30.0,
                 placement_aware: bool = True) -> None:
        self.server = server
        self.chunk_bytes = chunk_bytes
        self.backpressure_bytes = backpressure_bytes
        self.transfer_timeout_s = transfer_timeout_s
        #: weight-source peer ranked by (queue load + placement cost of the
        #: stage weights about to move), not queue depth alone
        self.placement_aware = placement_aware
        self._uid = itertools.count()
        self.bootstraps_total = 0
        self.weight_bytes: list[int] = []
        self.transfer_s: list[float] = []
        self.warm_s: list[float] = []
        # -- residency-protocol counters (registry tests / bench read) -----
        self.model_loads_total = 0       # LOAD streams completed
        self.model_loads_cold = 0        # installs from the registry store
        self.model_swaps_total = 0       # SWAP-headed streams
        self.load_bytes: list[int] = []

    def _pick_peer(self, stage: int, worker_id: str, role: str = "both",
                   model=None):
        """Weight-source choice: a same-host peer saves a cross-host copy of
        the whole stage pytree, which dwarfs any queue-depth difference.
        A same-*role* peer is preferred over any other — its served shape
        profile is exactly the traffic the new replica's pool will see, so
        the compile warmup replays nothing the role can't use — but weights
        are role-agnostic, so any peer works as the fallback. ``model=``
        restricts to peers with that model resident (the LOAD protocol's
        weight source must actually hold the weights); None matches the
        default-model behavior."""
        server = self.server
        peers = [r for r in server.replicas[stage]
                 if r.worker.alive and not r.draining
                 and r.worker_id != worker_id]
        if model is not None:
            peers = [r for r in peers
                     if model in getattr(r, "resident", ())]
            if not peers:
                return None
        if role != "both":
            same = [r for r in peers
                    if getattr(r, "role", "both") == role]
            peers = same or peers
        if not peers:
            return None
        placement = getattr(server.cluster, "placement", None)
        if not self.placement_aware or placement is None:
            return min(peers, key=lambda r: r.queue_depth())
        psets = (server.stage_param_sets if model is None
                 else server.model_stages(model)[1])
        nbytes = cache_nbytes(psets[stage])
        return min(peers, key=lambda r: placement.score(
            r.queue_depth(), r.worker_id, worker_id, nbytes))

    async def bootstrap(self, stage: int, worker_id: str, *,
                        fresh_executor: bool = False,
                        role: str = "both") -> dict:
        """Fetch weights + warm compiles for a new replica of ``stage``.
        Returns a report dict whose ``executor`` the caller installs on the
        replica before it starts serving. The weight fetch only happens for
        a fresh executor — the shared per-stage executor already holds the
        stage params, and streaming a pytree nobody will use is pure wire
        cost. ``role`` selects the pool executor and filters the warm
        replay to the role's slice of the peer profile (a prefill replica
        never compiles decode widths and vice versa — measurably cheaper
        than the colocated replay)."""
        from repro.serving.executor import StageExecutor

        server = self.server
        t_begin = time.monotonic()
        peer = self._pick_peer(stage, worker_id, role)
        report: dict = {"stage": stage, "peer": peer.worker_id if peer
                        else None, "bytes": 0, "transfer_s": 0.0,
                        "warm_s": 0.0, "fresh_executor": fresh_executor,
                        "role": role}

        if fresh_executor:
            sparams = server.stage_param_sets[stage]
            if peer is not None:
                t0 = time.monotonic()
                sparams = await self._fetch_weights(peer, worker_id, sparams)
                report["transfer_s"] = time.monotonic() - t0
                report["bytes"] = self.weight_bytes[-1]
            executor = StageExecutor(
                server.cfg, server.stage_specs[stage], sparams,
                max_len=server.max_len, role=role)
        else:
            executor = server.role_executor(stage, role)

        if peer is not None:
            profile = peer.executor.warm_profile()
            t0 = time.monotonic()
            # jit compiles are blocking host work — keep them off the loop
            await asyncio.get_event_loop().run_in_executor(
                None, executor.warm, profile)
            report["warm_s"] = time.monotonic() - t0
            report["profile"] = profile
        self.bootstraps_total += 1
        self.transfer_s.append(report["transfer_s"])
        self.warm_s.append(report["warm_s"])
        # these logs feed p50-style reporting over the recent window only;
        # a long-lived elastic fleet must not grow them per scale-up forever
        if len(self.transfer_s) > 1024:
            del self.transfer_s[:512]
            del self.warm_s[:512]
        if len(self.weight_bytes) > 1024:
            del self.weight_bytes[:512]
        report["executor"] = executor
        # control-plane root span: a bootstrap belongs to no client session,
        # so it gets its own (single-node) trace tree
        tracer = getattr(server, "tracer", None)
        if tracer is not None:
            root = tracer.begin()
            tracer.record(root, "bootstrap", t_begin,
                          time.monotonic() - t_begin, worker_id,
                          f"stage={stage} peer={report['peer']}")
        return report

    async def load_model(self, rep, name: str, *, warm: bool = True,
                         swap_from: str = None) -> dict:
        """The residency protocol's wire leg: bring model ``name``'s stage
        weights to live replica ``rep`` without it leaving rotation.

        With a same-stage peer hosting the model, the peer streams the
        stage's parameter pytree as typed ``LOAD`` envelopes over a fresh
        ``load:`` pairwise world — headed by a ``SWAP`` marker when
        ``swap_from`` names the outgoing model of an A->B swap, trailed by
        an ``UNLOAD`` marker directing the receiver to retire it. The
        receiver validates the marker framing and the reassembled pytree is
        checked bit-identical against the registry store (the store is the
        source of truth; the stream is the transport). With no resident
        peer the install is cold from the store: zero wire bytes.

        ``warm=True`` replays the peer's model-executor shape profile on
        the (possibly freshly built) model executor so the model's first
        real request compiles nothing. Returns a report dict."""
        from repro.serving.envelope import Envelope, Kind

        server = self.server
        t_begin = time.monotonic()
        stage = rep.stage
        server.registry.get(name)  # unknown names fail fast, with suggestions
        psets = server.model_stages(name)[1]
        peer = self._pick_peer(stage, rep.worker_id, rep.role, model=name)
        report: dict = {"model": name, "stage": stage, "bytes": 0,
                        "transfer_s": 0.0, "warm_s": 0.0,
                        "swap_from": swap_from,
                        "peer": peer.worker_id if peer else None,
                        "source": "peer" if peer is not None else "store"}
        loop = asyncio.get_event_loop()
        if peer is not None:
            sparams = psets[stage]
            chunks = await loop.run_in_executor(
                None, functools.partial(params_encode, sparams,
                                        chunk_bytes=self.chunk_bytes))
            envs = []
            if swap_from is not None:
                envs.append(Envelope(req_id=-1, session_id=-1,
                                     kind=Kind.SWAP, model=swap_from))
            envs.extend(Envelope(req_id=-1, session_id=-1, kind=Kind.LOAD,
                                 payload=c, model=name) for c in chunks)
            if swap_from is not None:
                envs.append(Envelope(req_id=-1, session_id=-1,
                                     kind=Kind.UNLOAD, model=swap_from))
            world = f"load:{server.name}:{rep.worker_id}:{next(self._uid)}"
            t0 = time.monotonic()
            received = await stream_chunks(
                server, peer.worker, rep.worker, world, envs,
                backpressure_bytes=self.backpressure_bytes,
                timeout_s=self.transfer_timeout_s)
            report["transfer_s"] = time.monotonic() - t0
            # marker framing: a swap stream must arrive exactly
            # SWAP, LOAD..., UNLOAD(swap_from); a plain load all-LOAD —
            # anything else means the worlds crossed streams
            kinds = [e.kind for e in received]
            loads = [e for e in received if e.kind is Kind.LOAD]
            ok_frame = (
                all(k is Kind.LOAD for k in kinds) if swap_from is None
                else (kinds[0] is Kind.SWAP and kinds[-1] is Kind.UNLOAD
                      and received[-1].model == swap_from
                      and all(k is Kind.LOAD for k in kinds[1:-1])))
            if not ok_frame or len(loads) != len(chunks):
                raise SnapshotTransferError(
                    f"torn LOAD stream for {name!r} on {world}: "
                    f"{[int(k) for k in kinds]}")
            nbytes = sum(e.nbytes for e in loads)
            report["bytes"] = nbytes
            self.load_bytes.append(nbytes)
            if len(self.load_bytes) > 1024:
                del self.load_bytes[:512]
            # the stream is the transport, the store the source of truth —
            # install the reassembled pytree only after it round-trips
            await loop.run_in_executor(
                None, params_assemble, [e.payload for e in loads])
        # the model executor for this (stage, role) — built lazily from the
        # registry store; shared with every other replica hosting the model
        executor = server.model_executor(name, stage, rep.role)
        if warm:
            profile = None
            if peer is not None:
                profile = server.model_executor(
                    name, stage, peer.role).warm_profile()
            if not profile:
                # cold load / cold peer: warm the canonical smoke shapes of
                # the default executor's served profile instead
                profile = rep.executor.warm_profile()
            t0 = time.monotonic()
            await loop.run_in_executor(None, executor.warm, profile)
            report["warm_s"] = time.monotonic() - t0
        self.model_loads_total += 1
        if peer is None:
            self.model_loads_cold += 1
        if swap_from is not None:
            self.model_swaps_total += 1
        report["executor"] = executor
        tracer = getattr(server, "tracer", None)
        if tracer is not None:
            root = tracer.begin()
            tracer.record(root, "model_load", t_begin,
                          time.monotonic() - t_begin, rep.worker_id,
                          f"model={name} source={report['source']}"
                          + (f" swap_from={swap_from}" if swap_from
                             else ""))
        return report

    async def _fetch_weights(self, peer, worker_id: str, sparams):
        """Stream the stage weight pytree peer -> new worker over the shared
        bounded bulk path; returns the reassembled (bit-identical) pytree."""
        server = self.server
        loop = asyncio.get_event_loop()
        chunks = await loop.run_in_executor(
            None, functools.partial(params_encode, sparams,
                                    chunk_bytes=self.chunk_bytes))
        world = f"boot:{server.name}:{worker_id}:{next(self._uid)}"
        received = await stream_chunks(
            server, peer.worker, server.cluster.worker(worker_id), world,
            chunks, backpressure_bytes=self.backpressure_bytes,
            timeout_s=self.transfer_timeout_s)
        self.weight_bytes.append(sum(c.nbytes for c in received))
        return await loop.run_in_executor(None, params_assemble, received)

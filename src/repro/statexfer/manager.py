"""MigrationManager: move live decode state instead of recomputing it.

Two recovery paths share this manager, mirroring the planned/unplanned split
of the README recovery matrix:

* **Planned live handoff** (:meth:`migrate_session`): scale-down or
  rebalance knows in advance which replica is going away. The session is
  paused at a step boundary (new decode steps are *held*, an in-flight fused
  step is awaited), its stage-slice KV cache + cursor is serialized into
  chunked wire blobs and streamed to a survivor replica of the same stage
  over a fresh pairwise world — with byte-level backpressure so a multi-MB
  cache never floods the channel — then installed, the upstream and
  downstream session pins are flipped to the survivor, held steps are
  released into the survivor's inbox, and decode resumes. Zero re-prefill;
  greedy decode is token-identical because the fp codec is byte-exact.

* **Snapshot restore** (:meth:`restore_session`): an unplanned kill left no
  handoff window. The client's recovery path calls this before falling back
  to full re-prefill: each stage either still holds the session live (the
  kill only destroyed one replica) or re-installs the latest background
  snapshot from the :class:`~repro.statexfer.snapstore.SnapshotStore`; pins
  are wired along the rebuilt route and the caller replays only the decode
  steps since the oldest restored cursor. Any gap — no snapshot for a
  stage, no healthy replica, torn blob — returns ``None`` and the caller
  re-prefills (at-least-once semantics are never weakened).

A third path makes the same machinery the *steady-state* data path
(disaggregated prefill/decode pools): :meth:`handoff_prefill` streams a
freshly built KV cache from a prefill-pool replica to the session's chosen
decode-pool home, chunk-by-chunk over HANDOFF envelopes — the FailSafe
observation that resilience-grade state movement doubles as a serving
primitive. The fp codec keeps the handoff byte-exact, so greedy decode on
the decode home is token-identical to decoding where the cache was built.

Anything that goes wrong mid-handoff (transfer error, vanished survivor,
missing pin) unwinds to the PR 2 behavior: the session is bounced via RETRY
and the client re-prefills. State transfer is an optimization, never a new
failure mode.
"""
from __future__ import annotations

import asyncio
import functools
import itertools
import time
from typing import Optional

from repro.core import (
    WorldBrokenError,
    WorldNotFoundError,
    WorldSpec,
    WorldStatus,
)
from repro.core.transport import payload_nbytes

from .codec import (
    FP,
    INT8,
    DEFAULT_CHUNK_BYTES,
    SessionSnapshot,
    SnapshotTransferError,
    int8_margin_ok,
    snapshot_assemble,
    snapshot_encode,
)


def cache_nbytes(cache) -> int:
    """Decoded size of a cache pytree — the bytes a handoff is about to
    move, for placement-cost scoring before any encode work happens."""
    import jax

    return sum(payload_nbytes(leaf) for leaf in jax.tree.leaves(cache))


async def stream_chunks(server, src_worker, dst_worker, world: str,
                        chunks: list, *, backpressure_bytes: int,
                        timeout_s: float, persistent: bool = False) -> list:
    """Stream wire chunks src -> dst over a pairwise world with byte-level
    backpressure and a hard receive deadline. Shared by session migration,
    warm bootstrap, and the prefill->decode handoff — any bulk state
    transfer between two live workers takes this path, so a silently hung
    peer costs ``timeout_s``, never a wedged coroutine.

    ``persistent=False`` (migration/bootstrap: rare transfers) builds a
    fresh world and tears it down afterwards. ``persistent=True`` (the
    steady-state handoff path: one transfer per session) reuses a world
    the caller already instantiated and leaves it up — a per-transfer
    rendezvous would dominate the handoff cost."""
    if not persistent:
        await server.instantiator.instantiate(
            [WorldSpec.pair(world, src_worker.worker_id,
                            dst_worker.worker_id)])
    transport = server.cluster.transport
    deadline = time.monotonic() + timeout_s

    async def _recv_all() -> list:
        return [await dst_worker.comm.recv(0, world) for _ in range(len(chunks))]

    try:
        recv_task = asyncio.ensure_future(_recv_all())
        try:
            for chunk in chunks:
                # the backpressure wait shares the transfer deadline: a
                # receiver that died mid-transfer stops draining the
                # channel, and without the bound this loop would spin
                # forever before ever reaching the wait_for below
                while transport.pending_bytes(world) > backpressure_bytes:
                    if recv_task.done() or time.monotonic() > deadline:
                        raise TimeoutError(
                            f"bulk transfer on {world} stalled")
                    await asyncio.sleep(0)
                await src_worker.comm.send(chunk, 1, world)
            return await asyncio.wait_for(
                recv_task, max(0.001, deadline - time.monotonic()))
        except BaseException:
            recv_task.cancel()
            raise
    finally:
        if not persistent:
            server._remove_world_everywhere(world)


class MigrationManager:
    def __init__(self, server, *, codec: str = FP,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 backpressure_bytes: int = 4 * 1024 * 1024,
                 freeze_timeout_s: float = 5.0,
                 transfer_timeout_s: float = 10.0,
                 placement_aware: bool = True) -> None:
        self.server = server
        self.codec = codec
        self.chunk_bytes = chunk_bytes
        self.backpressure_bytes = backpressure_bytes
        self.freeze_timeout_s = freeze_timeout_s
        self.transfer_timeout_s = transfer_timeout_s
        #: rank survivors/restore targets by (queue load + placement cost of
        #: the bytes about to move); False restores the placement-blind
        #: queue-depth-only choice for A/B benchmarking (bench_place)
        self.placement_aware = placement_aware
        self._uid = itertools.count()
        # -- counters (MetricsHub / bench_migrate read these) --------------
        self.migrations_total = 0
        self.migration_failures = 0
        self.heal_migrations_total = 0   # live handoffs on the heal path
        #: steady-state prefill -> decode-pool KV handoffs (disaggregation)
        self.handoffs_total = 0
        self.handoff_failures = 0
        self.handoff_s: list[float] = []
        self.handoff_bytes: list[int] = []
        self.restores_total = 0
        self.restore_failures = 0
        self.reprefills_total = 0        # full-history fallbacks (state lost)
        self.int8_fallbacks = 0          # thin-margin int8 -> fp demotions
        self.migration_s: list[float] = []
        self.migration_bytes: list[int] = []
        #: token-position accounting: positions resumed from moved/restored
        #: state vs positions recomputed (replayed suffix or re-prefill)
        self.recovered_tokens = 0
        self.recomputed_tokens = 0

    # ------------------------------------------------------------- placement
    def _rank(self, src_worker_id: Optional[str], candidates, nbytes: int):
        """Order transfer targets by (queue load, placement cost of moving
        ``nbytes`` from ``src_worker_id``); placement-blind mode reproduces
        the old (open_sessions, queue_depth) ordering exactly."""
        placement = getattr(self.server.cluster, "placement", None)
        if not self.placement_aware or placement is None:
            return min(candidates, key=lambda r: (r.open_sessions(),
                                                  r.queue_depth()))
        return min(candidates, key=lambda r: placement.score(
            r.open_sessions() + r.queue_depth(),
            src_worker_id, r.worker_id, nbytes))

    def _decode_capable(self, stage: int, exclude=None,
                        model: Optional[str] = None) -> list:
        """Replicas able to *hold and serve* a session's decode state: a
        prefill-pool replica is never a valid survivor/restore target — its
        executor has no decode executables and routing would send decode
        convoys into the pool the split exists to protect. With ``model=``,
        only replicas hosting that model's weights qualify — migrating a
        session under a replica that cannot run it just converts a planned
        handoff into a RETRY. One predicate, owned by the server, shared
        with handoff peer choice."""
        return self.server.decode_replicas(stage, exclude=exclude,
                                           model=model)

    # ------------------------------------------------------------ reporting
    def migration_p50_s(self) -> float:
        if not self.migration_s:
            return 0.0
        s = sorted(self.migration_s)
        return s[len(s) // 2]

    def handoff_p50_s(self) -> float:
        if not self.handoff_s:
            return 0.0
        s = sorted(self.handoff_s)
        return s[len(s) // 2]

    def stats(self) -> dict:
        return {
            "migrations_total": self.migrations_total,
            "migration_failures": self.migration_failures,
            "heal_migrations_total": self.heal_migrations_total,
            "handoffs_total": self.handoffs_total,
            "handoff_failures": self.handoff_failures,
            "handoff_p50_s": self.handoff_p50_s(),
            "handoff_bytes_total": sum(self.handoff_bytes),
            "migration_p50_s": self.migration_p50_s(),
            "migration_bytes_total": sum(self.migration_bytes),
            "restores_total": self.restores_total,
            "restore_failures": self.restore_failures,
            "reprefills_total": self.reprefills_total,
            "int8_fallbacks": self.int8_fallbacks,
            "recovered_tokens": self.recovered_tokens,
            "recomputed_tokens": self.recomputed_tokens,
        }

    # ------------------------------------------------------- planned handoff
    async def migrate_replica_sessions(self, rep) -> dict[int, bool]:
        """Drain-time bulk handoff: freeze every open session first (so no
        step sneaks past into the RETRY path), then hand them off one by
        one. Returns sid -> migrated?; failures fall back to re-prefill."""
        for sid in list(rep.sessions):
            rep.held.setdefault(sid, [])
        results: dict[int, bool] = {}
        for sid in list(rep.sessions):
            results[sid] = await self.migrate_session(rep, sid)
        return results

    async def migrate_session(self, rep, sid: int,
                              survivor=None, *, heal: bool = False) -> bool:
        """Live handoff of one session from ``rep`` to a same-stage survivor.
        Returns True on success; on any failure the session is released
        locally (the RETRY/re-prefill fallback takes over) and False is
        returned.

        ``heal=True`` is the fenced-replica discipline: the victim's route
        pins were already dropped when the watchdog fenced its edges, so
        missing pins are tolerated — whatever pins survive are flipped, the
        state lands on the target, and the client's restore path (which the
        controller's heal races against a grace window) rewires the rest of
        the route from live state with zero recompute."""
        server = self.server
        t_begin = time.monotonic()
        #: session's causal parent — the migration span joins the trace tree
        #: of the client call whose state is moving
        sess = rep.sessions.get(sid)
        parent = getattr(sess, "trace", None)
        if survivor is None:
            peers = self._decode_capable(
                rep.stage, exclude=rep,
                model=getattr(sess, "model", None))
            if not peers:
                self.migration_failures += 1
                self._release(rep, sid)
                return False
            est = cache_nbytes(sess.cache) if sess is not None else 0
            survivor = self._rank(rep.worker_id, peers, est)
        rep.held.setdefault(sid, [])          # freeze: hold new steps
        try:
            snap = await self._freeze_snapshot(rep, sid)
            moved, nbytes = await self._transfer(rep, survivor, snap)
            self._install(rep, survivor, sid, moved, heal=heal)
        except (SnapshotTransferError, WorldBrokenError, WorldNotFoundError,
                asyncio.TimeoutError, TimeoutError):
            self.migration_failures += 1
            self._release(rep, sid)
            return False
        self.migrations_total += 1
        if heal:
            self.heal_migrations_total += 1
        # appended pairwise only on success, so the lists stay in step and
        # the window trim below never deletes mismatched entries
        self.migration_s.append(time.monotonic() - t_begin)
        self.migration_bytes.append(nbytes)
        if len(self.migration_s) > 1024:      # p50 over the recent window;
            del self.migration_s[:512]        # never grows unbounded
            del self.migration_bytes[:512]
        if not heal:
            # heal handoffs are finished by the client's restore pass, which
            # does the recovered-token accounting for the whole route
            self.recovered_tokens += max(0, snap.step + 1)
        server._event("heal_migrate" if heal else "migrate",
                      f"{sid}: {rep.worker_id}->{survivor.worker_id}")
        server.tracer.span(parent, "migrate", t_begin, rep.worker_id,
                           f"sid={sid}->{survivor.worker_id}"
                           + (" heal" if heal else ""))
        return True

    # ------------------------------------------------- prefill/decode handoff
    async def handoff_prefill(self, rep, peer, sid: int, cache,
                              batch: int, step: int, trace=None,
                              model: Optional[str] = None,
                              tenant: Optional[str] = None) -> bool:
        """Steady-state disaggregation path: stream a freshly prefilled KV
        cache from prefill-pool replica ``rep`` to decode-pool ``peer`` and
        install it there at the prefill step boundary. Each chunk crosses
        the wire as a typed HANDOFF envelope (bulk byte-accounted like any
        other state transfer). Returns True on success; on any failure the
        caller drops the cache and bounces the client into full re-prefill
        on the prefill pool — the handoff is never a new failure mode.

        Unlike drain/heal migration there is nothing to freeze or repin
        here: the client has not seen the prefill response yet, so no
        decode step can be in flight, and the caller wires the decode
        route's pins onto ``peer`` itself.

        The transfer rides a *persistent* pairwise world per (prefill,
        decode) replica pair, instantiated on first use and kept up: a
        handoff happens once per session, and paying a world rendezvous
        every time would dominate the steady-state cost. The prefill
        replica's serve loop is serialized, so transfers on one pair world
        never interleave. Any failure drops the pair world (stale chunks
        must not greet the next handoff) and unwinds to RETRY."""
        from repro.serving.envelope import Envelope, Kind, ROLE_DECODE

        server = self.server
        loop = asyncio.get_event_loop()
        t_begin = time.monotonic()
        if hasattr(cache, "freeze"):
            # paged handle: pin the pool arrays + page list NOW — the encode
            # below runs on a worker thread while the serve loop keeps
            # decoding other sessions (which swaps in new pool arrays)
            cache = cache.freeze()
        snap = SessionSnapshot(session_id=sid, stage=rep.stage, step=step,
                               batch=batch, cache=cache,
                               origin=rep.worker_id)
        world = f"hand:{server.name}:{rep.worker_id}->{peer.worker_id}"
        try:
            chunks = await loop.run_in_executor(
                None, functools.partial(snapshot_encode, snap, codec=FP,
                                        chunk_bytes=self.chunk_bytes))
            envs = [Envelope(req_id=-1, session_id=sid, kind=Kind.HANDOFF,
                             step=step, payload=c, role=ROLE_DECODE,
                             trace=trace)
                    for c in chunks]
            def _ready(worker) -> bool:
                # a once-removed name stays in manager.worlds with status
                # REMOVED — only a HEALTHY world on *both* endpoints is a
                # usable channel
                w = worker.manager.worlds.get(world)
                return w is not None and w.status is WorldStatus.HEALTHY

            if (not _ready(rep.worker) or not _ready(peer.worker)
                    or world in server.broken_worlds):
                server._remove_world_everywhere(world)
                server.broken_worlds.discard(world)
                await server.instantiator.instantiate(
                    [WorldSpec.pair(world, rep.worker_id, peer.worker_id)])
                rep.handoff_worlds.add(world)
                peer.handoff_worlds.add(world)
            received = await self._stream(rep.worker, peer.worker, world,
                                          envs, persistent=True)
            assembled = await loop.run_in_executor(
                None, snapshot_assemble, [e.payload for e in received])
            if not peer.worker.alive or peer.draining:
                raise SnapshotTransferError(
                    "decode peer vanished mid-handoff")
            peer.install_session(sid, assembled.cache, assembled.batch,
                                 assembled.step, trace=trace,
                                 model=model, tenant=tenant)
        except (SnapshotTransferError, WorldBrokenError, WorldNotFoundError,
                asyncio.TimeoutError, TimeoutError) as e:
            self.handoff_failures += 1
            server.recorder.record("handoff_failure", session=sid,
                                   src=rep.worker_id, dst=peer.worker_id,
                                   error=repr(e))
            server._remove_world_everywhere(world)
            rep.handoff_worlds.discard(world)
            peer.handoff_worlds.discard(world)
            return False
        self.handoffs_total += 1
        self.handoff_s.append(time.monotonic() - t_begin)
        self.handoff_bytes.append(sum(e.nbytes for e in received))
        if len(self.handoff_s) > 4096:        # p50 over the recent window
            del self.handoff_s[:2048]
            del self.handoff_bytes[:2048]
        server._event("handoff",
                      f"{sid}: {rep.worker_id}->{peer.worker_id}")
        server.tracer.span(trace, "handoff", t_begin, rep.worker_id,
                           f"sid={sid}->{peer.worker_id}")
        return True

    # ---------------------------------------------------------- heal handoff
    async def heal_replica_sessions(self, rep) -> dict[int, bool]:
        """Live-migrate every open session off an alive-but-fenced replica.

        Unlike the drain path, the victim's upstream pins are usually gone
        (fencing dropped them) and no new steps can arrive — each session is
        frozen, streamed to a placement-ranked same-stage target (typically
        the fresh replacement on the victim's own host), and installed;
        the client's grace-window restore then rewires the route from live
        state and resumes with zero recomputed tokens. Failures fall back to
        snapshot restore / re-prefill exactly as before."""
        for sid in list(rep.sessions):
            rep.held.setdefault(sid, [])
        results: dict[int, bool] = {}
        for sid in list(rep.sessions):
            results[sid] = await self.migrate_session(rep, sid, heal=True)
        return results

    async def _freeze_snapshot(self, rep, sid: int) -> SessionSnapshot:
        """Wait for the session's in-flight step (if any) to land, then
        capture (cache, step) at the step boundary."""
        deadline = time.monotonic() + self.freeze_timeout_s
        while sid in rep.active:
            if time.monotonic() > deadline:
                raise SnapshotTransferError(f"freeze of {sid} timed out")
            await asyncio.sleep(0.001)
        sess = rep.sessions.get(sid)
        if sess is None:
            raise SnapshotTransferError(f"session {sid} vanished mid-freeze")
        cache = sess.cache
        if hasattr(cache, "freeze"):
            # snapshot-stable capture for paged sessions: the view pins the
            # pool arrays + page list so the worker-thread encode reads a
            # consistent image while the serve loop keeps decoding
            cache = cache.freeze()
        return SessionSnapshot(session_id=sid, stage=rep.stage,
                               step=sess.step, batch=sess.batch,
                               cache=cache, origin=rep.worker_id)

    async def _transfer(self, rep, survivor,
                        snap: SessionSnapshot) -> tuple[SessionSnapshot, int]:
        """Stream the snapshot rep -> survivor over a fresh pairwise world,
        with byte-level backpressure; returns the reassembled snapshot and
        the bytes that crossed the wire."""
        server = self.server
        loop = asyncio.get_event_loop()
        codec = self.codec
        if codec == INT8:
            gap = getattr(server, "session_margins", {}) \
                .get(snap.session_id)
            ok = await loop.run_in_executor(
                None, functools.partial(int8_margin_ok, gap, snap.cache))
            if not ok:          # thin argmax margin: move exact bytes
                codec = FP
                self.int8_fallbacks += 1
                server.recorder.record("codec_fallback", path="int8->fp",
                                       session=snap.session_id,
                                       where="migration")
        chunks = await loop.run_in_executor(
            None, functools.partial(snapshot_encode, snap, codec=codec,
                                    chunk_bytes=self.chunk_bytes))
        world = f"mig:{server.name}:{snap.session_id}:{next(self._uid)}"
        received = await self._stream(rep.worker, survivor.worker, world,
                                      chunks)
        assembled = await loop.run_in_executor(None, snapshot_assemble,
                                               received)
        return assembled, sum(c.nbytes for c in received)

    async def _stream(self, src_worker, dst_worker, world: str,
                      chunks: list, persistent: bool = False) -> list:
        # seam for tests (torn-transfer injection) and subclasses
        return await stream_chunks(
            self.server, src_worker, dst_worker, world, chunks,
            backpressure_bytes=self.backpressure_bytes,
            timeout_s=self.transfer_timeout_s, persistent=persistent)

    def _install(self, rep, survivor, sid: int,
                 snap: SessionSnapshot, *, heal: bool = False) -> None:
        """Install on the survivor, flip pins, release held steps. Runs
        without awaits so no envelope can interleave half-flipped state.

        The drain path (``heal=False``) demands a fully pinned route — a
        missing pin there means the session state machine is torn and the
        re-prefill fallback is safer. The heal path tolerates missing pins
        (fencing already dropped them): surviving pins are flipped, the rest
        of the route is rewired by the client's restore pass from the live
        state this install just placed."""
        from repro.serving.pipeline import CLIENT, _edge

        server = self.server
        sess = rep.sessions.get(sid)
        if sess is None or not survivor.worker.alive or survivor.draining:
            raise SnapshotTransferError("endpoint vanished before install")
        # downstream pin: same next-hop replica (or the client), new edge
        down_world = rep.router.pinned(sid)
        new_down = None
        if down_world is None:
            if not heal:
                raise SnapshotTransferError(f"session {sid} has no route pin")
        else:
            down = server._world_to_replica.get(down_world)   # None -> client
            new_down = _edge(server.name, survivor.worker_id,
                             CLIENT if down is None else down.worker_id)
            if new_down not in survivor.router.healthy():
                if heal:
                    new_down = None
                else:
                    raise SnapshotTransferError(
                        f"survivor lacks downstream edge {new_down}")
        # upstream pin: the router (client's or an upstream replica's) that
        # pinned this session onto rep must repin onto survivor
        flips = []
        for world_u, router in rep.upstream_edges:
            if router.pinned(sid) == world_u:
                new_up = next((w for w, r2 in survivor.upstream_edges
                               if r2 is router), None)
                if new_up is None:
                    raise SnapshotTransferError(
                        "no survivor edge for the pinning upstream router")
                flips.append((router, new_up))
        if not flips and not heal:
            raise SnapshotTransferError(f"session {sid} has no upstream pin")

        survivor.install_session(sid, snap.cache, snap.batch, snap.step,
                                 trace=getattr(sess, "trace", None),
                                 model=getattr(sess, "model", None),
                                 tenant=getattr(sess, "tenant", None))
        if new_down is not None:
            survivor.router.pin(sid, new_down)
        for router, new_up in flips:
            router.pin(sid, new_up)
        server.recorder.record(
            "pin_flip", session=sid, src=rep.worker_id,
            dst=survivor.worker_id, heal=heal,
            flips=len(flips) + (1 if new_down is not None else 0))
        rep.drop_session(sid)      # paged pages return to the source pool
        rep.router.unpin(sid)
        # release: held steps first (FIFO), then any straggler that is still
        # in rep's channels/pumps gets forwarded via the migrated map
        rep.migrated[sid] = survivor
        for item in rep.held.pop(sid, []):
            survivor.inbox.put_nowait(item)

    def _release(self, rep, sid: int) -> None:
        """Failed handoff: un-freeze and hand held steps back to the local
        serve loop (which will serve them, or RETRY them if draining).

        They go back through the *inbox*, not the stash: the serve loop only
        re-checks its stash after waking from ``inbox.get()``, so a
        stash-only release would strand the steps (and their clients) until
        unrelated traffic happened to arrive. Per-session order is safe —
        the protocol allows one in-flight step per session, and held items
        re-enqueue in held order."""
        for item in rep.held.pop(sid, []):
            rep.inbox.put_nowait(item)

    # ------------------------------------------------------ snapshot restore
    async def restore_session(self, sid: int, *,
                              count_failures: bool = True,
                              parent=None) -> Optional[int]:
        """Rebuild a lost session from live survivor state + stored
        snapshots. Returns the oldest restored decode position ``t0`` (the
        caller replays positions ``t0+1..``), or None if any stage cannot be
        restored — the caller then falls back to full re-prefill.

        ``count_failures=False`` suppresses the failure counter for the
        grace-window retry loop, which probes every few milliseconds while
        a heal is in flight — one *logical* recovery failure must count
        once, not once per probe."""
        from repro.serving.pipeline import CLIENT, _edge

        server = self.server
        t_begin = time.monotonic()
        # a tagged session must restore onto replicas hosting its model —
        # the client records the tag because the dead replica can't tell us
        model = getattr(server, "session_models", {}).get(sid)
        tenant = getattr(server, "session_tenants", {}).get(sid)
        route, installs, steps = [], [], []
        for stage in range(server.n_stages):
            live = [r for r in server.replicas[stage]
                    if r.worker.alive and not r.draining
                    and sid in r.sessions and sid not in r.held]
            if live:
                rep = live[0]
                route.append(rep)
                installs.append(None)
                steps.append(rep.sessions[sid].step)
                continue
            snap = (server.snapshots.latest(sid, stage)
                    if server.snapshots is not None else None)
            healthy = self._decode_capable(stage, model=model)
            if snap is None or not healthy:
                if count_failures:
                    self.restore_failures += 1
                return None
            # placement-aware install target: the snapshot's bytes prefer
            # to land near where they were captured (same host = cheap)
            rep = self._rank(snap.origin, healthy, cache_nbytes(snap.cache))
            route.append(rep)
            installs.append(snap)
            steps.append(snap.step)
        t0 = min(steps)
        # replay idempotence: the resumed client re-feeds positions from
        # t0+1 AND re-feeds its pending token at the old cursor when the
        # lost step had already been integrated everywhere — an exact
        # overwrite for full attention caches, but a double-integration for
        # SSM/ring state. Restore therefore requires full caches throughout;
        # SSM/windowed pipelines take the re-prefill fallback.
        if model is None or model == server.default_model:
            full = all(server.stage_executors[i].full_cache
                       for i in range(server.n_stages))
        else:
            full = all(server.model_executor(model, i).full_cache
                       for i in range(server.n_stages))
        if not full:
            if count_failures:
                self.restore_failures += 1
            return None
        # the route must be fully wired before any pin flips
        entry = _edge(server.name, CLIENT, route[0].worker_id)
        hops = [entry]
        for i, rep in enumerate(route):
            nxt = (CLIENT if i == len(route) - 1
                   else route[i + 1].worker_id)
            hops.append(_edge(server.name, rep.worker_id, nxt))
        routers = [server.client_router] + [r.router for r in route]
        if any(h not in router.healthy()
               for h, router in zip(hops, routers)):
            if count_failures:
                self.restore_failures += 1
            return None
        for rep, snap in zip(route, installs):
            if snap is not None:
                rep.install_session(sid, snap.cache, snap.batch, snap.step,
                                    trace=parent, model=model, tenant=tenant)
        for router, hop in zip(routers, hops):
            router.pin(sid, hop)
        self.restores_total += 1
        self.recovered_tokens += max(0, t0 + 1)
        server._event("restore", f"{sid} from snapshots@t<={t0}")
        server.tracer.span(parent, "restore", t_begin, "",
                           f"sid={sid} t0={t0}")
        return t0

"""Distributed train step factory.

``make_train_step`` closes over (model, optimizer config) and returns the
pure step function ``(params, opt_state, batch) -> (params, opt_state,
metrics)``; ``shardings_for`` maps the logical axes of every argument through
the active rule set so launch code can hand jit explicit in/out shardings —
the same path the multi-pod dry-run lowers.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

from repro.configs.shapes import batch_logical_axes
from repro.distributed import tree_logical_sharding
from .optimizer import (
    AdamWConfig,
    abstract_opt_state,
    adamw_update,
    init_opt_state,
    opt_logical_axes,
)


def make_train_step(model, opt_cfg: AdamWConfig) -> Callable:
    def train_step(params, opt_state, batch):
        def loss_only(p):
            loss, metrics = model.loss(p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_only, has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def train_state_axes(model) -> tuple[Any, Any]:
    """(param logical axes, opt-state logical axes)."""
    p_axes = model.logical_axes()
    return p_axes, opt_logical_axes(p_axes)


def shardings_for(model, *, include_opt: bool = True):
    """NamedShardings for (params, opt_state, batch) under the active rules.

    Returns None outside an ``axis_rules`` context (single-device paths).
    """
    p_axes, o_axes = train_state_axes(model)
    p_sh = tree_logical_sharding(p_axes)
    if p_sh is None:
        return None
    b_axes = batch_logical_axes(model.cfg)
    b_sh = tree_logical_sharding(b_axes)
    if not include_opt:
        return p_sh, b_sh
    o_sh = tree_logical_sharding(o_axes)
    return p_sh, o_sh, b_sh


__all__ = [
    "AdamWConfig", "abstract_opt_state", "init_opt_state",
    "make_train_step", "shardings_for", "train_state_axes",
]

from .data import DataConfig, MarkovStream, UniformStream, make_stream
from .optimizer import (
    AdamWConfig,
    abstract_opt_state,
    adamw_update,
    cosine_schedule,
    global_norm,
    init_opt_state,
    opt_logical_axes,
)
from .train_step import make_train_step, shardings_for, train_state_axes

__all__ = [
    "DataConfig", "MarkovStream", "UniformStream", "make_stream",
    "AdamWConfig", "abstract_opt_state", "adamw_update", "cosine_schedule",
    "global_norm", "init_opt_state", "opt_logical_axes",
    "make_train_step", "shardings_for", "train_state_axes",
]

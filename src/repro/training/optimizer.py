"""AdamW + LR schedules, pytree-native (no optax dependency).

Optimizer state is a pytree congruent with params (m, v per leaf + scalar
step), so it inherits the params' logical sharding — under FSDP rules the
AdamW moments shard over (data, model) exactly like their weights, which is
what makes yi-34b trainable on 16 GB chips.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = cfg.lr * step / max(cfg.warmup_steps, 1)
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = cfg.min_lr_ratio * cfg.lr + (1 - cfg.min_lr_ratio) * cfg.lr \
            * 0.5 * (1 + jnp.cos(math.pi * frac))
        return jnp.where(step < cfg.warmup_steps, warm, cos)
    return lr


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params: Any) -> dict:
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(sds, abstract_params),
        "v": jax.tree.map(sds, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_logical_axes(param_axes: Any) -> dict:
    """Moments share their parameter's logical axes; step is replicated."""
    ident = lambda a: a
    return {
        "m": jax.tree.map(ident, param_axes,
                          is_leaf=lambda x: isinstance(x, tuple)),
        "v": jax.tree.map(ident, param_axes,
                          is_leaf=lambda x: isinstance(x, tuple)),
        "step": (),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any, state: dict
                 ) -> tuple[Any, dict, dict]:
    """One AdamW step with global-norm clipping and decoupled weight decay."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = cosine_schedule(cfg)(step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

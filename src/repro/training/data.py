"""Synthetic data pipeline.

Two generators:

* ``MarkovStream`` — tokens from a fixed random bigram table. A language
  model *can learn* this distribution, so training examples show a real
  falling loss curve, not noise.
* ``UniformStream`` — i.i.d. tokens for shape/throughput exercises.

Both are shardable (rank/num_shards split by seed), infinite, and produce
``{tokens, targets}`` batches with next-token targets — the contract of
``model.loss``. Multimodal variants attach stub frontend embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.models import ModelConfig


@dataclasses.dataclass
class DataConfig:
    batch_size: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    rank: int = 0
    num_shards: int = 1
    branching: int = 4          # Markov: candidate successors per token


class MarkovStream:
    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)  # table shared by all shards
        v = cfg.vocab_size
        self.successors = rng.integers(0, v, size=(v, cfg.branching))
        self.rng = np.random.default_rng(
            (cfg.seed + 1) * 7919 + cfg.rank)     # per-shard sampling stream

    def _sequence(self, length: int) -> np.ndarray:
        v = self.cfg.vocab_size
        out = np.empty(length + 1, np.int32)
        out[0] = self.rng.integers(0, v)
        picks = self.rng.integers(0, self.cfg.branching, size=length)
        for i in range(length):
            out[i + 1] = self.successors[out[i], picks[i]]
        return out

    def __iter__(self) -> Iterator[dict]:
        b, s = self.cfg.batch_size, self.cfg.seq_len
        while True:
            seqs = np.stack([self._sequence(s) for _ in range(b)])
            yield {"tokens": seqs[:, :-1], "targets": seqs[:, 1:]}


class UniformStream:
    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed * 31 + cfg.rank)

    def __iter__(self) -> Iterator[dict]:
        b, s, v = self.cfg.batch_size, self.cfg.seq_len, self.cfg.vocab_size
        while True:
            seqs = self.rng.integers(0, v, size=(b, s + 1), dtype=np.int32)
            yield {"tokens": seqs[:, :-1], "targets": seqs[:, 1:]}


def attach_frontend_stubs(batch: dict, cfg: ModelConfig,
                          rng: np.random.Generator) -> dict:
    """Add stub-modality inputs for audio/vlm families (assignment carve-out)."""
    b, s = batch["tokens"].shape
    if cfg.family == "audio":
        batch["frames"] = rng.standard_normal(
            (b, cfg.encoder_frames, cfg.d_model)).astype(np.float32) * 0.02
    if cfg.family == "vlm":
        batch["input_embeds"] = rng.standard_normal(
            (b, s, cfg.d_model)).astype(np.float32) * 0.02
        pos = np.broadcast_to(np.arange(s, dtype=np.int32)[None], (b, s))
        batch["mrope_positions"] = np.stack([pos, pos, pos])
    return batch


def make_stream(cfg: ModelConfig, batch_size: int, seq_len: int,
                kind: str = "markov", seed: int = 0, rank: int = 0,
                num_shards: int = 1):
    dc = DataConfig(batch_size=batch_size, seq_len=seq_len,
                    vocab_size=cfg.vocab_size, seed=seed, rank=rank,
                    num_shards=num_shards)
    stream = MarkovStream(dc) if kind == "markov" else UniformStream(dc)
    rng = np.random.default_rng(seed + 1234)

    def gen():
        for batch in stream:
            yield attach_frontend_stubs(batch, cfg, rng)

    return gen()

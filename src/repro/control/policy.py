"""Scaling policies: StageSnapshot in, ScaleDecision out.

Pure functions of observed state — no cluster access, no side effects — so
they are unit-testable without an event loop and swappable at runtime. The
controller composes one policy per stage (or one shared policy) with the
executor that actually adds/drains replicas.

Provided policies:

* :class:`TargetQueueDepthPolicy` — classic queue-proportional sizing: keep
  per-replica backlog near a target (the serving-survey "load-adaptive
  replica management" axis).
* :class:`LatencySLOPolicy` — scale on the user-visible signal: grow when
  the stage latency EWMA breaches the SLO, shrink when it is comfortably
  under and the queue is near-empty.
* :class:`TokenRatePolicy` — the generative-plane signal: size the stage by
  decode tokens/s against a per-replica capacity target, and never shrink
  while open sessions would have to relocate en masse.
* :class:`TTFTSLOPolicy` — the prefill-pool signal: grow on TTFT (per-
  prefill service EWMA, handoff included) breaching its SLO or on queue
  backlog; shrink only when both are comfortably low.
* :class:`TailLatencySLOPolicy` — the fleet-scale signal: decide on the
  stage digest's *tail* percentiles (``p95_ttft_s`` / ``p99_decode_s``,
  computed from merged LogSketches — see obs/digest.py) instead of means.
  Means hide exactly the incidents SLOs are written about: one slow
  replica in fifty barely moves the stage mean but owns the p99.
* :class:`PerTenantSLOPolicy` — the multi-tenant, multi-model signal: hold
  every tenant's client-observed p95 TTFT under that tenant's own SLO
  (:class:`TenantSpec`), preferring a *residency swap* (retarget one
  replica from an over-provisioned model to the starved one — zero fleet
  growth) over scale-up. Swap votes ride the same :class:`ScaleDecision`
  (``swap_from``/``swap_to`` at delta=0), so hysteresis/cooldown wrap them
  like any other action.
* :class:`HysteresisPolicy` — a wrapper adding the stability knobs every
  real autoscaler needs: K-consecutive-votes confirmation, post-action
  cooldown, and ±1 step clamping. Wrap any policy above with it to stop
  flapping on noisy load.
* :class:`DisaggregatedStagePolicy` — per-role composition for a stage
  with split pools: the prefill policy votes on the ``prefill`` slice of
  the StageSnapshot, the decode policy on the ``decode`` slice, and each
  resulting decision carries its ``role`` so the controller scales the
  right pool. A stage without split pools falls back to the colocated
  policy over the whole snapshot.
* :class:`SpecDecodePolicy` — the speculative-decoding signal: trade
  capacity between the draft pool and the target pools on the measured
  draft-token acceptance rate. High acceptance grows the draft pool
  (optionally funded by draining a decode-capable replica — constant
  fleet size); low acceptance drains it back into plain target decode.

Generative serving makes scale-down stateful: draining a replica relocates
every session pinned to it (each one re-prefills its full history on a
survivor). ``shrink_open_sessions`` on the queue/latency policies caps how
many open sessions per replica a voluntary shrink may displace.
"""
from __future__ import annotations

import copy
import dataclasses
import math
import time
from typing import Optional, Protocol

from .metrics import StageSnapshot

HOLD_REASON = "hold"


@dataclasses.dataclass(frozen=True)
class ScaleDecision:
    stage: int
    delta: int            # >0 scale up, <0 scale down, 0 hold
    reason: str
    #: pool the action targets (None = the colocated 'both' pool)
    role: Optional[str] = None
    #: scale-up only: model the new replica(s) should come up hosting
    #: (None = the pipeline's default model, legacy behavior)
    model: Optional[str] = None
    #: residency action instead of (or alongside) a size change: direct
    #: one stage replica to swap ``swap_from`` -> ``swap_to``. delta=0 with
    #: swap_to set is a *swap vote*, not a hold — capacity is rebalanced
    #: across models at constant fleet size, the cheapest lever a
    #: multi-model controller has.
    swap_from: Optional[str] = None
    swap_to: Optional[str] = None

    @property
    def hold(self) -> bool:
        return self.delta == 0 and self.swap_to is None

    def as_record(self) -> dict:
        """Flight-recorder / JSON form of the vote (the ``reason`` string
        is the policy's own explanation — the 'vote' a crash dump needs to
        show why the fleet was the size it was)."""
        rec = {"stage": self.stage, "delta": self.delta,
               "reason": self.reason, "role": self.role or "both"}
        if self.model is not None:
            rec["model"] = self.model
        if self.swap_to is not None:
            rec["swap_from"] = self.swap_from
            rec["swap_to"] = self.swap_to
        return rec


def hold(stage: int, reason: str = HOLD_REASON,
         role: Optional[str] = None) -> ScaleDecision:
    return ScaleDecision(stage, 0, reason, role)


class ScalingPolicy(Protocol):
    def decide(self, snap: StageSnapshot) -> ScaleDecision: ...


@dataclasses.dataclass
class TargetQueueDepthPolicy:
    """Size the stage so per-replica queue depth sits near ``target``.

    desired = ceil(total_backlog / target); the dead band between
    ``scale_down_at`` and ``target`` prevents shrink/grow oscillation at
    the boundary.
    """

    target: float = 4.0
    scale_down_at: float = 0.5     # shrink only when backlog/replica < this
    min_replicas: int = 1
    max_replicas: int = 8
    #: refuse voluntary shrink while it would displace more than this many
    #: open sessions per replica (None = session-blind, legacy behavior)
    shrink_open_sessions: Optional[float] = None

    def decide(self, snap: StageSnapshot) -> ScaleDecision:
        n = max(snap.n_replicas, 1)
        per = snap.queue_per_replica
        if per > self.target:
            desired = min(math.ceil(snap.queue_total / self.target),
                          self.max_replicas)
            if desired > n:
                return ScaleDecision(
                    snap.stage, desired - n,
                    f"queue/replica {per:.1f} > target {self.target:g}")
        elif per < self.scale_down_at and n > self.min_replicas:
            if (self.shrink_open_sessions is not None
                    and snap.open_sessions / n > self.shrink_open_sessions):
                return hold(snap.stage,
                            f"{snap.open_sessions} open sessions pin capacity")
            return ScaleDecision(
                snap.stage, -1,
                f"queue/replica {per:.2f} < {self.scale_down_at:g}")
        return hold(snap.stage)


@dataclasses.dataclass
class LatencySLOPolicy:
    """Grow when stage latency breaches ``slo_s``; shrink when it is under
    ``shrink_frac * slo_s`` *and* the queue is nearly empty (latency alone
    is not a safe shrink signal — an idle stage has great latency)."""

    slo_s: float
    shrink_frac: float = 0.3
    idle_queue: float = 0.5
    min_replicas: int = 1
    max_replicas: int = 8

    def decide(self, snap: StageSnapshot) -> ScaleDecision:
        n = max(snap.n_replicas, 1)
        lat = snap.latency_s
        if lat > self.slo_s and n < self.max_replicas:
            return ScaleDecision(
                snap.stage, 1, f"latency {lat * 1e3:.0f}ms > SLO "
                               f"{self.slo_s * 1e3:.0f}ms")
        if (lat < self.shrink_frac * self.slo_s
                and snap.queue_per_replica < self.idle_queue
                and n > self.min_replicas):
            return ScaleDecision(
                snap.stage, -1,
                f"latency {lat * 1e3:.0f}ms well under SLO, queue idle")
        return hold(snap.stage)


@dataclasses.dataclass
class TokenRatePolicy:
    """Size a stage by decode throughput: grow when the per-replica token
    rate exceeds ``target_tokens_per_s`` (the replica's measured or budgeted
    decode capacity), shrink when the stage is well under capacity *and*
    few enough sessions would have to relocate.

    This is the policy that watches the generative data plane directly —
    queue depth lags token demand because one queued DECODE envelope is one
    *step*, not one request.

    ``migration_aware=True`` removes the open-sessions scale-down guard: with
    the state-transfer subsystem, draining a replica hands its sessions off
    live (no re-prefill storm), so displaced sessions are no longer a reason
    to keep surplus capacity around. Without migration each displaced
    session pays a full-history re-prefill, which is why the guard defaults
    on.
    """

    target_tokens_per_s: float
    shrink_frac: float = 0.25
    shrink_open_sessions: float = 2.0
    min_replicas: int = 1
    max_replicas: int = 8
    migration_aware: bool = False

    def decide(self, snap: StageSnapshot) -> ScaleDecision:
        n = max(snap.n_replicas, 1)
        per = snap.tokens_per_s / n
        if per > self.target_tokens_per_s and n < self.max_replicas:
            desired = min(
                math.ceil(snap.tokens_per_s / self.target_tokens_per_s),
                self.max_replicas)
            return ScaleDecision(
                snap.stage, max(desired - n, 1),
                f"{per:.0f} tok/s/replica > target "
                f"{self.target_tokens_per_s:g}")
        if (per < self.shrink_frac * self.target_tokens_per_s
                and n > self.min_replicas
                and (self.migration_aware
                     or snap.open_sessions / n <= self.shrink_open_sessions)):
            return ScaleDecision(
                snap.stage, -1,
                f"{per:.0f} tok/s/replica well under target"
                + (" (sessions migrate live)" if self.migration_aware
                   else ""))
        return hold(snap.stage)


@dataclasses.dataclass
class TTFTSLOPolicy:
    """Prefill-pool sizing: the user-visible prefill signal is time to
    first token. Grow when the pool's TTFT EWMA breaches ``slo_s`` or the
    per-replica backlog exceeds ``queue_target`` (queue depth leads TTFT —
    a prefill burst shows up as backlog one EWMA half-life before it shows
    up as latency); shrink only when TTFT is comfortably under the SLO
    *and* the queue is near-empty."""

    slo_s: float
    queue_target: float = 4.0
    shrink_frac: float = 0.3
    idle_queue: float = 0.5
    min_replicas: int = 1
    max_replicas: int = 8

    def decide(self, snap: StageSnapshot) -> ScaleDecision:
        n = max(snap.n_replicas, 1)
        ttft = snap.ttft_s
        if n < self.max_replicas:
            if ttft > self.slo_s:
                return ScaleDecision(
                    snap.stage, 1,
                    f"TTFT {ttft * 1e3:.0f}ms > SLO "
                    f"{self.slo_s * 1e3:.0f}ms")
            if snap.queue_per_replica > self.queue_target:
                return ScaleDecision(
                    snap.stage, 1,
                    f"prefill queue/replica {snap.queue_per_replica:.1f} "
                    f"> {self.queue_target:g}")
        if (ttft < self.shrink_frac * self.slo_s
                and snap.queue_per_replica < self.idle_queue
                and n > self.min_replicas):
            return ScaleDecision(
                snap.stage, -1,
                f"TTFT {ttft * 1e3:.0f}ms well under SLO, queue idle")
        return hold(snap.stage)


@dataclasses.dataclass
class TailLatencySLOPolicy:
    """Tail-percentile sizing over digest summaries.

    Grows when the stage's sketch-backed tail breaches the objective:
    ``p95_ttft_s > ttft_slo_s`` (prefill tail) or ``p99_decode_s >
    decode_slo_s`` (decode tail). Shrinks only when both watched tails sit
    under ``shrink_frac`` of their SLOs *and* the queue is near-empty.
    Either SLO may be None to watch a single tail. Snapshots from replicas
    that keep no sketches report 0.0 tails — the policy holds rather than
    shrink on a signal that is absent (``require_signal``)."""

    ttft_slo_s: Optional[float] = None
    decode_slo_s: Optional[float] = None
    shrink_frac: float = 0.3
    idle_queue: float = 0.5
    min_replicas: int = 1
    max_replicas: int = 8
    require_signal: bool = True

    def decide(self, snap: StageSnapshot) -> ScaleDecision:
        n = max(snap.n_replicas, 1)
        p95_ttft = getattr(snap, "p95_ttft_s", 0.0)
        p99_dec = getattr(snap, "p99_decode_s", 0.0)
        if (self.ttft_slo_s is not None and p95_ttft > self.ttft_slo_s
                and n < self.max_replicas):
            return ScaleDecision(
                snap.stage, 1,
                f"p95 TTFT {p95_ttft * 1e3:.0f}ms > SLO "
                f"{self.ttft_slo_s * 1e3:.0f}ms")
        if (self.decode_slo_s is not None and p99_dec > self.decode_slo_s
                and n < self.max_replicas):
            return ScaleDecision(
                snap.stage, 1,
                f"p99 decode {p99_dec * 1e3:.0f}ms > SLO "
                f"{self.decode_slo_s * 1e3:.0f}ms")
        watched = [(p95_ttft, self.ttft_slo_s), (p99_dec, self.decode_slo_s)]
        watched = [(v, slo) for v, slo in watched if slo is not None]
        if self.require_signal and not any(v > 0 for v, _ in watched):
            return hold(snap.stage, "no tail signal yet")
        if (all(v < self.shrink_frac * slo for v, slo in watched)
                and snap.queue_per_replica < self.idle_queue
                and n > self.min_replicas):
            return ScaleDecision(
                snap.stage, -1, "tails well under SLO, queue idle")
        return hold(snap.stage)


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's contract with the pool: which model its traffic runs,
    what TTFT tail it was promised, and its weight in the replica-side
    WDRR fair scheduler (informational here — the policy reads latency,
    the scheduler enforces the weight)."""

    name: str
    model: Optional[str] = None    # None = the pipeline's default model
    ttft_slo_s: float = 1.0
    weight: float = 1.0


@dataclasses.dataclass
class PerTenantSLOPolicy:
    """Multi-tenant, multi-model sizing: keep every tenant's client-observed
    p95 TTFT under its own SLO, preferring *residency rebalancing* (swap a
    replica from an over-provisioned model to the starved one — zero fleet
    growth) over scale-up.

    Per tick, the policy finds the worst-breached tenant (largest
    ``p95/slo`` ratio over tenants with ≥ ``min_samples`` observations).
    If a *donor* model exists — one no breached tenant runs, with more
    resident replicas than the starved model and either spare residency
    (≥2 replicas) or zero open sessions — it votes ``swap_from=donor,
    swap_to=starved`` at delta=0. Otherwise it votes a model-tagged
    scale-up, so the healed capacity comes up already hosting the starved
    model. With every observed tenant comfortably under (``shrink_frac``)
    and the queue idle, it votes shrink; anything else holds.

    Reads the snapshot's multi-model dimensions (``tenant_tails``,
    ``model_replicas``, ``model_sessions`` — see control/metrics.py);
    absent dimensions (single-tenant pipeline) make it a pure hold policy.
    """

    tenants: list
    shrink_frac: float = 0.3
    idle_queue: float = 0.5
    min_samples: int = 8
    min_replicas: int = 1
    max_replicas: int = 8

    def _spec(self, name: str) -> Optional[TenantSpec]:
        for t in self.tenants:
            if t.name == name:
                return t
        return None

    def decide(self, snap: StageSnapshot) -> ScaleDecision:
        tails = getattr(snap, "tenant_tails", {}) or {}
        n = max(snap.n_replicas, 1)
        worst: Optional[tuple[float, TenantSpec, float]] = None
        observed = 0
        for name, tail in tails.items():
            spec = self._spec(name)
            if spec is None or tail.get("n", 0) < self.min_samples:
                continue
            observed += 1
            ratio = tail["p95_ttft_s"] / spec.ttft_slo_s
            if ratio > 1.0 and (worst is None or ratio > worst[0]):
                worst = (ratio, spec, tail["p95_ttft_s"])
        if worst is not None:
            ratio, spec, p95 = worst
            target = spec.model or "default"
            donor = self._donor(snap, target)
            breach = (f"tenant {spec.name!r} p95 TTFT {p95 * 1e3:.0f}ms > "
                      f"SLO {spec.ttft_slo_s * 1e3:.0f}ms")
            if donor is not None:
                return ScaleDecision(
                    snap.stage, 0,
                    f"{breach}: swap a {donor!r} replica to {target!r}",
                    swap_from=donor, swap_to=target)
            if n < self.max_replicas:
                return ScaleDecision(
                    snap.stage, 1, f"{breach}: no donor model, grow",
                    model=spec.model)
            return hold(snap.stage, f"{breach}: at max_replicas, no donor")
        if (observed and n > self.min_replicas
                and snap.queue_per_replica < self.idle_queue
                and all(
                    tails[name]["p95_ttft_s"]
                    < self.shrink_frac * spec.ttft_slo_s
                    for name in tails
                    if (spec := self._spec(name)) is not None
                    and tails[name].get("n", 0) >= self.min_samples)):
            return ScaleDecision(
                snap.stage, -1, "every tenant well under SLO, queue idle")
        return hold(snap.stage,
                    "no tenant signal" if not observed else HOLD_REASON)

    def _donor(self, snap: StageSnapshot, target: str) -> Optional[str]:
        """A model that can give up one residency for ``target``: not run
        by any breached tenant's spec... more precisely, any model with
        more resident replicas than the starved one and spare capacity
        (≥2 replicas, or zero open sessions at this stage). Prefers the
        most over-provisioned, least-loaded donor."""
        reps = getattr(snap, "model_replicas", {}) or {}
        sessions = getattr(snap, "model_sessions", {}) or {}
        starved = reps.get(target, 0)
        best: Optional[tuple[tuple, str]] = None
        for name, count in reps.items():
            if name == target or count <= starved:
                continue
            open_here = sessions.get(name, 0)
            if count < 2 and open_here > 0:
                continue
            key = (count, -open_here)
            if best is None or key > best[0]:
                best = (key, name)
        return best[1] if best is not None else None


@dataclasses.dataclass
class DisaggregatedStagePolicy:
    """Per-role composition for a disaggregated stage.

    ``prefill`` votes on the stage's prefill-pool slice (queue depth /
    TTFT), ``decode`` on the decode-pool slice (tokens/s + open sessions);
    each vote is stamped with its role so the controller adds or drains in
    the right pool. Policies carry hysteresis state, so give each stage its
    own instance (the controller deep-copies a shared one). ``colocated``
    governs 'both' replicas — the whole stage when no split pools exist
    (role-less vote, byte-compatible with a plain single-policy stage) and
    the 'both' slice of a mixed stage otherwise; it defaults to an
    *independent copy* of the decode policy, so no pool is ever left
    unmanaged and no hysteresis state is shared across slices.
    """

    prefill: ScalingPolicy
    decode: ScalingPolicy
    colocated: Optional[ScalingPolicy] = None

    def __post_init__(self) -> None:
        if self.colocated is None:
            self.colocated = copy.deepcopy(self.decode)

    def decide_many(self, snap: StageSnapshot) -> list[ScaleDecision]:
        slices = getattr(snap, "role_slices", {}) or {}
        out: list[ScaleDecision] = []
        split = "prefill" in slices or "decode" in slices
        if not split:
            return [self.colocated.decide(snap)]
        if "prefill" in slices:
            d = self.prefill.decide(slices["prefill"])
            out.append(dataclasses.replace(d, role="prefill"))
        if "decode" in slices:
            d = self.decode.decide(slices["decode"])
            out.append(dataclasses.replace(d, role="decode"))
        if "both" in slices:
            d = self.colocated.decide(slices["both"])
            out.append(dataclasses.replace(d, role="both"))
        return out

    def decide(self, snap: StageSnapshot) -> ScaleDecision:
        """Single-decision view (first non-hold vote) for callers that do
        not speak ``decide_many``."""
        for d in self.decide_many(snap):
            if not d.hold:
                return d
        return hold(snap.stage)


@dataclasses.dataclass
class SpecDecodePolicy:
    """Acceptance-driven capacity trading between the draft pool and the
    target (decode-capable) pools of one stage.

    Speculative decoding only pays while the target pool keeps accepting
    the draft's proposals: every accepted token is a target decode step
    the fleet skipped, every rejected one is pure draft-side waste. The
    per-replica acceptance EWMAs (judged on the decode side, where the
    VERIFY dispatch compares draft tokens against target argmax) roll up
    into ``StageSnapshot.acceptance_rate``; this policy votes on that
    signal:

    * acceptance >= ``grow_at`` and draft headroom -> grow the draft pool,
      optionally *funded* by draining one decode-capable replica
      (``trade=True``): constant fleet size, capacity shifted to where the
      speedup lives. The drain-guard refuses to give up the last
      decode-capable replica, so an over-eager trade degrades to a hold.
    * acceptance <= ``shrink_at`` -> drain the draft pool (proposals are
      mostly rejected; the capacity serves better as plain target decode),
      optionally returning the replica to the decode pool.
    * in between, or with fewer than ``min_tokens`` proposals ever judged
      (cold EWMAs), hold.

    Draft-pool votes carry ``role="draft"``; the paired trade vote carries
    the donor/recipient pool's own role. Wrap with
    :class:`HysteresisPolicy` per pool if the acceptance signal is noisy.
    """

    grow_at: float = 0.8
    shrink_at: float = 0.3
    #: total proposed tokens across the stage before any vote — the
    #: acceptance EWMAs mean nothing until real proposals were judged
    min_tokens: int = 16
    min_draft: int = 0
    max_draft: int = 4
    #: pair every draft grow/shrink with the opposite action on a
    #: decode-capable pool: trade capacity instead of changing fleet size
    trade: bool = True
    #: never drain a decode-capable pool below this many replicas
    min_target: int = 1

    def decide_many(self, snap: StageSnapshot) -> list[ScaleDecision]:
        slices = getattr(snap, "role_slices", {}) or {}
        draft = slices.get("draft")
        n_draft = draft.n_replicas if draft is not None else 0
        if n_draft == 0:
            return [hold(snap.stage, "no draft pool", "draft")]
        proposed = sum(getattr(r, "spec_proposed", 0)
                       for r in snap.replicas)
        if proposed < self.min_tokens:
            return [hold(snap.stage,
                         f"only {proposed} proposed tokens judged", "draft")]
        # the donor/recipient of a trade: prefer the dedicated decode
        # pool, fall back to colocated 'both' replicas
        donor = None
        for role in ("decode", "both"):
            s = slices.get(role)
            if s is not None and s.n_replicas > 0:
                donor = role
                n_target = s.n_replicas
                break
        acc = snap.acceptance_rate
        if acc >= self.grow_at and n_draft < self.max_draft:
            out = [ScaleDecision(
                snap.stage, 1,
                f"acceptance {acc:.2f} >= {self.grow_at:g}: "
                f"draft capacity pays", role="draft")]
            if self.trade and donor is not None \
                    and n_target > self.min_target:
                out.append(ScaleDecision(
                    snap.stage, -1,
                    f"traded to draft pool (acceptance {acc:.2f})",
                    role=donor))
            return out
        if acc <= self.shrink_at and n_draft > self.min_draft:
            out = [ScaleDecision(
                snap.stage, -1,
                f"acceptance {acc:.2f} <= {self.shrink_at:g}: "
                f"proposals mostly rejected", role="draft")]
            if self.trade and donor is not None:
                out.append(ScaleDecision(
                    snap.stage, 1,
                    f"traded back to target pool (acceptance {acc:.2f})",
                    role=donor))
            return out
        return [hold(snap.stage, f"acceptance {acc:.2f} in band", "draft")]

    def decide(self, snap: StageSnapshot) -> ScaleDecision:
        """Single-decision view (first non-hold vote) for callers that do
        not speak ``decide_many``."""
        for d in self.decide_many(snap):
            if not d.hold:
                return d
        return hold(snap.stage, role="draft")


@dataclasses.dataclass
class HysteresisPolicy:
    """Stability wrapper: act only after ``confirm`` consecutive same-sign
    votes from ``inner``, wait out ``cooldown_s`` after every action, and
    clamp each action to ±``max_step``."""

    inner: ScalingPolicy
    confirm: int = 2
    cooldown_s: float = 1.0
    max_step: int = 1
    clock: object = time.monotonic

    def __post_init__(self) -> None:
        self._streak_sign = 0
        self._streak = 0
        self._last_action_t: Optional[float] = None

    def decide(self, snap: StageSnapshot) -> ScaleDecision:
        want = self.inner.decide(snap)
        now = self.clock()
        if want.hold:
            self._streak_sign, self._streak = 0, 0
            return want
        sign = 1 if want.delta > 0 else -1
        if sign == self._streak_sign:
            self._streak += 1
        else:
            self._streak_sign, self._streak = sign, 1
        if self._last_action_t is not None \
                and now - self._last_action_t < self.cooldown_s:
            return hold(snap.stage, "cooldown")
        if self._streak < self.confirm:
            return hold(snap.stage,
                        f"awaiting confirmation {self._streak}/{self.confirm}")
        self._streak_sign, self._streak = 0, 0
        self._last_action_t = now
        delta = max(-self.max_step, min(self.max_step, want.delta))
        # replace() keeps whatever else the inner vote carried (its role
        # stamp in particular — clamping must not retarget the pool)
        return dataclasses.replace(want, delta=delta)

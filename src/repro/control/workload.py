"""Open-loop traffic generation for elastic-serving scenarios.

Closed-loop drivers (send, await, send) self-throttle when the system slows
down and therefore can't exercise autoscaling — backlog never builds. An
*open-loop* generator samples Poisson arrivals from a time-varying rate
profile and fires each request as its own task, exactly like independent
users: when the pipeline falls behind, queues grow and the controller must
react. Profiles cover the canonical elasticity shapes: constant, burst
(flash crowd), ramp, and diurnal (sinusoidal day/night).

Multi-tenant mixes (:class:`MultiTenantGenerator`): each tenant brings its
own rate profile, prompt-length distribution, and target model
(:class:`TenantProfile`); the generator superposes the per-tenant Poisson
streams on one absolute clock — the skewed 80/20 mix the fair-scheduler
and per-tenant-SLO scenarios need is just two profiles with a 4:1 rate
ratio. Every record carries its tenant tag, and ``summary()`` reports the
overall stats plus a per-tenant breakdown, so a bench can gate each
tenant's p95 against that tenant's own SLO.
"""
from __future__ import annotations

import asyncio
import dataclasses
import math
import random
import time
from typing import Awaitable, Callable, Optional


class RateProfile:
    """req/s as a function of elapsed seconds."""

    def rate(self, t: float) -> float:
        raise NotImplementedError


@dataclasses.dataclass
class ConstantProfile(RateProfile):
    rps: float

    def rate(self, t: float) -> float:
        return self.rps


@dataclasses.dataclass
class BurstProfile(RateProfile):
    """Flash crowd: ``base`` rps with a [t0, t1) window at ``burst`` rps."""

    base: float
    burst: float
    t0: float
    t1: float

    def rate(self, t: float) -> float:
        return self.burst if self.t0 <= t < self.t1 else self.base


@dataclasses.dataclass
class RampProfile(RateProfile):
    """Linear growth from ``start`` to ``end`` rps over ``duration``."""

    start: float
    end: float
    duration: float

    def rate(self, t: float) -> float:
        if t >= self.duration:
            return self.end
        return self.start + (self.end - self.start) * t / self.duration


@dataclasses.dataclass
class DiurnalProfile(RateProfile):
    """Sinusoidal day/night cycle: mean ± amplitude over ``period_s``."""

    mean: float
    amplitude: float
    period_s: float

    def rate(self, t: float) -> float:
        return max(0.0, self.mean + self.amplitude
                   * math.sin(2 * math.pi * t / self.period_s))


@dataclasses.dataclass
class RequestRecord:
    t_sent: float        # seconds since generator start
    latency_s: float     # -1.0 on failure
    ok: bool
    error: str = ""
    tenant: str = ""     # "" = untagged (single-tenant generator)


@dataclasses.dataclass
class TenantProfile:
    """One tenant's traffic contract for the multi-tenant generator: its
    arrival-rate profile, the prompt-length range its requests draw from
    (uniform, inclusive), and the registered model its traffic targets
    (None = the pipeline's default model). ``weight`` is carried through
    to the summary so artifacts record the fairness configuration the run
    measured under."""

    name: str
    profile: RateProfile
    prompt_len: tuple = (4, 12)
    model: Optional[str] = None
    weight: float = 1.0


class OpenLoopGenerator:
    """Fire-and-record Poisson arrivals against an async ``submit`` callable.

    ``submit`` is any coroutine function taking no arguments and returning
    when the request completes (e.g. ``lambda: server.submit(toks)``); the
    generator never waits for one request before sending the next.
    """

    def __init__(self, submit: Callable[[], Awaitable],
                 profile: RateProfile, *, seed: int = 0,
                 max_inflight: int = 256) -> None:
        self.submit = submit
        self.profile = profile
        #: kept on the instance so a bench artifact can record the exact
        #: arrival stream it measured (reproducibility)
        self.seed = seed
        self.rng = random.Random(seed)
        self.max_inflight = max_inflight
        self.records: list[RequestRecord] = []
        self.sent = 0
        self.ok = 0
        self.failed = 0
        self.shed = 0            # dropped by the generator's inflight cap
        self._inflight = 0

    async def _one(self, t_rel: float) -> None:
        # _inflight was incremented at spawn time (run()): counting here
        # would let a catch-up batch blow straight through max_inflight,
        # since none of the spawned tasks has run yet
        t0 = time.monotonic()
        try:
            await self.submit()
            self.ok += 1
            self.records.append(
                RequestRecord(t_rel, time.monotonic() - t0, True))
        except Exception as e:  # noqa: BLE001 — record, don't crash the run
            self.failed += 1
            self.records.append(
                RequestRecord(t_rel, -1.0, False, f"{type(e).__name__}: {e}"))
        finally:
            self._inflight -= 1

    async def run(self, duration_s: float) -> dict:
        """Drive traffic for ``duration_s``; returns summary stats.

        Arrival times are pre-sampled on an absolute clock and fired with
        catch-up: if the event loop is busy (exactly when elasticity is
        being exercised), every arrival that came due during the stall is
        dispatched immediately instead of being silently rate-limited —
        sleeping one inter-arrival gap at a time would make the generator
        closed-loop in disguise.
        """
        start = time.monotonic()
        tasks: list[asyncio.Task] = []
        t_next = self.rng.expovariate(max(self.profile.rate(0.0), 1e-3))
        while t_next < duration_s:
            now = time.monotonic() - start
            if now < t_next:
                await asyncio.sleep(t_next - now)
                now = time.monotonic() - start
            while t_next <= now and t_next < duration_s:
                if self._inflight >= self.max_inflight:
                    self.shed += 1
                else:
                    self.sent += 1
                    self._inflight += 1
                    tasks.append(asyncio.ensure_future(self._one(t_next)))
                t_next += self.rng.expovariate(
                    max(self.profile.rate(t_next), 1e-3))
        if tasks:
            await asyncio.gather(*tasks, return_exceptions=True)
        return self.summary()

    def summary(self) -> dict:
        lats = sorted(r.latency_s for r in self.records if r.ok)
        return {
            "sent": self.sent, "ok": self.ok, "failed": self.failed,
            "shed": self.shed, "seed": self.seed,
            "p50_s": percentile(lats, 50), "p95_s": percentile(lats, 95),
            "p99_s": percentile(lats, 99),
            "mean_s": (sum(lats) / len(lats)) if lats else float("nan"),
        }


class MultiTenantGenerator:
    """Superposed per-tenant Poisson streams against one async ``submit``.

    ``submit`` is a coroutine function ``submit(tenant, prompt_len)``
    receiving the firing :class:`TenantProfile` and a prompt length drawn
    from its range; it returns when the request completes. Each tenant's
    arrival stream is sampled from its own seeded RNG (reproducible per
    tenant, independent of the others), and the streams are merged on one
    absolute clock with the same catch-up discipline as
    :class:`OpenLoopGenerator` — a stalled event loop dispatches every
    due arrival immediately instead of silently rate-limiting.
    """

    def __init__(self, submit: Callable[..., Awaitable],
                 tenants: list, *, seed: int = 0,
                 max_inflight: int = 256) -> None:
        self.submit = submit
        self.tenants = list(tenants)
        self.seed = seed
        #: per-tenant RNGs: tenant i's arrivals/prompt draws are a pure
        #: function of (seed, i), unchanged by reordering other tenants
        self._rngs = [random.Random(f"{seed}:{t.name}")
                      for t in self.tenants]
        self.max_inflight = max_inflight
        self.records: list[RequestRecord] = []
        self.sent = 0
        self.ok = 0
        self.failed = 0
        self.shed = 0
        self._inflight = 0

    async def _one(self, t_rel: float, tenant: TenantProfile,
                   prompt_len: int) -> None:
        t0 = time.monotonic()
        try:
            await self.submit(tenant, prompt_len)
            self.ok += 1
            self.records.append(RequestRecord(
                t_rel, time.monotonic() - t0, True, tenant=tenant.name))
        except Exception as e:  # noqa: BLE001 — record, don't crash the run
            self.failed += 1
            self.records.append(RequestRecord(
                t_rel, -1.0, False, f"{type(e).__name__}: {e}",
                tenant=tenant.name))
        finally:
            self._inflight -= 1

    async def run(self, duration_s: float) -> dict:
        start = time.monotonic()
        tasks: list[asyncio.Task] = []
        t_next = [rng.expovariate(max(t.profile.rate(0.0), 1e-3))
                  for t, rng in zip(self.tenants, self._rngs)]
        while True:
            due = [tn for tn in t_next if tn < duration_s]
            if not due:
                break
            t_min = min(due)
            now = time.monotonic() - start
            if now < t_min:
                await asyncio.sleep(t_min - now)
                now = time.monotonic() - start
            # catch-up: fire every tenant's arrivals that came due during
            # the sleep (or an event-loop stall), earliest first
            for i, tenant in enumerate(self.tenants):
                rng = self._rngs[i]
                while t_next[i] <= now and t_next[i] < duration_s:
                    if self._inflight >= self.max_inflight:
                        self.shed += 1
                    else:
                        self.sent += 1
                        self._inflight += 1
                        lo, hi = tenant.prompt_len
                        tasks.append(asyncio.ensure_future(self._one(
                            t_next[i], tenant, rng.randint(lo, hi))))
                    t_next[i] += rng.expovariate(
                        max(tenant.profile.rate(t_next[i]), 1e-3))
        if tasks:
            await asyncio.gather(*tasks, return_exceptions=True)
        return self.summary()

    def summary(self) -> dict:
        """Overall stats plus a per-tenant breakdown keyed by tenant name
        — each tenant's latency percentiles come from its own records, so
        a heavy tenant's tail can't hide a light tenant's starvation."""
        lats = sorted(r.latency_s for r in self.records if r.ok)
        out = {
            "sent": self.sent, "ok": self.ok, "failed": self.failed,
            "shed": self.shed, "seed": self.seed,
            "p50_s": percentile(lats, 50), "p95_s": percentile(lats, 95),
            "p99_s": percentile(lats, 99),
            "mean_s": (sum(lats) / len(lats)) if lats else float("nan"),
            "tenants": {},
        }
        for tenant in self.tenants:
            recs = [r for r in self.records if r.tenant == tenant.name]
            tl = sorted(r.latency_s for r in recs if r.ok)
            out["tenants"][tenant.name] = {
                "sent": len(recs),
                "ok": sum(1 for r in recs if r.ok),
                "failed": sum(1 for r in recs if not r.ok),
                "weight": tenant.weight,
                "model": tenant.model,
                "p50_s": percentile(tl, 50), "p95_s": percentile(tl, 95),
                "mean_s": (sum(tl) / len(tl)) if tl else float("nan"),
            }
        return out


def percentile(sorted_xs: list, p: float) -> float:
    """Linear-interpolated percentile over a pre-sorted list.

    Total over the edge cases a live summary hits: NaN on empty (a run
    where nothing succeeded must not raise mid-report), the sole element
    on a singleton, exact endpoints at p=0/p=100, and interpolation in
    between — never an out-of-range index for any (len, p) pair.
    """
    n = len(sorted_xs)
    if n == 0:
        return float("nan")
    if n == 1:
        return float(sorted_xs[0])
    p = min(max(p, 0.0), 100.0)
    rank = p / 100.0 * (n - 1)
    lo = int(rank)
    hi = min(lo + 1, n - 1)
    frac = rank - lo
    return float(sorted_xs[lo] + (sorted_xs[hi] - sorted_xs[lo]) * frac)

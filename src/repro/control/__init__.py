"""Elastic control plane: the closed loop over MultiWorld's mechanisms.

core/ gives worker-granular fault domains (worlds), out-of-band failure
detection (watchdog) and online instantiation; serving/ gives a replicated
stage pipeline with drain-and-remove. This package closes the loop the
paper leaves as future work: observe (MetricsHub) -> decide (policies) ->
act (ElasticController: scale up / drain down / heal), plus an open-loop
workload generator to drive elastic scenarios.
"""
from .controller import ControlEvent, ElasticController
from .metrics import Ewma, MetricsHub, ReplicaSample, StageSnapshot
from .policy import (
    DisaggregatedStagePolicy,
    HysteresisPolicy,
    LatencySLOPolicy,
    PerTenantSLOPolicy,
    ScaleDecision,
    ScalingPolicy,
    SpecDecodePolicy,
    TailLatencySLOPolicy,
    TargetQueueDepthPolicy,
    TenantSpec,
    TokenRatePolicy,
    TTFTSLOPolicy,
)
from .workload import (
    BurstProfile,
    ConstantProfile,
    DiurnalProfile,
    MultiTenantGenerator,
    OpenLoopGenerator,
    RampProfile,
    RateProfile,
    RequestRecord,
    TenantProfile,
    percentile,
)

__all__ = [
    "ControlEvent", "ElasticController",
    "Ewma", "MetricsHub", "ReplicaSample", "StageSnapshot",
    "DisaggregatedStagePolicy", "HysteresisPolicy", "LatencySLOPolicy",
    "PerTenantSLOPolicy", "ScaleDecision", "ScalingPolicy",
    "SpecDecodePolicy",
    "TailLatencySLOPolicy", "TargetQueueDepthPolicy", "TenantSpec",
    "TokenRatePolicy", "TTFTSLOPolicy",
    "BurstProfile", "ConstantProfile", "DiurnalProfile",
    "MultiTenantGenerator", "OpenLoopGenerator", "RampProfile",
    "RateProfile", "RequestRecord", "TenantProfile", "percentile",
]

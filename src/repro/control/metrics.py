"""MetricsHub: the observation half of the elastic control loop.

The paper contributes the *mechanisms* (worlds, watchdog, online
instantiation) and leaves the controller as future work (§3.1). A controller
needs eyes before hands: this module turns the pipeline's raw per-replica
counters (queue depth, processed count, wait/service sums — see
``_Replica`` in serving/pipeline.py) and the WorldManager structured event
stream into smoothed per-stage signals a scaling policy can act on.

Design notes:

* EWMAs, not windows — O(1) state per signal, and the smoothing constant is
  the single knob that trades reactivity against flapping (the policy layer
  adds hysteresis on top).
* Break events arrive via ``WorldManager.on_event`` subscription, not by
  re-scanning ``manager.events`` each poll; managers appear dynamically as
  the controller scales, so the hub re-sweeps the cluster for unseen
  managers on every poll (idempotent).
* The hub never *acts* — it is a pure observer, so it can also back
  dashboards/benchmark timelines without dragging in controller state.
* Aggregation is *hierarchical and merge-closed* (fleet scale): per-stage
  rollups are :class:`~repro.obs.digest.StageDigest`s built by
  ``fold_samples`` — replica samples fold into shard digests fold into the
  stage digest, and stage digests merge into one fleet digest. Every
  aggregate a policy reads (sums, means-as-(sum,n), sketch percentiles)
  merges associatively, so a sharded fold over 40k replicas answers the
  same questions as the flat fold, in bounded space. The tail signals
  (``p95_ttft_s``, ``p99_decode_s``) come from the digests' mergeable
  LogSketches, never from averaging per-replica percentiles.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.obs.digest import StageDigest, fold_samples, merge_digests
from repro.obs.slo import SLOMonitor


class Ewma:
    """Exponentially weighted moving average; seeded by the first sample."""

    def __init__(self, alpha: float = 0.3) -> None:
        self.alpha = alpha
        self.value: Optional[float] = None

    def update(self, x: float) -> float:
        if self.value is None:
            self.value = float(x)
        else:
            self.value += self.alpha * (float(x) - self.value)
        return self.value

    def get(self, default: float = 0.0) -> float:
        return default if self.value is None else self.value


@dataclasses.dataclass
class ReplicaSample:
    worker_id: str
    stage: int
    alive: bool
    draining: bool
    queue_depth: int
    inflight: int
    processed: int
    throughput: float       # completed req/s, EWMA
    latency_s: float        # wait + service per request, EWMA
    tokens_per_s: float = 0.0   # decode tokens/s, EWMA (generative plane)
    open_sessions: int = 0      # sessions whose KV cache lives here
    expired: int = 0            # deadline-expired envelopes dropped here
    role: str = "both"          # pool membership (prefill/decode/both)
    ttft_s: float = 0.0         # per-prefill service time (incl. handoff),
    #                             EWMA — the stage's TTFT contribution
    decode_lat_s: float = 0.0   # per fused decode dispatch (~per token), EWMA
    #: mergeable per-replica latency distributions (LogSketch), populated
    #: when the replica keeps sketches; fold into the stage digest so the
    #: stage/fleet p95/p99 are computed from merged buckets, not means
    ttft_sketch: object = None
    decode_sketch: object = None
    #: models resident on the replica (multi-model pools); () = default only
    models: tuple = ()
    #: decode batch slots served per tenant by the WDRR fair scheduler
    tenant_served: dict = dataclasses.field(default_factory=dict)
    #: speculative decoding (decode-pool replicas judge acceptance, so the
    #: counters live there): cumulative proposed/accepted draft tokens and
    #: the per-replica acceptance-rate EWMA the SpecDecodePolicy trades
    #: draft-vs-target capacity on
    spec_proposed: int = 0
    spec_accepted: int = 0
    acceptance: float = 0.0


@dataclasses.dataclass
class StageSnapshot:
    """What a scaling policy sees for one pipeline stage.

    ``role_slices`` re-aggregates the same replica samples per pool
    (``prefill`` / ``decode`` / ``both``), so a disaggregated policy can
    scale each pool on its own signal — decode on tokens/s + open sessions,
    prefill on queue depth / TTFT. Slices are instantaneous re-aggregations
    of the per-replica EWMAs (the stage-level ``queue_per_replica`` EWMA is
    not re-smoothed per slice).
    """

    stage: int
    t: float
    n_replicas: int                 # healthy (alive, not draining)
    n_failed: int                   # watchdog-fenced heal candidates
    queue_total: int
    queue_per_replica: float
    throughput: float               # stage-total completed req/s, EWMA
    latency_s: float                # mean request sojourn in stage, EWMA
    replicas: list[ReplicaSample] = dataclasses.field(default_factory=list)
    tokens_per_s: float = 0.0       # stage-total decode tokens/s, EWMA
    open_sessions: int = 0          # live sessions across healthy replicas
    expired: int = 0                # deadline drops summed over replicas
    #                                 currently in the stage (retired
    #                                 replicas' counts live in the hub's
    #                                 deadline_expired_total accumulator)
    ttft_s: float = 0.0             # mean per-prefill service EWMA (healthy)
    decode_latency_s: float = 0.0   # mean per-dispatch decode EWMA (healthy)
    role: str = "all"               # "all" for the stage view, else the pool
    role_slices: dict = dataclasses.field(default_factory=dict)
    # tail percentiles from the stage digest's merged latency sketches —
    # 0.0 when the replicas keep no sketches (EWMA-only deployments)
    p95_ttft_s: float = 0.0
    p99_ttft_s: float = 0.0
    p95_decode_s: float = 0.0
    p99_decode_s: float = 0.0
    #: multi-model pool view: model -> healthy replicas hosting it at this
    #: stage, and model -> open sessions running it here — the signals a
    #: swap policy weighs ("B is starved, A has idle residency")
    model_replicas: dict = dataclasses.field(default_factory=dict)
    model_sessions: dict = dataclasses.field(default_factory=dict)
    #: client-observed per-tenant latency tails (pipeline-wide, attached to
    #: every stage snapshot): tenant -> {p50/p95_ttft_s, p95_decode_s, n}
    tenant_tails: dict = dataclasses.field(default_factory=dict)
    #: speculative decoding: mean of the per-replica acceptance EWMAs over
    #: replicas that have judged draft proposals (0.0 = no spec traffic)
    acceptance_rate: float = 0.0
    #: the StageDigest this snapshot was derived from (None for snapshots
    #: constructed directly, e.g. in tests)
    digest: Optional[StageDigest] = None


class MetricsHub:
    def __init__(self, server, *, alpha: float = 0.3,
                 digest_shard: int = 64,
                 slo: Optional[SLOMonitor] = None) -> None:
        self.server = server
        self.alpha = alpha
        #: shard width for the hierarchical fold: stages with more replicas
        #: than this aggregate via shard digests that merge upward (the
        #: fleet-scale path); smaller stages fold flat — both are the same
        #: merge-closed arithmetic, so the choice never changes a decision
        self.digest_shard = digest_shard
        #: per-pipeline SLO burn-rate monitor; observed from the client
        #: latency logs each poll, evaluated by the controller each tick
        self.slo = slo
        #: stage digests from the most recent poll (stage order)
        self.stage_digests: list[StageDigest] = []
        #: (t, kind, world) world-lifecycle events from every manager
        self.world_events: list[tuple[float, str, str]] = []
        self.breaks_seen = 0
        self._prev: dict[str, tuple] = {}
        self._tput: dict[str, Ewma] = {}
        self._lat: dict[str, Ewma] = {}
        self._toks: dict[str, Ewma] = {}
        self._ttft: dict[str, Ewma] = {}
        self._declat: dict[str, Ewma] = {}
        self._accept: dict[str, Ewma] = {}
        self._qdepth: dict[int, Ewma] = {}
        self._snap_bytes = Ewma(alpha)
        #: client-observed latency split, fed from the server's per-kind
        #: logs: prefill round-trip (true TTFT) vs per-token decode
        self._client_ttft = Ewma(alpha)
        self._client_declat = Ewma(alpha)
        self._subscribed: set[str] = set()
        self._subscribe_new_managers()

    # ----------------------------------------------------------- subscription
    def _subscribe_new_managers(self) -> None:
        for worker in list(self.server.cluster.workers.values()):
            mgr = worker.manager
            if mgr.worker_id in self._subscribed:
                continue
            self._subscribed.add(mgr.worker_id)
            mgr.on_event(self._on_world_event)
            # replay history so late subscription misses nothing
            for t, kind, world in mgr.events:
                self._on_world_event(t, kind, world, replay=True)

    #: soft cap on the retained event timeline (a days-long elastic run
    #: would otherwise grow it without bound); oldest half is dropped
    MAX_EVENTS = 100_000

    def _on_world_event(self, t: float, kind: str, world: str,
                        replay: bool = False) -> None:
        self.world_events.append((t, kind, world))
        if len(self.world_events) > self.MAX_EVENTS:
            del self.world_events[:self.MAX_EVENTS // 2]
        if kind == "broken":
            self.breaks_seen += 1

    # ----------------------------------------------------------------- polling
    def _replica_sample(self, rep, now: float) -> ReplicaSample:
        wid = rep.worker_id
        prev = self._prev.get(wid)
        processed = rep.processed
        lat_sum = rep.wait_s_sum + rep.service_s_sum
        tokens = rep.tokens_out
        prefills = rep.prefills
        prefill_s = rep.prefill_s_sum
        dbatches = rep.decode_batches
        decode_s = rep.decode_s_sum
        sp_prop = getattr(rep, "spec_proposed", 0)
        sp_acc = getattr(rep, "spec_accepted", 0)
        tput = self._tput.setdefault(wid, Ewma(self.alpha))
        lat = self._lat.setdefault(wid, Ewma(self.alpha))
        toks = self._toks.setdefault(wid, Ewma(self.alpha))
        ttft = self._ttft.setdefault(wid, Ewma(self.alpha))
        declat = self._declat.setdefault(wid, Ewma(self.alpha))
        accept = self._accept.setdefault(wid, Ewma(self.alpha))
        if prev is not None:
            t0, done0, lat0, tok0, pre0, pres0, db0, ds0, sp0, sa0 = prev
            dt = max(now - t0, 1e-9)
            dn = processed - done0
            tput.update(dn / dt)
            toks.update((tokens - tok0) / dt)
            if dn > 0:
                lat.update((lat_sum - lat0) / dn)
            # per-kind latency split: prefill service time (TTFT slice at
            # this stage, handoff included) vs per-fused-dispatch decode
            if prefills > pre0:
                ttft.update((prefill_s - pres0) / (prefills - pre0))
            if dbatches > db0:
                declat.update((decode_s - ds0) / (dbatches - db0))
            # acceptance EWMA over the poll window's verified proposals —
            # the freshness the SpecDecodePolicy trades capacity on
            if sp_prop > sp0:
                accept.update((sp_acc - sa0) / (sp_prop - sp0))
        self._prev[wid] = (now, processed, lat_sum, tokens,
                           prefills, prefill_s, dbatches, decode_s,
                           sp_prop, sp_acc)
        open_sessions = rep.open_sessions()
        return ReplicaSample(
            worker_id=wid, stage=rep.stage, alive=rep.worker.alive,
            draining=rep.draining, queue_depth=rep.queue_depth(),
            inflight=rep.inflight, processed=processed,
            throughput=tput.get(), latency_s=lat.get(),
            tokens_per_s=toks.get(), open_sessions=open_sessions,
            expired=rep.expired, role=getattr(rep, "role", "both"),
            ttft_s=ttft.get(), decode_lat_s=declat.get(),
            ttft_sketch=getattr(rep, "ttft_sketch", None),
            decode_sketch=getattr(rep, "decode_sketch", None),
            models=tuple(sorted(getattr(rep, "resident", ()) or ())),
            tenant_served=dict(getattr(rep, "tenant_served", {}) or {}),
            spec_proposed=sp_prop, spec_accepted=sp_acc,
            acceptance=accept.get())

    def _prune_retired(self) -> None:
        """Worker ids are never reused, so per-replica state for retired
        replicas is garbage — drop it or a long-lived elastic cluster leaks
        one entry set per scale/heal cycle."""
        live = {r.worker_id for reps in self.server.replicas for r in reps}
        for d in (self._prev, self._tput, self._lat, self._toks,
                  self._ttft, self._declat, self._accept):
            for wid in [w for w in d if w not in live]:
                del d[wid]
        # retired workers leave the cluster registry too (teardown reclaims
        # them) — keep the subscription set in step
        self._subscribed &= set(self.server.cluster.workers)

    def poll(self) -> list[StageSnapshot]:
        """One observation pass: returns a snapshot per pipeline stage.
        Aggregation runs replicas -> (shard digests ->) stage digest; the
        per-poll stage digests are kept on ``stage_digests`` and merge
        into the cross-stage rollup via :meth:`fleet_digest`."""
        self._subscribe_new_managers()
        self._prune_retired()
        now = time.monotonic()
        snaps: list[StageSnapshot] = []
        self.stage_digests = []
        tails = self.tenant_tails()
        default = getattr(self.server, "default_model", "default")
        for stage, reps in enumerate(self.server.replicas):
            samples = [self._replica_sample(r, now) for r in reps]
            failed = set(self.server.failed_replicas(stage))
            snap = self._aggregate(stage, now, samples, failed)
            self.stage_digests.append(snap.digest)
            for role in sorted({s.role for s in samples}):
                snap.role_slices[role] = self._aggregate(
                    stage, now, [s for s in samples if s.role == role],
                    failed, role=role)
            # multi-model dimensions: where each model is resident and how
            # many open sessions run it at this stage (the swap policy's
            # supply-vs-demand view); single-model pools see {default: ...}
            for r in reps:
                if r.worker.alive and not r.draining:
                    for m in getattr(r, "resident", ()) or ():
                        snap.model_replicas[m] = (
                            snap.model_replicas.get(m, 0) + 1)
                for sess in getattr(r, "sessions", {}).values():
                    m = getattr(sess, "model", None) or default
                    snap.model_sessions[m] = (
                        snap.model_sessions.get(m, 0) + 1)
            snap.tenant_tails = tails
            snaps.append(snap)
        self._update_migration_ewmas()
        return snaps

    def tenant_tails(self) -> dict:
        """Client-observed per-tenant latency tails from the server's
        tenant sketches: ``tenant -> {p50_ttft_s, p95_ttft_s, p95_decode_s,
        n}``. Empty for untagged (single-tenant) pipelines — the per-tenant
        SLO policy treats a missing tenant as 'no signal yet'."""
        out: dict[str, dict] = {}
        for tenant, sk in getattr(self.server, "tenant_sketches",
                                  {}).items():
            ttft, dec = sk.get("ttft"), sk.get("decode")
            out[tenant] = {
                "p50_ttft_s": ttft.quantile(0.5) if ttft is not None else 0.0,
                "p95_ttft_s": ttft.quantile(0.95) if ttft is not None else 0.0,
                "p95_decode_s": dec.quantile(0.95) if dec is not None else 0.0,
                "n": float(getattr(ttft, "count", 0) or 0),
            }
        return out

    def fleet_digest(self) -> StageDigest:
        """Cross-stage rollup of the latest poll (stage == -1): the whole
        fleet's load and latency distributions in one bounded digest.
        Merges into a fresh digest so the per-stage rollups stay intact."""
        return merge_digests(
            [StageDigest().merge(d) for d in self.stage_digests if d])

    def _aggregate(self, stage: int, now: float,
                   samples: list[ReplicaSample], failed: set,
                   role: str = "all") -> StageSnapshot:
        """Fold replica samples into one StageSnapshot, via the mergeable
        StageDigest (sharded hierarchically when the replica set exceeds
        ``digest_shard``). The whole-stage view (role="all") owns the
        smoothed queue_per_replica EWMA; role slices re-aggregate
        instantaneously over the pool's samples."""
        digest = fold_samples(
            samples, failed, stage=stage, t=now, role=role,
            shard=self.digest_shard)
        n = digest.n_replicas
        if role == "all":
            qd = self._qdepth.setdefault(stage, Ewma(self.alpha))
            qd.update(digest.queue_total / max(n, 1))
            queue_per = qd.get()
        else:
            queue_per = digest.queue_per_replica
        accs = [s.acceptance for s in samples
                if getattr(s, "spec_proposed", 0) > 0]
        return StageSnapshot(
            stage=stage, t=now, n_replicas=n,
            n_failed=digest.n_failed,
            queue_total=digest.queue_total,
            queue_per_replica=queue_per,
            throughput=digest.throughput,
            latency_s=digest.latency_s,
            replicas=samples,
            tokens_per_s=digest.tokens_per_s,
            open_sessions=digest.open_sessions,
            expired=digest.expired,
            ttft_s=digest.ttft_s,
            decode_latency_s=digest.decode_latency_s,
            role=role,
            p95_ttft_s=digest.p95_ttft_s,
            p99_ttft_s=digest.p99_ttft_s,
            p95_decode_s=digest.p95_decode_s,
            p99_decode_s=digest.p99_decode_s,
            acceptance_rate=sum(accs) / len(accs) if accs else 0.0,
            digest=digest)

    # ------------------------------------------------------- state transfer
    def _update_migration_ewmas(self) -> None:
        snaps = getattr(self.server, "snapshots", None)
        if snaps is not None:
            # consume sizes logged since the last poll; the EWMA smooths
            # over sessions of different history lengths
            for nbytes in snaps.bytes_log:
                self._snap_bytes.update(float(nbytes))
            snaps.bytes_log.clear()
        # client-observed per-kind latency: the server logs one sample per
        # prefill round-trip (TTFT) and per decode step; drain into EWMAs
        # and fan each sample into the SLO burn-rate monitor (good/bad
        # bucketing wants per-request samples, not the smoothed mean)
        now = time.monotonic()
        for log, ewma, metric in (
                (getattr(self.server, "ttft_log", None),
                 self._client_ttft, "ttft"),
                (getattr(self.server, "decode_lat_log", None),
                 self._client_declat, "decode")):
            if log:
                for dt in log:
                    ewma.update(dt)
                    if self.slo is not None:
                        self.slo.observe(metric, dt, now)
                log.clear()

    def latency_metrics(self) -> dict:
        """Client-observed per-kind latency split: TTFT (PREFILL round-trip,
        handoff included) vs per-token decode — the signals the per-role
        scaling policies consume, here as the end-to-end client view."""
        return {
            "ttft_s": self._client_ttft.get(),
            "decode_latency_s": self._client_declat.get(),
        }

    def migration_metrics(self) -> dict:
        """State-transfer counters for dashboards/benchmarks: how often
        state moved instead of being recomputed, how long a handoff takes,
        how big snapshots run, and the recovered-vs-recomputed token split.
        """
        mig = getattr(self.server, "migrations", None)
        out = {
            "migrations_total": 0, "migration_p50_s": 0.0,
            "snapshot_bytes_ewma": self._snap_bytes.get(),
            "recovered_tokens": 0, "recomputed_tokens": 0,
            "restores_total": 0, "reprefills_total": 0,
            # exact across scale-down: teardown folds each retiring
            # replica's count into the server-side accumulator
            "deadline_expired_total": (
                getattr(self.server, "expired_retired", 0)
                + sum(r.expired
                      for reps in self.server.replicas for r in reps)),
        }
        if mig is not None:
            out.update({
                "migrations_total": mig.migrations_total,
                "migration_p50_s": mig.migration_p50_s(),
                "recovered_tokens": mig.recovered_tokens,
                "recomputed_tokens": mig.recomputed_tokens,
                "restores_total": mig.restores_total,
                "reprefills_total": mig.reprefills_total,
                "heal_migrations_total": mig.heal_migrations_total,
                # steady-state prefill -> decode-pool KV handoffs
                "handoffs_total": mig.handoffs_total,
                "handoff_failures": mig.handoff_failures,
                "handoff_p50_s": mig.handoff_p50_s(),
                "handoff_bytes_total": sum(mig.handoff_bytes),
            })
        snaps_store = getattr(self.server, "snapshots", None)
        if snaps_store is not None:
            # delta snapshots: how much of the background-snapshot stream
            # rode the (base, delta) path and what it cost in bytes
            out["delta_snapshots_total"] = snaps_store.delta_snapshots_taken
            out["snapshot_delta_bytes_total"] = snaps_store.delta_bytes_total
            out["snapshot_bytes_total"] = snaps_store.snapshot_bytes_total
        # thin-margin int8 -> fp demotions, wherever the quantized codec
        # runs (background snapshots and live handoffs)
        snaps = getattr(self.server, "snapshots", None)
        out["int8_fp_fallbacks"] = (
            (getattr(snaps, "int8_fallbacks", 0) if snaps else 0)
            + (getattr(mig, "int8_fallbacks", 0) if mig else 0))
        return out

    def spec_metrics(self) -> dict:
        """Speculative-decoding counters: draft tokens proposed vs accepted
        by the target pool (client-committed, so exact), graceful-degrade
        fallbacks to plain decode, and dispatch counts on both sides of the
        propose/verify split. Empty when the pipeline never ran a spec
        round and has no draft pool, so non-speculative deployments export
        nothing extra."""
        rounds = getattr(self.server, "spec_rounds_total", 0)
        proposed = getattr(self.server, "spec_proposed_total", 0)
        accepted = getattr(self.server, "spec_accepted_total", 0)
        fallbacks = getattr(self.server, "spec_fallbacks_total", 0)
        verifies = proposals = 0
        for reps in self.server.replicas:
            for r in reps:
                verifies += getattr(r, "spec_verifies", 0)
                proposals += getattr(r, "spec_proposals", 0)
        if not (rounds or fallbacks or proposals or verifies):
            return {}
        return {
            "proposed_tokens_total": proposed,
            "accepted_tokens_total": accepted,
            "spec_rounds_total": rounds,
            "spec_fallbacks_total": fallbacks,
            "verify_dispatches_total": verifies,
            "propose_dispatches_total": proposals,
            "acceptance_rate": accepted / proposed if proposed else 0.0,
        }

    # ---------------------------------------------------------- obs surface
    def trace_summary(self) -> dict:
        """Per-span-kind latency summary from the server's tracer:
        ``{kind: {count, mean_s, p50_s, p95_s, max_s}}`` over the retained
        span ring. This is the supported read path for TTFT / per-token
        decode / handoff / migration / heal / restore latencies — callers
        must not reach into the server's raw latency logs (the hub drains
        and clears those on every poll)."""
        tracer = getattr(self.server, "tracer", None)
        return tracer.summary() if tracer is not None else {}

    def export_prometheus(self, snaps=None) -> str:
        """Render the hub's whole view in Prometheus text exposition
        format. ``snaps`` reuses an existing ``poll()`` result; omitted,
        the hub polls once itself (polling is idempotent observation)."""
        from repro.obs.export import render_prometheus

        if snaps is None:
            snaps = self.poll()
        per_stage: dict[str, dict] = {
            "replicas": {}, "failed": {}, "queue_total": {},
            "throughput": {}, "tokens_per_s": {}, "open_sessions": {},
        }
        for s in snaps:
            sid = str(s.stage)
            per_stage["replicas"][sid] = s.n_replicas
            per_stage["failed"][sid] = s.n_failed
            per_stage["queue_total"][sid] = s.queue_total
            per_stage["throughput"][sid] = s.throughput
            per_stage["tokens_per_s"][sid] = s.tokens_per_s
            per_stage["open_sessions"][sid] = s.open_sessions
        groups: dict[str, dict] = {
            "stage": per_stage,
            "latency": self.latency_metrics(),
            "migration": self.migration_metrics(),
            "placement": self.placement_metrics(),
        }
        # multi-tenant / multi-model label dimensions — omitted entirely
        # for untagged single-model pipelines (no empty metric families)
        tenant = self.tenant_metrics()
        if tenant:
            groups["tenant"] = tenant
        model = self.model_metrics()
        if model:
            groups["model"] = model
        # speculative decoding — only exported once a spec round (or a
        # draft dispatch) actually happened
        spec = self.spec_metrics()
        if spec:
            groups["spec"] = spec
        # executor dispatch/compile counters, summed over the distinct
        # executors behind the fleet (replicas may share one per stage)
        execs = {id(r.executor): r.executor
                 for reps in self.server.replicas for r in reps
                 if getattr(r, "executor", None) is not None}
        exec_totals: dict[str, float] = {}
        for ex in execs.values():
            for k, v in ex.obs_stats().items():
                exec_totals[k] = exec_totals.get(k, 0) + v
        if exec_totals:
            groups["executor"] = exec_totals
        kvpool = self.kvpool_metrics(execs.values())
        if kvpool:
            groups["kvpool"] = kvpool
        span_flat: dict[str, float] = {}
        for kind, stats in self.trace_summary().items():
            for stat, v in stats.items():
                span_flat[f"{kind}_{stat}"] = v
        if span_flat:
            groups["span"] = span_flat
        # fleet digest rollup: the bounded cross-stage view, including the
        # sketch-backed tail percentiles policies decide on
        if self.stage_digests:
            fleet = self.fleet_digest()
            groups["digest"] = {
                k: v for k, v in fleet.summary().items()
                if k not in ("stage", "role")}
        # SLO burn rates + firing state, when a monitor is attached
        if self.slo is not None:
            groups["slo"] = self.slo.metrics(time.monotonic())
        obs: dict[str, float] = {"world_breaks": self.breaks_seen}
        tracer = getattr(self.server, "tracer", None)
        if tracer is not None:
            obs["spans_recorded"] = tracer.recorded
            obs["spans_dropped"] = tracer.dropped
            obs["traces_sampled_out"] = getattr(tracer, "sampled_out", 0)
            obs["traces_tail_kept"] = getattr(tracer, "tail_kept", 0)
        rec = getattr(self.server, "recorder", None)
        if rec is not None:
            obs["flight_events"] = len(rec)
            obs["flight_dumps"] = rec.dumps_total
        groups["obs"] = obs
        return render_prometheus(groups)

    def tenant_metrics(self) -> dict:
        """Per-tenant label dimension for the exporter: client-observed
        latency tails, token/session totals, and WDRR decode slots served
        (summed over replicas). Empty when no traffic ever carried a tenant
        tag, so single-tenant deployments export nothing extra."""
        tails = self.tenant_tails()
        out: dict[str, dict] = {}
        if tails:
            out["p95_ttft_s"] = {t: v["p95_ttft_s"] for t, v in tails.items()}
            out["p95_decode_s"] = {t: v["p95_decode_s"]
                                   for t, v in tails.items()}
        tokens = dict(getattr(self.server, "tenant_tokens", {}) or {})
        if tokens:
            out["tokens_total"] = tokens
        sessions = dict(getattr(self.server, "tenant_sessions", {}) or {})
        if sessions:
            out["sessions_total"] = sessions
        served: dict[str, int] = {}
        for reps in self.server.replicas:
            for r in reps:
                for t, n in (getattr(r, "tenant_served", {}) or {}).items():
                    served[t] = served.get(t, 0) + n
        if served:
            out["slots_served"] = served
        return out

    def model_metrics(self) -> dict:
        """Per-model label dimension: residency spread (replicas hosting
        each model), open sessions per model, and the registry/protocol
        lifetime counters. Empty when only the default model is registered
        and no residency protocol traffic ever ran."""
        registry = getattr(self.server, "registry", None)
        if registry is None:
            return {}
        boot = getattr(self.server, "bootstrap", None)
        counters = registry.stats()
        if (len(registry.entries) <= 1
                and not getattr(boot, "model_loads_total", 0)
                and not getattr(self.server, "swaps_total", 0)):
            return {}
        default = getattr(self.server, "default_model", "default")
        sessions: dict[str, int] = {}
        for reps in self.server.replicas:
            for r in reps:
                for sess in getattr(r, "sessions", {}).values():
                    m = getattr(sess, "model", None) or default
                    sessions[m] = sessions.get(m, 0) + 1
        out = {
            "replicas": registry.resident_counts(),
            "swaps_total": getattr(self.server, "swaps_total", 0),
            **counters,
        }
        if sessions:
            out["sessions"] = sessions
        if boot is not None:
            out["wire_loads_total"] = boot.model_loads_total
            out["wire_loads_cold"] = boot.model_loads_cold
            out["wire_swaps_total"] = boot.model_swaps_total
            out["wire_load_bytes_total"] = sum(boot.load_bytes)
        return out

    def kvpool_metrics(self, executors=None) -> dict:
        """Paged KV pool pressure/sharing view, summed over the distinct
        pools behind the fleet (one per paged executor). Empty when no
        executor runs paged — the exporter then omits the group entirely.
        Ratios are derived here so dashboards never join raw counters:
        ``occupancy`` (used/total) is the admission-pressure signal,
        ``shared_page_ratio`` (shared/used) is how much of the resident
        cache the prefix trie is deduplicating."""
        if executors is None:
            executors = {id(r.executor): r.executor
                         for reps in self.server.replicas for r in reps
                         if getattr(r, "executor", None) is not None}.values()
        totals: dict[str, float] = {}
        for ex in executors:
            stats = getattr(ex, "pool_stats", None)
            for k, v in (stats() if callable(stats) else {}).items():
                totals[k] = totals.get(k, 0) + v
        if not totals:
            return {}
        total = totals.get("kv_pages_total", 0)
        used = totals.get("kv_pages_used", 0)
        totals["occupancy"] = used / total if total else 0.0
        totals["shared_page_ratio"] = (
            totals.get("kv_pages_shared", 0) / used if used else 0.0)
        return totals

    def placement_metrics(self) -> dict:
        """Topology-cost view of the data plane: how many bytes crossed a
        host boundary, and the cost-weighted total (bytes x per-edge cost).
        The ``bulk_*`` slice isolates state transfer (migrations, snapshots,
        weight streaming) — the traffic the placement-aware choices in
        MigrationManager/WarmBootstrap/restore exist to keep on-host."""
        t = self.server.cluster.transport
        return {
            "bytes_sent": t.bytes_sent,
            "cross_host_bytes": t.cross_host_bytes_sent,
            "cross_host_messages": t.cross_host_messages_sent,
            "cost_weighted_bytes": t.cost_weighted_bytes,
            "bulk_bytes": t.bulk_bytes_sent,
            "bulk_cross_host_bytes": t.bulk_cross_host_bytes_sent,
            "bulk_cost_weighted_bytes": t.bulk_cost_weighted_bytes,
            "messages_dropped": getattr(t, "messages_dropped", 0),
        }

"""ElasticController: the closed loop the paper leaves as future work.

    "Via a controller, a new worker can be created and added back ...
     we leave it as future work."  (§3.1)

One asyncio task per pipeline: each tick it (1) polls MetricsHub, (2) heals
— every watchdog-fenced replica is unhooked (``remove_replica(drain=False)``)
and replaced via online instantiation, the paper's Fig. 2c rhombus with the
human taken out of the loop — and (3) executes the scaling policy: scale-up
through ``add_replica`` (fresh worlds, zero disturbance to live traffic),
scale-down through the drain-and-remove path (zero request loss).

Healing outranks scaling: a fenced replica distorts the load signal, so the
loop restores capacity first and lets policies see the healed state next
tick. Every action lands in ``timeline`` for Fig. 5-style reporting.
"""
from __future__ import annotations

import asyncio
import copy
import dataclasses
import time
from typing import Optional, Union

from .metrics import MetricsHub, StageSnapshot
from .policy import ScalingPolicy, TargetQueueDepthPolicy


@dataclasses.dataclass(frozen=True)
class ControlEvent:
    t: float
    kind: str          # scale_up | scale_down | heal | error
    stage: int
    detail: str


class ElasticController:
    def __init__(
        self,
        server,
        policy: Union[ScalingPolicy, list[ScalingPolicy], None] = None,
        *,
        hub: Optional[MetricsHub] = None,
        interval: float = 0.1,
        heal: bool = True,
        scale_stages: Optional[list[int]] = None,
        migrate_on_drain: bool = True,
    ) -> None:
        self.server = server
        self.hub = hub or MetricsHub(server)
        n = server.n_stages
        if policy is None:
            policy = [TargetQueueDepthPolicy() for _ in range(n)]
        elif not isinstance(policy, list):
            # one independent policy object per stage — policies (and their
            # wrapped inners) carry hysteresis state, so a shallow copy
            # would cross-contaminate stages
            policy = [copy.deepcopy(policy) for _ in range(n)]
        if len(policy) != n:
            raise ValueError(f"need one policy per stage: got {len(policy)} "
                             f"for {n} stages")
        self.policies: list[ScalingPolicy] = policy
        self.interval = interval
        self.heal = heal
        #: scale-down discipline: live-migrate open sessions to survivors
        #: (state transfer) instead of bouncing them into re-prefill; False
        #: restores the PR 2 drain for A/B benchmarking
        self.migrate_on_drain = migrate_on_drain
        #: stages the policy may resize (healing covers all stages always);
        #: default: every stage
        self.scale_stages = (list(range(n)) if scale_stages is None
                             else scale_stages)
        self.timeline: list[ControlEvent] = []
        self.ticks = 0
        self.heals = 0
        self.scale_ups = 0
        self.scale_downs = 0
        self._task: Optional[asyncio.Task] = None
        self._stop = asyncio.Event()

    # ------------------------------------------------------------- lifecycle
    def start(self) -> None:
        if self._task is None or self._task.done():
            self._stop.clear()
            self._task = asyncio.ensure_future(self.run())

    async def stop(self) -> None:
        self._stop.set()
        if self._task is not None:
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None

    async def run(self) -> None:
        while not self._stop.is_set():
            try:
                await self.step()
            except asyncio.CancelledError:
                raise
            except Exception as e:  # noqa: BLE001 — a raising policy or
                # observation pass must not silently end healing forever
                self._record("error", -1, f"control tick failed: {e!r}")
            try:
                await asyncio.wait_for(self._stop.wait(), self.interval)
            except asyncio.TimeoutError:
                pass

    # ------------------------------------------------------------- one tick
    async def step(self) -> list[StageSnapshot]:
        self.ticks += 1
        snaps = self.hub.poll()
        if self.heal:
            await self._heal_failed()
        for snap in snaps:
            if snap.stage not in self.scale_stages:
                continue
            decision = self.policies[snap.stage].decide(snap)
            if decision.hold:
                continue
            await self._apply(decision)
        return snaps

    async def _heal_failed(self) -> None:
        for stage in range(self.server.n_stages):
            for worker_id in self.server.failed_replicas(stage):
                # A dead worker can't drain; an alive-but-cut-off replica
                # (every upstream edge fenced) still can — instantiate the
                # successor first (capacity never dips), then drain the old
                # one so its queued payloads reach downstream before
                # teardown.
                worker = self.server.cluster.workers.get(worker_id)
                alive = worker is not None and worker.alive
                try:
                    if alive:
                        new_id = await self.server.add_replica(stage)
                        try:
                            await self.server.remove_replica(
                                stage, worker_id, drain=True, timeout=10.0)
                        except TimeoutError:
                            await self.server.remove_replica(
                                stage, worker_id, drain=False)
                    else:
                        await self.server.remove_replica(
                            stage, worker_id, drain=False)
                        new_id = await self.server.add_replica(stage)
                except Exception as e:  # noqa: BLE001 — keep the loop alive
                    self._record("error", stage, f"heal failed: {e!r}")
                    continue
                self.heals += 1
                self._record("heal", stage,
                             f"{worker_id} fenced -> replaced by {new_id}")

    async def _apply(self, decision) -> None:
        stage, delta = decision.stage, decision.delta
        try:
            if delta > 0:
                for _ in range(delta):
                    new_id = await self.server.add_replica(stage)
                    self.scale_ups += 1
                    self._record("scale_up", stage,
                                 f"+{new_id} ({decision.reason})")
            else:
                for _ in range(-delta):
                    gone = await self.server.remove_replica(
                        stage, drain=True, migrate=self.migrate_on_drain)
                    self.scale_downs += 1
                    self._record("scale_down", stage,
                                 f"-{gone} ({decision.reason})")
        except Exception as e:  # noqa: BLE001 — a failed action must not
            # kill the control loop; next tick re-observes and retries
            self._record("error", stage, f"{decision.reason}: {e!r}")

    def _record(self, kind: str, stage: int, detail: str) -> None:
        self.timeline.append(
            ControlEvent(time.monotonic(), kind, stage, detail))

    # ------------------------------------------------------------ reporting
    def replica_counts(self) -> list[int]:
        return [len(self.server.healthy_replicas(s))
                for s in range(self.server.n_stages)]

"""ElasticController: the closed loop the paper leaves as future work.

    "Via a controller, a new worker can be created and added back ...
     we leave it as future work."  (§3.1)

One asyncio task per pipeline: each tick it (1) polls MetricsHub, (2) heals
— every watchdog-fenced replica is replaced via online instantiation, the
paper's Fig. 2c rhombus with the human taken out of the loop — and (3)
executes the scaling policy: scale-up through ``add_replica`` (fresh worlds,
zero disturbance to live traffic), scale-down through the drain-and-remove
path (zero request loss).

Heal moves state instead of recomputing it, like drain does:

* an **alive-but-fenced** replica (its worlds are broken, but the worker is
  reachable in-process) gets a replacement instantiated on its own host
  (``near=``), then its open sessions are *live-migrated* to same-stage
  survivors (``MigrationManager.heal_replica_sessions``) before teardown —
  bounced clients, parked in their restore grace window, rewire the route
  from the moved state and resume with **zero recomputed tokens**;
* a **dead** worker cannot hand anything off — its replacement is placed on
  the dead worker's host and the clients' snapshot-restore path (suffix
  replay from the SnapshotStore) remains the fallback.

Either way the replacement joins the victim's own role pool (prefill /
decode / both), so a disaggregated stage heals back to the split the
operator configured; per-role scaling rides ``DisaggregatedStagePolicy``,
whose votes carry the pool they target.

Replacements and scale-ups are **warm** whenever a same-stage peer exists
(weight fetch + compiled-shape warmup before entering rotation), with an
automatic cold fallback.

Multi-model pools add a third lever between "grow" and "shrink": a policy
vote carrying ``swap_to`` directs one stage replica to retarget its
resident model (``PipelineServer.swap_model`` — hot, in rotation), so a
starved model gains capacity at constant fleet size. The controller picks
the hosting replica with the fewest incumbent sessions (cheapest migration
bill), treats a refused swap as a hold, heals a swapped replica back to
the victim's full residency set, and honors ``model``-tagged scale-ups by
bringing the new replica up with that model already loaded.

Heals run as *bounded concurrent tasks* (``max_concurrent_heals``) off the
control loop: one slow drain (``heal_drain_timeout_s``) can no longer
freeze scaling decisions for every other stage. ``wait_heals`` joins them
(tests, teardown). Every action lands in ``timeline`` for Fig. 5-style
reporting.
"""
from __future__ import annotations

import asyncio
import copy
import dataclasses
import time
from typing import Optional, Union

from .metrics import MetricsHub, StageSnapshot
from .policy import ScalingPolicy, TargetQueueDepthPolicy


@dataclasses.dataclass(frozen=True)
class ControlEvent:
    t: float
    kind: str          # scale_up | scale_down | heal | error
    stage: int
    detail: str


class ElasticController:
    def __init__(
        self,
        server,
        policy: Union[ScalingPolicy, list[ScalingPolicy], None] = None,
        *,
        hub: Optional[MetricsHub] = None,
        interval: float = 0.1,
        heal: bool = True,
        scale_stages: Optional[list[int]] = None,
        migrate_on_drain: bool = True,
        live_heal: bool = True,
        warm_replicas: bool = True,
        fresh_executors: bool = False,
        heal_drain_timeout_s: float = 10.0,
        max_concurrent_heals: int = 4,
    ) -> None:
        self.server = server
        self.hub = hub or MetricsHub(server)
        n = server.n_stages
        if policy is None:
            policy = [TargetQueueDepthPolicy() for _ in range(n)]
        elif not isinstance(policy, list):
            # one independent policy object per stage — policies (and their
            # wrapped inners) carry hysteresis state, so a shallow copy
            # would cross-contaminate stages
            policy = [copy.deepcopy(policy) for _ in range(n)]
        if len(policy) != n:
            raise ValueError(f"need one policy per stage: got {len(policy)} "
                             f"for {n} stages")
        self.policies: list[ScalingPolicy] = policy
        self.interval = interval
        self.heal = heal
        #: scale-down discipline: live-migrate open sessions to survivors
        #: (state transfer) instead of bouncing them into re-prefill; False
        #: restores the PR 2 drain for A/B benchmarking
        self.migrate_on_drain = migrate_on_drain
        #: heal discipline: live-migrate an alive-but-fenced replica's open
        #: sessions to the replacement/survivors instead of letting every
        #: one re-prefill its full history; False restores the PR 3 heal
        #: for A/B benchmarking (bench_place)
        self.live_heal = live_heal
        #: warm-bootstrap healed/scaled replicas from a same-stage peer
        #: (weights + compiled shapes) when one exists; cold is automatic
        #: when there is no peer or the warm path fails
        self.warm_replicas = warm_replicas
        #: give each warm replica its own StageExecutor (models a real new
        #: process that cannot share the peers' jit cache); the default
        #: shared executor makes compile warmup a no-op by construction
        self.fresh_executors = fresh_executors
        #: drain budget for the old replica on the heal path (was a
        #: hardcoded 10 s that froze the whole control loop)
        self.heal_drain_timeout_s = heal_drain_timeout_s
        self._heal_sem = asyncio.Semaphore(max(1, max_concurrent_heals))
        #: worker ids with a heal task in flight (dedup across ticks)
        self._healing: set[str] = set()
        self._heal_tasks: set[asyncio.Task] = set()
        #: stages the policy may resize (healing covers all stages always);
        #: default: every stage
        self.scale_stages = (list(range(n)) if scale_stages is None
                             else scale_stages)
        self.timeline: list[ControlEvent] = []
        self.ticks = 0
        self.heals = 0
        self.scale_ups = 0
        self.scale_downs = 0
        self.swaps = 0
        self.slo_alerts = 0
        self._task: Optional[asyncio.Task] = None
        self._stop = asyncio.Event()

    # ------------------------------------------------------------- lifecycle
    def start(self) -> None:
        if self._task is None or self._task.done():
            self._stop.clear()
            self._task = asyncio.ensure_future(self.run())

    async def stop(self) -> None:
        self._stop.set()
        if self._task is not None:
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None
        await self.wait_heals()

    async def wait_heals(self) -> None:
        """Join every in-flight heal task (tests and teardown barriers)."""
        while self._heal_tasks:
            await asyncio.gather(*list(self._heal_tasks),
                                 return_exceptions=True)

    async def run(self) -> None:
        while not self._stop.is_set():
            try:
                await self.step()
            except asyncio.CancelledError:
                raise
            except Exception as e:  # noqa: BLE001 — a raising policy or
                # observation pass must not silently end healing forever
                self._record("error", -1, f"control tick failed: {e!r}")
            try:
                await asyncio.wait_for(self._stop.wait(), self.interval)
            except asyncio.TimeoutError:
                pass

    # ------------------------------------------------------------- one tick
    async def step(self) -> list[StageSnapshot]:
        self.ticks += 1
        snaps = self.hub.poll()
        self._evaluate_slos()
        if self.heal:
            await self._heal_failed()
        for snap in snaps:
            if snap.stage not in self.scale_stages:
                continue
            policy = self.policies[snap.stage]
            # a disaggregated policy votes once per role pool; plain
            # policies keep the single-decision contract
            many = getattr(policy, "decide_many", None)
            decisions = many(snap) if many is not None \
                else [policy.decide(snap)]
            for decision in decisions:
                if decision.hold:
                    continue
                await self._apply(decision)
        return snaps

    def _evaluate_slos(self) -> None:
        """Advance the hub's SLO burn-rate state machine once per tick.
        Alert transitions are control-plane incidents: they land in the
        flight recorder next to the scale decisions they should explain,
        and in the timeline for Fig. 5-style reporting."""
        mon = getattr(self.hub, "slo", None)
        if mon is None:
            return
        for ev in mon.evaluate(time.monotonic()):
            if ev["kind"] == "slo_alert":
                self.slo_alerts += 1
            self.server.recorder.record(ev["kind"], **{
                k: v for k, v in ev.items() if k != "kind"})
            self._record(ev["kind"], -1,
                         f"{ev['slo']} [{ev['severity']}] burn "
                         f"long={ev['burn_long']:.1f} "
                         f"short={ev['burn_short']:.1f} "
                         f"(threshold {ev['threshold']:g})")

    async def _heal_failed(self) -> None:
        """Schedule one bounded background heal task per fenced replica.

        The tasks run off the control loop: a slow drain on one stage no
        longer freezes scaling decisions for every other stage, and several
        failures heal in parallel up to ``max_concurrent_heals``."""
        for stage in range(self.server.n_stages):
            for worker_id in self.server.failed_replicas(stage):
                if worker_id in self._healing:
                    continue        # a heal task is already on it
                self._healing.add(worker_id)
                task = asyncio.ensure_future(self._heal_one(stage, worker_id))
                self._heal_tasks.add(task)
                task.add_done_callback(self._heal_tasks.discard)

    async def _add_replica(self, stage: int, *,
                           role: str = "both",
                           near: Optional[str] = None,
                           host: Optional[str] = None,
                           models: Optional[list] = None) -> str:
        """Warm scale-up/heal with automatic cold fallback: warm bootstrap
        needs a same-stage peer to stream weights/shapes from, and a torn
        warm path must degrade to the plain cold add, never fail the
        action. The replica joins the requested role pool either way.
        ``models`` brings the new replica up hosting those models beyond
        the default (model-tagged scale-up, and heals that restore the
        victim's residency set)."""
        if self.warm_replicas and self.server.healthy_replicas(stage):
            try:
                return await self.server.add_replica(
                    stage, role=role, warm=True,
                    fresh_executor=self.fresh_executors,
                    near=near, host=host, models=models)
            except Exception as e:  # noqa: BLE001 — warm is an optimization
                self._record("error", stage,
                             f"warm bootstrap failed, going cold: {e!r}")
        return await self.server.add_replica(stage, role=role, near=near,
                                             host=host, models=models)

    async def _heal_one(self, stage: int, worker_id: str) -> None:
        """Replace one fenced replica, moving its state instead of
        recomputing it.

        Alive-but-fenced: the successor is instantiated first on the
        victim's host (capacity never dips, migrated bytes stay local),
        the victim's open sessions are live-migrated to same-stage
        survivors, then the victim drains (bounded) and is torn down.
        Dead: unhook, replace on the same host; clients restore from
        background snapshots (the fallback for state nobody can hand off).
        """
        server = self.server
        async with self._heal_sem:
            t_begin = time.monotonic()
            worker = server.cluster.workers.get(worker_id)
            alive = worker is not None and worker.alive
            server.recorder.record("heal_begin", stage=stage,
                                   worker=worker_id, alive=alive)
            host = server.cluster.topology.host_of(worker_id) \
                if worker is not None else None
            victim = next((r for r in server.replicas[stage]
                           if r.worker_id == worker_id), None)
            #: the replacement joins the victim's own pool — healing a dead
            #: decode replica with a 'both' one would silently erode the
            #: split the operator asked for
            role = getattr(victim, "role", "both")
            #: ...and restores the victim's model residency set — healing a
            #: swapped replica back to default-only would silently shrink
            #: the starved model's capacity the swap existed to grow
            default = getattr(server, "default_model", "default")
            models = [m for m in getattr(victim, "resident", ()) or ()
                      if m != default]
            try:
                if alive:
                    new_id = await self._add_replica(stage, role=role,
                                                     host=host,
                                                     models=models)
                    rep = victim
                    if self.live_heal and rep is not None and rep.sessions:
                        moved = await server.migrations \
                            .heal_replica_sessions(rep)
                        n_ok = sum(1 for ok in moved.values() if ok)
                        self._record(
                            "heal_migrate", stage,
                            f"{n_ok}/{len(moved)} sessions live-migrated "
                            f"off {worker_id}")
                    try:
                        # live_heal already moved the sessions; with it off,
                        # the drain-time migrate reproduces the PR 3 heal
                        # (which fails on pin-less fenced sessions and sends
                        # every one through full re-prefill — bench_place
                        # measures exactly that gap)
                        await server.remove_replica(
                            stage, worker_id, drain=True,
                            timeout=self.heal_drain_timeout_s,
                            migrate=not self.live_heal)
                    except TimeoutError:
                        await server.remove_replica(
                            stage, worker_id, drain=False)
                else:
                    await server.remove_replica(
                        stage, worker_id, drain=False)
                    new_id = await self._add_replica(stage, role=role,
                                                     host=host,
                                                     models=models)
            except Exception as e:  # noqa: BLE001 — keep the loop alive
                self._record("error", stage, f"heal failed: {e!r}")
                server.recorder.record("heal_failed", stage=stage,
                                       worker=worker_id, error=repr(e))
                server.recorder.dump("heal_failed", stage=stage,
                                     worker=worker_id)
                return
            finally:
                self._healing.discard(worker_id)
            self.heals += 1
            self._record("heal", stage,
                         f"{worker_id} fenced -> replaced by {new_id}")
            # a heal is a control-plane incident: span it (own root — it
            # belongs to no client session) and snapshot the flight recorder
            # so the window leading up to the failure survives the ring
            root = server.tracer.begin()
            server.tracer.record(
                root, "heal", t_begin, time.monotonic() - t_begin,
                worker_id, f"stage={stage} replacement={new_id} "
                f"alive={alive}")
            server.recorder.record("heal_done", stage=stage,
                                   worker=worker_id, replacement=new_id,
                                   alive=alive,
                                   heal_s=time.monotonic() - t_begin)
            server.recorder.dump("heal", stage=stage, worker=worker_id,
                                 replacement=new_id)

    async def _apply(self, decision) -> None:
        stage, delta = decision.stage, decision.delta
        role = getattr(decision, "role", None)
        model = getattr(decision, "model", None)
        # every acted-on policy vote lands in the flight recorder — a crash
        # dump must show *why* the fleet was the size it was
        self.server.recorder.record("scale_decision",
                                    **decision.as_record())
        try:
            if getattr(decision, "swap_to", None) is not None:
                await self._apply_swap(decision)
            if delta > 0:
                for _ in range(delta):
                    new_id = await self._add_replica(
                        stage, role=role or "both",
                        models=[model] if model else None)
                    self.scale_ups += 1
                    self._record("scale_up", stage,
                                 f"+{new_id} ({decision.reason})")
            elif delta < 0:
                for _ in range(-delta):
                    gone = await self.server.remove_replica(
                        stage, role=role, drain=True,
                        migrate=self.migrate_on_drain)
                    self.scale_downs += 1
                    self._record("scale_down", stage,
                                 f"-{gone} ({decision.reason})")
        except Exception as e:  # noqa: BLE001 — a failed action must not
            # kill the control loop; next tick re-observes and retries
            self._record("error", stage, f"{decision.reason}: {e!r}")

    async def _apply_swap(self, decision) -> None:
        """Execute a residency rebalance vote: pick the stage replica that
        hosts ``swap_from`` with the fewest open sessions running it (the
        cheapest migration bill) and direct it to swap to ``swap_to``. A
        refused swap (``ResidencyError`` — e.g. nowhere to migrate the
        incumbent sessions) is a hold, not a failure: the next tick
        re-observes, and a heal or scale-up may have changed the answer."""
        from repro.serving.registry import ResidencyError

        server = self.server
        stage = decision.stage
        src, dst = decision.swap_from, decision.swap_to
        default = getattr(server, "default_model", "default")
        src = src or default
        candidates = [
            r for r in server.replicas[stage]
            if r.worker.alive and not r.draining
            and src in getattr(r, "resident", ())
            and dst not in getattr(r, "resident", ())]
        if not candidates:
            self._record("swap_hold", stage,
                         f"no replica hosts {src!r} without {dst!r}")
            return

        def _src_sessions(r):
            return sum(1 for s in r.sessions.values()
                       if (getattr(s, "model", None) or default) == src)

        victim = min(candidates, key=_src_sessions)
        try:
            report = await server.swap_model(victim.worker_id, src, dst)
        except ResidencyError as e:
            self._record("swap_hold", stage, f"swap refused: {e}")
            return
        self.swaps += 1
        self._record(
            "swap", stage,
            f"{victim.worker_id}: {src!r} -> {dst!r} "
            f"[{report.get('source')}, {report.get('bytes', 0)}B] "
            f"({decision.reason})")

    #: soft cap on the retained action timeline — a days-long elastic run
    #: appends one event per action forever otherwise; oldest half dropped
    MAX_TIMELINE = 65_536

    def _record(self, kind: str, stage: int, detail: str) -> None:
        self.timeline.append(
            ControlEvent(time.monotonic(), kind, stage, detail))
        if len(self.timeline) > self.MAX_TIMELINE:
            del self.timeline[:self.MAX_TIMELINE // 2]

    # ------------------------------------------------------------ reporting
    def replica_counts(self) -> list[int]:
        return [len(self.server.healthy_replicas(s))
                for s in range(self.server.n_stages)]

"""Hierarchical metric digests: bounded, mergeable rollups of load samples.

The flat telemetry plane (MetricsHub iterating every replica's sample each
poll) is per-replica-granular: O(fleet) work and O(fleet) state at the
single controller process. This module is the mergeable middle layer that
makes the plane hierarchical:

    replica samples --fold--> shard digests --merge--> stage digest
                                    stage digests --merge--> fleet digest

A :class:`StageDigest` is a *bounded-size* rollup — a fixed set of partial
sums/counts plus two :class:`~repro.obs.sketch.LogSketch` latency sketches
(TTFT, per-dispatch decode) — so a digest of 4 replicas and a digest of
40k replicas are the same number of bytes. Every aggregate a scaling
policy reads is kept in a merge-closed form:

* sums (queue, throughput, tokens/s, open sessions, expired) — additive;
* means (stage latency, TTFT, decode latency) — kept as (sum, n) pairs;
* tail quantiles (p95 TTFT, p99 decode) — mergeable sketches, so the
  fleet p99 is computed from the fleet-level merged sketch, not from an
  unsound average-of-percentiles.

``fold_samples`` is the one aggregation implementation: MetricsHub drives
it per stage (sharded when the replica set is large), benches drive it
directly to prove that sharded hierarchical aggregation produces the same
policy decisions as a flat fold over the identical samples.

This package stays dependency-free within the repo: samples are
duck-typed (any object with the ``ReplicaSample`` load fields), and the
control layer converts digests into its own ``StageSnapshot`` view.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

from .sketch import LogSketch

__all__ = ["StageDigest", "fold_samples", "merge_digests"]

#: wire-form schema tag for digest rollups
WIRE_SCHEMA = "digest/v1"

#: relative accuracy of the digest latency sketches — 1% keeps p99
#: estimates honest while a replica's sketch stays well under a KB
DEFAULT_ACCURACY = 0.01


def _sketch() -> LogSketch:
    return LogSketch(DEFAULT_ACCURACY)


@dataclasses.dataclass
class StageDigest:
    """Bounded mergeable rollup of one replica group's load samples.

    ``stage`` is the pipeline stage (-1 for the cross-stage fleet rollup),
    ``role`` the pool slice ("all" = whole stage). All scalar fields are
    merge-closed partial aggregates; derived views (means, percentiles)
    are properties so a merged digest never carries stale derivations.
    """

    stage: int = -1
    t: float = 0.0
    role: str = "all"
    # -- counts --------------------------------------------------------
    n_samples: int = 0           # samples folded in (healthy or not)
    n_replicas: int = 0          # healthy (alive, not draining, not failed)
    n_failed: int = 0            # watchdog-fenced heal candidates
    # -- additive sums over healthy replicas ---------------------------
    queue_total: int = 0
    throughput: float = 0.0
    tokens_per_s: float = 0.0
    open_sessions: int = 0
    latency_sum: float = 0.0     # sum of per-replica sojourn EWMAs
    # -- additive over ALL samples (cumulative counters survive fencing)
    expired: int = 0
    processed: int = 0
    # -- (sum, n) pairs over replicas that serve the kind --------------
    ttft_sum: float = 0.0
    ttft_n: int = 0
    declat_sum: float = 0.0
    declat_n: int = 0
    # -- mergeable latency distributions -------------------------------
    ttft_sketch: LogSketch = dataclasses.field(default_factory=_sketch)
    decode_sketch: LogSketch = dataclasses.field(default_factory=_sketch)

    # ------------------------------------------------------------- derived
    @property
    def latency_s(self) -> float:
        return self.latency_sum / self.n_replicas if self.n_replicas else 0.0

    @property
    def ttft_s(self) -> float:
        return self.ttft_sum / self.ttft_n if self.ttft_n else 0.0

    @property
    def decode_latency_s(self) -> float:
        return self.declat_sum / self.declat_n if self.declat_n else 0.0

    @property
    def queue_per_replica(self) -> float:
        return self.queue_total / max(self.n_replicas, 1)

    @property
    def p95_ttft_s(self) -> float:
        return self.ttft_sketch.p95()

    @property
    def p99_ttft_s(self) -> float:
        return self.ttft_sketch.p99()

    @property
    def p95_decode_s(self) -> float:
        return self.decode_sketch.p95()

    @property
    def p99_decode_s(self) -> float:
        return self.decode_sketch.p99()

    # --------------------------------------------------------------- fold
    def add_sample(self, s, failed: bool = False) -> None:
        """Fold one replica load sample (duck-typed ``ReplicaSample``)."""
        self.n_samples += 1
        self.expired += s.expired
        self.processed += getattr(s, "processed", 0)
        if failed:
            self.n_failed += 1
        healthy = s.alive and not s.draining and not failed
        if not healthy:
            return
        self.n_replicas += 1
        self.queue_total += s.queue_depth
        self.throughput += s.throughput
        self.tokens_per_s += s.tokens_per_s
        self.open_sessions += s.open_sessions
        self.latency_sum += s.latency_s
        # per-kind means count only replicas that actually serve the kind:
        # a decode pool's zero TTFT must not dilute the prefill signal
        if s.ttft_s > 0:
            self.ttft_sum += s.ttft_s
            self.ttft_n += 1
        if s.decode_lat_s > 0:
            self.declat_sum += s.decode_lat_s
            self.declat_n += 1
        tsk = getattr(s, "ttft_sketch", None)
        if tsk is not None and tsk.count:
            self.ttft_sketch.merge(tsk)
        dsk = getattr(s, "decode_sketch", None)
        if dsk is not None and dsk.count:
            self.decode_sketch.merge(dsk)

    def merge(self, other: "StageDigest") -> "StageDigest":
        """Lossless rollup merge: sums add, (sum, n) pairs add, sketches
        merge bucket-wise — associative and commutative, so any shard
        tree over the same samples yields the same digest."""
        if other.t > self.t:
            self.t = other.t
        if self.stage != other.stage:
            self.stage = -1          # cross-stage rollup = fleet view
        if self.role != other.role:
            self.role = "all"
        self.n_samples += other.n_samples
        self.n_replicas += other.n_replicas
        self.n_failed += other.n_failed
        self.queue_total += other.queue_total
        self.throughput += other.throughput
        self.tokens_per_s += other.tokens_per_s
        self.open_sessions += other.open_sessions
        self.latency_sum += other.latency_sum
        self.expired += other.expired
        self.processed += other.processed
        self.ttft_sum += other.ttft_sum
        self.ttft_n += other.ttft_n
        self.declat_sum += other.declat_sum
        self.declat_n += other.declat_n
        self.ttft_sketch.merge(other.ttft_sketch)
        self.decode_sketch.merge(other.decode_sketch)
        return self

    # ----------------------------------------------------------- wire form
    def summary(self) -> dict:
        """Flat scalar view for exporters/artifacts (no sketches)."""
        return {
            "stage": self.stage,
            "role": self.role,
            "n_replicas": self.n_replicas,
            "n_failed": self.n_failed,
            "queue_total": self.queue_total,
            "throughput": self.throughput,
            "tokens_per_s": self.tokens_per_s,
            "open_sessions": self.open_sessions,
            "expired": self.expired,
            "latency_s": self.latency_s,
            "ttft_s": self.ttft_s,
            "decode_latency_s": self.decode_latency_s,
            "p95_ttft_s": self.p95_ttft_s,
            "p99_ttft_s": self.p99_ttft_s,
            "p95_decode_s": self.p95_decode_s,
            "p99_decode_s": self.p99_decode_s,
        }

    def to_wire(self) -> dict:
        """Compact JSON-able form — what a sharded aggregator would ship
        upward instead of raw samples."""
        return {
            "schema": WIRE_SCHEMA,
            "t": self.t,
            "scalars": {
                f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)
                if f.name not in ("ttft_sketch", "decode_sketch")
            },
            "ttft_sketch": self.ttft_sketch.to_wire(),
            "decode_sketch": self.decode_sketch.to_wire(),
        }

    @classmethod
    def from_wire(cls, wire: dict) -> "StageDigest":
        if wire.get("schema") != WIRE_SCHEMA:
            raise ValueError(f"not a {WIRE_SCHEMA} wire form: "
                             f"{wire.get('schema')!r}")
        out = cls(**wire["scalars"])
        out.ttft_sketch = LogSketch.from_wire(wire["ttft_sketch"])
        out.decode_sketch = LogSketch.from_wire(wire["decode_sketch"])
        return out


def fold_samples(samples: Sequence, failed: Iterable[str] = (), *,
                 stage: int = 0, t: float = 0.0, role: str = "all",
                 shard: Optional[int] = None) -> StageDigest:
    """Fold replica samples into one :class:`StageDigest`.

    ``shard=None`` folds flat, in sample order — the reference ("raw")
    aggregation. ``shard=N`` folds hierarchically: consecutive groups of N
    samples become partial digests that are then merged — the fleet-scale
    path, where each group models one sharded aggregator. Both paths fold
    the identical samples into merge-closed aggregates, so the resulting
    policy decisions must agree (``bench_fleet`` gates exactly that).
    """
    failed = set(failed)
    if shard is None or shard <= 0 or len(samples) <= shard:
        d = StageDigest(stage=stage, t=t, role=role)
        for s in samples:
            d.add_sample(s, failed=s.worker_id in failed)
        return d
    parts = []
    for i in range(0, len(samples), shard):
        part = StageDigest(stage=stage, t=t, role=role)
        for s in samples[i:i + shard]:
            part.add_sample(s, failed=s.worker_id in failed)
        parts.append(part)
    return merge_digests(parts)


def merge_digests(digests: Sequence[StageDigest]) -> StageDigest:
    """Merge a non-empty sequence of digests left-to-right (the fleet
    rollup used for stage -> fleet folding too)."""
    if not digests:
        return StageDigest()
    out = digests[0]
    for d in digests[1:]:
        out.merge(d)
    return out

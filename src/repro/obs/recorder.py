"""FlightRecorder: a crash-dump ring of structured control-plane events.

Benches and examples today end with "zero failures" — an aggregate that
says nothing about *what happened on the way*. The recorder keeps the last
N structured events (world create/fence/remove, scale decisions with the
policy's vote text, pin flips, deadline expiries, codec fallbacks) in a
bounded deque and serializes them to a schema-versioned JSON dump on any
unhandled failure, every heal, or an explicit :meth:`dump` — the same
artifact shape whether it came from a crash or a curious operator.

Events are plain dicts with a monotonic timestamp and a ``kind``; fields
beyond that are event-specific and must be JSON-serializable (the recorder
coerces stragglers to ``str`` at dump time, never at record time — the
record path is one dict build + one deque append).
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from typing import Optional

__all__ = ["FlightRecorder", "validate_dump"]

SCHEMA = "flightrec/v1"


class FlightRecorder:
    def __init__(self, capacity: int = 4096, *,
                 dump_dir: Optional[str] = None,
                 name: str = "pipe",
                 max_dumps: int = 32) -> None:
        self.capacity = capacity
        self.name = name
        #: where :meth:`dump` also writes a file; None = in-memory only
        self.dump_dir = dump_dir
        #: on-disk bound: only the newest ``max_dumps`` dump files are kept
        #: (a long elastic run heals — and dumps — indefinitely; the disk
        #: must not grow with uptime). <= 0 disables rotation.
        self.max_dumps = max_dumps
        self._events: deque = deque(maxlen=capacity)
        self.recorded = 0
        self.dumps_total = 0
        self.dumps_rotated = 0
        #: the most recent dump dict (tests and artifact writers read this)
        self.last_dump: Optional[dict] = None
        #: the most recent dumps in order — the benches schema-validate one
        #: entry per heal, so the window must cover a whole scenario's heals
        self.dump_log: deque = deque(maxlen=64)
        #: paths written by this recorder, oldest first (rotation set)
        self._dump_paths: deque = deque()
        self._uid = 0

    # ------------------------------------------------------------ recording
    def record(self, kind: str, **fields) -> None:
        ev = {"t": time.monotonic(), "kind": kind}
        if fields:
            ev.update(fields)
        self._events.append(ev)
        self.recorded += 1

    def __len__(self) -> int:
        return len(self._events)

    def events(self, kind: Optional[str] = None) -> list[dict]:
        if kind is None:
            return list(self._events)
        return [e for e in self._events if e["kind"] == kind]

    # -------------------------------------------------------------- dumping
    def dump(self, reason: str, **context) -> dict:
        """Serialize the ring (oldest first) into a schema-versioned dict;
        also writes ``<dump_dir>/flightrec_<name>_<n>.json`` when a dump
        directory is configured. Returns the dump dict either way."""
        d = {
            "schema": SCHEMA,
            "name": self.name,
            "reason": reason,
            "wall_clock": time.time(),
            "dropped": max(0, self.recorded - len(self._events)),
            "events": [self._jsonable(e) for e in self._events],
        }
        if context:
            d["context"] = {k: self._coerce(v) for k, v in context.items()}
        self.dumps_total += 1
        self.last_dump = d
        self.dump_log.append(d)
        if self.dump_dir:
            self._uid += 1
            path = os.path.join(
                self.dump_dir, f"flightrec_{self.name}_{self._uid}.json")
            try:
                os.makedirs(self.dump_dir, exist_ok=True)
                with open(path, "w") as f:
                    json.dump(d, f, indent=2)
                d["path"] = path
                self._dump_paths.append(path)
                self._rotate()
            except OSError:
                pass  # a full disk must not turn a dump into a crash
        return d

    def _rotate(self) -> None:
        """Keep only the newest ``max_dumps`` files this recorder wrote."""
        if self.max_dumps <= 0:
            return
        while len(self._dump_paths) > self.max_dumps:
            old = self._dump_paths.popleft()
            try:
                os.remove(old)
                self.dumps_rotated += 1
            except OSError:
                pass  # already gone / permissions: rotation is best-effort

    @classmethod
    def _jsonable(cls, ev: dict) -> dict:
        return {k: cls._coerce(v) for k, v in ev.items()}

    @staticmethod
    def _coerce(v):
        if isinstance(v, (str, int, float, bool)) or v is None:
            return v
        if isinstance(v, (list, tuple)):
            return [FlightRecorder._coerce(x) for x in v]
        if isinstance(v, dict):
            return {str(k): FlightRecorder._coerce(x) for k, x in v.items()}
        return str(v)


def validate_dump(d: dict) -> bool:
    """Schema check for a flight-recorder dump: the gate the migrate/place
    suites run on every heal-triggered dump."""
    if not isinstance(d, dict) or d.get("schema") != SCHEMA:
        return False
    for field in ("name", "reason", "wall_clock", "dropped", "events"):
        if field not in d:
            return False
    if not isinstance(d["events"], list):
        return False
    for ev in d["events"]:
        if not isinstance(ev, dict):
            return False
        if "t" not in ev or "kind" not in ev:
            return False
        if not isinstance(ev["kind"], str):
            return False
    return True

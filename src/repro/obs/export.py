"""Export surface: Prometheus text rendering + trace artifacts.

``render_prometheus`` turns nested metric dicts into the Prometheus text
exposition format (``# TYPE`` headers, label sets, one sample per line) —
:meth:`MetricsHub.export_prometheus` drives it with the hub's own metric
groups plus the tracer's per-kind digests. ``write_trace_artifact`` is the
shared writer the benches and examples use to drop a ``TRACE_*.json`` next
to their ``BENCH_*.json``: tracer summary + per-kind counts + any flight
recorder dumps collected during the run.
"""
from __future__ import annotations

import json
import re
import time
from typing import Optional

__all__ = ["render_prometheus", "write_trace_artifact"]

_NAME_BAD = re.compile(r"[^a-zA-Z0-9_]")

TRACE_SCHEMA = "trace/v1"


def _metric_name(*parts: str) -> str:
    return _NAME_BAD.sub("_", "_".join(p for p in parts if p))


def _escape_label(v) -> str:
    """Prometheus label-value escaping: backslash, double quote, newline.
    Arbitrary pipeline/model names (quotes, paths, unicode) must not break
    the scrape — the exposition format spec is explicit about these three.
    """
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _labels(labels: dict) -> str:
    if not labels:
        return ""
    body = ",".join(f'{k}="{_escape_label(v)}"'
                    for k, v in sorted(labels.items()))
    return "{" + body + "}"


def render_prometheus(groups: dict, *, prefix: str = "repro") -> str:
    """Render ``{group: {metric: value | {label: value}}}`` as Prometheus
    text. Scalar values become plain gauges; a dict value becomes one
    sample per label (e.g. per-replica throughput). Non-numeric values are
    skipped — the endpoint never raises on a weird counter. Every metric
    gets ``# HELP`` and ``# TYPE`` headers and label values are escaped,
    so the output is scrape-compliant for arbitrary pipeline/model names.
    """
    lines: list[str] = []
    for group, metrics in sorted(groups.items()):
        if not isinstance(metrics, dict):
            continue
        for metric, value in sorted(metrics.items()):
            name = _metric_name(prefix, group, metric)
            if isinstance(value, bool):
                value = int(value)
            if isinstance(value, (int, float)):
                lines.append(f"# HELP {name} {group} {metric}")
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name} {value}")
            elif isinstance(value, dict):
                samples = [(k, v) for k, v in sorted(value.items())
                           if isinstance(v, (int, float))
                           and not isinstance(v, bool)]
                if not samples:
                    continue
                lines.append(f"# HELP {name} {group} {metric} (per id)")
                lines.append(f"# TYPE {name} gauge")
                for k, v in samples:
                    lines.append(f"{name}{_labels({'id': k})} {v}")
    return "\n".join(lines) + "\n"


def write_trace_artifact(path: str, *, suite: str,
                         tracer=None,
                         recorder=None,
                         extra: Optional[dict] = None) -> dict:
    """Write the trace artifact every bench/example drops next to its
    ``BENCH_*.json``. Accepts either live objects or pre-collected dicts
    (the benches tear their servers down between phases)."""
    summary = tracer.summary() if hasattr(tracer, "summary") else (tracer or {})
    art = {
        "schema": TRACE_SCHEMA,
        "suite": suite,
        "wall_clock": time.time(),
        "span_summary": summary,
        "spans_recorded": getattr(tracer, "recorded", None),
        "spans_dropped": getattr(tracer, "dropped", None),
    }
    if recorder is not None:
        if hasattr(recorder, "events"):
            art["flight_events"] = len(recorder)
            art["flight_dumps"] = recorder.dumps_total
            art["last_dump"] = recorder.last_dump
        else:
            art["flight"] = recorder
    if extra:
        art.update(extra)
    with open(path, "w") as f:
        json.dump(art, f, indent=2, default=str)
    return art

"""Observability: causal spans + digests + SLOs + flight recorder + export.

This package is dependency-free within the repo (imports nothing from
``core``/``serving``/``control``) so every layer can import it without
cycles. Five pieces:

* :mod:`~repro.obs.trace` — an allocation-cheap :class:`Tracer` whose
  :class:`TraceContext` rides every :class:`~repro.serving.envelope.Envelope`
  so one session's lifecycle (prefill, per-step decode, handoff, snapshot,
  migration, heal, restore replay) reconstructs as one causal tree; head
  sampling with tail-based keep rules bounds its cost at fleet scale;
* :mod:`~repro.obs.sketch` — :class:`LogSketch`, a DDSketch-style
  mergeable quantile sketch with a guaranteed relative-error bound, the
  primitive that makes tail latencies (p95 TTFT, p99 decode) foldable
  across the replica → stage → fleet hierarchy;
* :mod:`~repro.obs.digest` — :class:`StageDigest`, a bounded mergeable
  rollup of replica load samples (sums, (sum, n) means, latency sketches)
  that MetricsHub folds hierarchically instead of iterating raw samples;
* :mod:`~repro.obs.slo` — per-pipeline :class:`SLOSpec`s with
  multi-window burn-rate evaluation (:class:`SLOMonitor`) emitting
  flight-recorder events and the ``slo`` Prometheus group;
* :mod:`~repro.obs.recorder` — a :class:`FlightRecorder` ring buffer of
  structured control-plane events (world lifecycle, scale votes, pin flips,
  deadline expiries, codec fallbacks, SLO alerts) that dumps to JSON on
  failure/heal, rotating old dumps;
* :mod:`~repro.obs.export` — Prometheus text rendering and the shared
  trace-artifact writer the benches and examples use.
"""
from .digest import StageDigest, fold_samples, merge_digests
from .recorder import FlightRecorder, validate_dump
from .sketch import LogSketch
from .slo import (BurnRatePolicy, DEFAULT_BURN_POLICIES, SLOMonitor,
                  SLOSpec, SLOTracker)
from .trace import (DEFAULT_KEEP_KINDS, SpanKind, TraceContext, Tracer,
                    connected_tree)

__all__ = [
    "BurnRatePolicy",
    "DEFAULT_BURN_POLICIES",
    "DEFAULT_KEEP_KINDS",
    "FlightRecorder",
    "LogSketch",
    "SLOMonitor",
    "SLOSpec",
    "SLOTracker",
    "SpanKind",
    "StageDigest",
    "TraceContext",
    "Tracer",
    "connected_tree",
    "fold_samples",
    "merge_digests",
    "validate_dump",
]

"""Observability: causal spans + flight recorder + export surface.

This package is dependency-free within the repo (imports nothing from
``core``/``serving``/``control``) so every layer can import it without
cycles. Three pieces:

* :mod:`~repro.obs.trace` — an allocation-cheap :class:`Tracer` whose
  :class:`TraceContext` rides every :class:`~repro.serving.envelope.Envelope`
  so one session's lifecycle (prefill, per-step decode, handoff, snapshot,
  migration, heal, restore replay) reconstructs as one causal tree;
* :mod:`~repro.obs.recorder` — a :class:`FlightRecorder` ring buffer of
  structured control-plane events (world lifecycle, scale votes, pin flips,
  deadline expiries, codec fallbacks) that dumps to JSON on failure/heal;
* :mod:`~repro.obs.export` — Prometheus text rendering and the shared
  trace-artifact writer the benches and examples use.
"""
from .recorder import FlightRecorder, validate_dump
from .trace import SpanKind, TraceContext, Tracer, connected_tree

__all__ = [
    "FlightRecorder",
    "SpanKind",
    "TraceContext",
    "Tracer",
    "connected_tree",
    "validate_dump",
]

"""Causal spans: one tree per client session, allocation-cheap emission.

The data plane moves one envelope per decode *step*; at thousands of
tokens/s any per-span allocation (a dict, a dataclass, a list append that
reallocates) shows up in the tokens/s A/B. The :class:`Tracer` therefore
preallocates a ring of reusable slot lists and mutates them in place —
recording a span is eight item stores and one index increment, no object
churn. The ring is a *recorder*, not a queue: readers (``spans()``,
``summary()``, artifact writers) materialize dicts on demand, off the hot
path.

Causality is carried by :class:`TraceContext` — ``(trace_id, span_id,
parent_id)`` — stamped on every envelope. The *client* ``generate()`` loop
owns the root context, so the tree survives the session-id changes a
re-prefill causes: PREFILL on the original replica, the RETRY bounce, the
re-prefill under a fresh session id, and the resumed decode all parent back
to the same root.

Sampling (fleet scale): default-on full tracing is the right debugging
default at smoke scale, but at 10k+ concurrent sessions every session
tree churns the ring and the interesting traces (failures, heals, tail
outliers) are overwritten by thousands of boring ones. ``sample_rate``
adds *head sampling with tail-based keep rules*: the keep/drop decision
is minted once at the session root (children inherit it through the
context, across worlds), but an unsampled trace is not discarded
outright — its spans buffer in a small bounded staging area and the trace
is promoted to the ring anyway if it turns out interesting: any span of a
``keep_kinds`` kind (heal/migrate/restore/reprefill by default), any span
whose detail marks an error or RETRY bounce, or any span slower than
``slow_keep_s``. Boring unsampled traces are dropped wholesale when their
root span closes. Tracing cost therefore stays ~flat as sessions grow:
the ring holds every anomalous trace plus a ``sample_rate`` slice of the
healthy ones.

Span taxonomy (the ``kind`` strings the summary aggregates over):

======================  ====================================================
``session``             client root — one per ``generate()`` call
``prefill``             stage-side prefill dispatch (KV-cache build)
``ttft``                client-observed prefill round trip (first token)
``decode``              one stage-side decode step (possibly fused/batched)
``decode_step``         client-observed per-token round trip
``handoff``             prefill→decode pool KV streaming + install
``snapshot``            one background snapshot write (base or delta)
``migrate``             live drain/heal session migration
``restore``             snapshot fetch + install after a kill
``restore_replay``      client-side suffix replay after a restore
``reprefill``           client-side full-history re-prefill (fallback path)
``bootstrap``           warm scale-up (weight fetch + compile warmup)
``heal``                controller heal of one failed replica
======================  ====================================================
"""
from __future__ import annotations

import itertools
import random
import time
from collections import OrderedDict, deque
from typing import Iterable, Optional

__all__ = ["SpanKind", "TraceContext", "Tracer", "connected_tree",
           "DEFAULT_KEEP_KINDS"]

#: span kinds that always promote an unsampled trace to the ring — the
#: control-plane incidents an operator reconstructs after the fact
DEFAULT_KEEP_KINDS = frozenset({
    "heal", "migrate", "restore", "restore_replay", "reprefill",
})


class SpanKind:
    """Well-known span kind strings (any string is accepted)."""

    SESSION = "session"
    PREFILL = "prefill"
    TTFT = "ttft"
    DECODE = "decode"
    DECODE_STEP = "decode_step"
    HANDOFF = "handoff"
    SNAPSHOT = "snapshot"
    MIGRATE = "migrate"
    RESTORE = "restore"
    RESTORE_REPLAY = "restore_replay"
    REPREFILL = "reprefill"
    BOOTSTRAP = "bootstrap"
    HEAL = "heal"


class TraceContext:
    """Identity of one span: which tree, which node, which parent.

    Immutable by convention; 0 is the nil parent (roots). Rides on
    ``Envelope.trace`` and crosses worlds by value — three ints and the
    head-sampling verdict, no references into the emitting process.
    ``sampled=False`` marks a trace whose spans stage in the tail-keep
    buffer instead of the ring (children inherit the verdict, so one
    decision at the session root governs the whole tree fleet-wide).
    """

    __slots__ = ("trace_id", "span_id", "parent_id", "sampled")

    def __init__(self, trace_id: int, span_id: int, parent_id: int = 0,
                 sampled: bool = True):
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.sampled = sampled

    def __repr__(self) -> str:  # debugging only — never on the hot path
        return (f"TraceContext(trace={self.trace_id}, span={self.span_id}, "
                f"parent={self.parent_id}"
                + ("" if self.sampled else ", unsampled") + ")")


# ring slot field offsets (one preallocated list per slot, mutated in place)
_TRACE, _SPAN, _PARENT, _KIND, _WORKER, _T0, _DT, _DETAIL = range(8)


class Tracer:
    """Preallocated span ring. Default-on; ``enabled=False`` turns every
    emission into a cheap early-return so the overhead A/B has a true
    baseline. ``sample_rate < 1.0`` head-samples session roots, with
    tail-based keep rules promoting anomalous unsampled traces (see the
    module docstring)."""

    def __init__(self, capacity: int = 32768, *, enabled: bool = True,
                 sample_rate: float = 1.0,
                 keep_kinds: frozenset = DEFAULT_KEEP_KINDS,
                 slow_keep_s: Optional[float] = None,
                 max_pending_traces: int = 4096,
                 pending_cap: int = 256,
                 seed: int = 0):
        self.enabled = enabled
        self.capacity = capacity
        # one reusable 8-field slot per ring position; item stores only
        self._ring = [[0, 0, 0, "", "", 0.0, 0.0, ""]
                      for _ in range(capacity)]
        self._head = 0          # next slot to overwrite
        self._count = 0         # slots holding live data (<= capacity)
        self.recorded = 0       # spans ever recorded into the ring
        self.dropped = 0        # spans overwritten before being read
        self._ids = itertools.count(1)
        # -- head sampling + tail keep ----------------------------------
        self.sample_rate = sample_rate
        self.keep_kinds = frozenset(keep_kinds)
        self.slow_keep_s = slow_keep_s
        self.max_pending_traces = max_pending_traces
        self.pending_cap = pending_cap
        self._rng = random.Random(seed)
        #: undecided unsampled traces: trace_id -> [keep_flag, spans]
        self._pending: OrderedDict[int, list] = OrderedDict()
        #: recent verdicts for traces whose root already closed, so late
        #: spans (background snapshots, stragglers) of a kept trace still
        #: reach the ring; bounded FIFO
        self._resolved: dict[int, bool] = {}
        self._resolved_order: deque = deque()
        self.sampled_out = 0    # boring unsampled traces discarded
        self.tail_kept = 0      # unsampled traces promoted by a keep rule

    # ------------------------------------------------------------ contexts
    def begin(self, parent: Optional[TraceContext] = None
              ) -> Optional[TraceContext]:
        """Mint a child context (or a root when ``parent`` is None).
        Returns None when disabled so call sites pay one attribute load.
        The head-sampling verdict is decided here, once per root."""
        if not self.enabled:
            return None
        sid = next(self._ids)
        if parent is None:
            sampled = (self.sample_rate >= 1.0
                       or self._rng.random() < self.sample_rate)
            return TraceContext(sid, sid, 0, sampled)
        return TraceContext(parent.trace_id, sid, parent.span_id,
                            parent.sampled)

    # ------------------------------------------------------------ emission
    def record(self, ctx: Optional[TraceContext], kind: str, t0: float,
               dt: float, worker: str = "", detail: str = "") -> None:
        """Store one completed span. No-op on a None context (disabled
        tracer, or an envelope minted before tracing was on). Spans of an
        unsampled trace stage in the tail-keep buffer instead."""
        if ctx is None or not self.enabled:
            return
        if not ctx.sampled:
            self._record_unsampled(ctx, kind, t0, dt, worker, detail)
            return
        self._store(ctx.trace_id, ctx.span_id, ctx.parent_id, kind,
                    worker, t0, dt, detail)

    def _store(self, trace_id: int, span_id: int, parent_id: int,
               kind: str, worker: str, t0: float, dt: float,
               detail: str) -> None:
        slot = self._ring[self._head]
        slot[_TRACE] = trace_id
        slot[_SPAN] = span_id
        slot[_PARENT] = parent_id
        slot[_KIND] = kind
        slot[_WORKER] = worker
        slot[_T0] = t0
        slot[_DT] = dt
        slot[_DETAIL] = detail
        self._head = (self._head + 1) % self.capacity
        if self._count < self.capacity:
            self._count += 1
        else:
            self.dropped += 1
        self.recorded += 1

    # ------------------------------------------------- tail-based sampling
    def _keep_worthy(self, kind: str, dt: float, detail: str) -> bool:
        """Tail keep rules: incident span kinds, error/RETRY details, and
        slow outliers always survive head sampling."""
        if kind in self.keep_kinds:
            return True
        if self.slow_keep_s is not None and dt >= self.slow_keep_s:
            return True
        return "error" in detail or "retry" in detail

    def _record_unsampled(self, ctx: TraceContext, kind: str, t0: float,
                          dt: float, worker: str, detail: str) -> None:
        tid = ctx.trace_id
        verdict = self._resolved.get(tid)
        if verdict is not None:
            if verdict:     # late span of a tail-kept trace: straight in
                self._store(tid, ctx.span_id, ctx.parent_id, kind,
                            worker, t0, dt, detail)
            return
        ent = self._pending.get(tid)
        if ent is None:
            if len(self._pending) >= self.max_pending_traces:
                # decide the oldest undecided trace with what it has —
                # the staging area is bounded, never a leak
                old_tid, old = self._pending.popitem(last=False)
                self._finish_pending(old_tid, old)
            ent = [False, []]           # [keep_flag, spans]
            self._pending[tid] = ent
        if len(ent[1]) < self.pending_cap:
            ent[1].append((tid, ctx.span_id, ctx.parent_id, kind,
                           worker, t0, dt, detail))
        if not ent[0] and self._keep_worthy(kind, dt, detail):
            ent[0] = True
        if ctx.parent_id == 0:          # root closed: decide the tree
            self._pending.pop(tid, None)
            self._finish_pending(tid, ent)

    def _finish_pending(self, tid: int, ent: list) -> None:
        keep, spans = ent
        if keep:
            self.tail_kept += 1
            for s in spans:
                self._store(*s)
        else:
            self.sampled_out += 1
        self._resolved[tid] = keep
        self._resolved_order.append(tid)
        while len(self._resolved_order) > 4096:
            self._resolved.pop(self._resolved_order.popleft(), None)

    def span(self, parent: Optional[TraceContext], kind: str, t0: float,
             worker: str = "", detail: str = "") -> Optional[TraceContext]:
        """Mint a child of ``parent`` and record it closed at now-t0 in one
        call — the common shape for stage-side work that is already done.
        No-op on a None parent: an untraced envelope must not spawn an
        orphan root (roots are minted explicitly via ``begin()``)."""
        if parent is None or not self.enabled:
            return None
        ctx = self.begin(parent)
        self.record(ctx, kind, t0, time.monotonic() - t0, worker, detail)
        return ctx

    # -------------------------------------------------------------- readers
    def _live_slots(self):
        if self._count < self.capacity:
            return self._ring[:self._count]
        # full ring: oldest live slot is at _head
        return self._ring[self._head:] + self._ring[:self._head]

    def spans(self, trace_id: Optional[int] = None) -> list[dict]:
        """Materialize spans as dicts (oldest first), optionally filtered
        to one tree. Reader-side cost only."""
        out = []
        for s in self._live_slots():
            if trace_id is not None and s[_TRACE] != trace_id:
                continue
            out.append({
                "trace_id": s[_TRACE], "span_id": s[_SPAN],
                "parent_id": s[_PARENT], "kind": s[_KIND],
                "worker": s[_WORKER], "t0": s[_T0], "dt": s[_DT],
                "detail": s[_DETAIL],
            })
        return out

    def trace_ids(self) -> list[int]:
        seen: dict[int, None] = {}
        for s in self._live_slots():
            seen.setdefault(s[_TRACE])
        return list(seen)

    def summary(self) -> dict:
        """Per-kind latency digests over the live ring:
        ``{kind: {count, mean_s, p50_s, p95_s, max_s}}``."""
        by_kind: dict[str, list[float]] = {}
        for s in self._live_slots():
            by_kind.setdefault(s[_KIND], []).append(s[_DT])
        out: dict = {}
        for kind, xs in by_kind.items():
            xs.sort()
            n = len(xs)
            out[kind] = {
                "count": n,
                "mean_s": sum(xs) / n,
                "p50_s": xs[n // 2],
                "p95_s": xs[min(n - 1, int(n * 0.95))],
                "max_s": xs[-1],
            }
        return out

    def clear(self) -> None:
        self._head = 0
        self._count = 0
        self._pending.clear()
        self._resolved.clear()
        self._resolved_order.clear()


def connected_tree(spans: Iterable[dict]) -> bool:
    """True iff ``spans`` form exactly one tree: a single root
    (parent_id == 0) and every other span's parent present in the set.
    The acceptance check for 'no orphan spans, parent links intact'."""
    spans = list(spans)
    if not spans:
        return False
    ids = {s["span_id"] for s in spans}
    roots = [s for s in spans if s["parent_id"] == 0]
    if len(roots) != 1:
        return False
    return all(s["parent_id"] in ids for s in spans
               if s["parent_id"] != 0)

"""Mergeable quantile sketch with guaranteed relative error (DDSketch-style).

At fleet scale the telemetry plane cannot ship raw latency samples upward:
a 100k-worker fleet at thousands of requests/s per worker produces more
samples than the controller can even *iterate*, and EWMAs collapse the
distribution to a mean — useless for the p99-tail questions (TTFT SLOs,
burn rates) that actually drive serving decisions. What the hierarchy
needs is a summary that is

* **O(1) insert** on the replica hot path (one log, one dict bump),
* **bounded** in size regardless of stream length (log-bucket collapse),
* **losslessly mergeable** — ``merge(a, b)`` over disjoint streams equals
  the sketch of the concatenated stream, in any association order, so
  replica sketches fold into stage digests fold into a fleet digest with
  no accuracy cliff at any level,
* **relative-error bounded**: every quantile estimate ``q̂`` satisfies
  ``|q̂ - q| <= relative_accuracy * q`` (for values above ``min_value``).

The construction is the DDSketch log-bucket scheme (Masson et al., VLDB
2019): bucket ``i`` covers ``(gamma^(i-1), gamma^i]`` with
``gamma = (1 + a) / (1 - a)``; reporting the geometric mid-point of the
bucket containing the target rank keeps the relative error within ``a``.
Values in ``[0, min_value]`` land in an exact zero-bucket (latencies of
0.0 from unstarted counters must not poison the log). Negative values are
clamped to the zero bucket — every stream this repo folds is a latency or
a byte count.

Size bound: at most ``max_bins`` log buckets are kept; on overflow the
*lowest* buckets collapse into one (tail quantiles — the ones decisions
read — stay exact-to-``a``; only the extreme low quantiles degrade).
"""
from __future__ import annotations

import math
from typing import Iterable, Optional

__all__ = ["LogSketch"]

#: wire-form schema tag (bumped if the bucket encoding ever changes)
WIRE_SCHEMA = "ddsketch/v1"


class LogSketch:
    """DDSketch-style quantile sketch over non-negative values."""

    __slots__ = ("relative_accuracy", "min_value", "max_bins", "_gamma",
                 "_log_gamma", "_buckets", "_zero", "count", "sum",
                 "_min", "_max", "collapsed")

    def __init__(self, relative_accuracy: float = 0.01, *,
                 min_value: float = 1e-9, max_bins: int = 2048) -> None:
        if not 0.0 < relative_accuracy < 1.0:
            raise ValueError(f"relative_accuracy must be in (0, 1): "
                             f"{relative_accuracy}")
        self.relative_accuracy = relative_accuracy
        self.min_value = min_value
        self.max_bins = max_bins
        self._gamma = (1.0 + relative_accuracy) / (1.0 - relative_accuracy)
        self._log_gamma = math.log(self._gamma)
        self._buckets: dict[int, int] = {}
        self._zero = 0               # exact count of values <= min_value
        self.count = 0
        self.sum = 0.0
        self._min: Optional[float] = None
        self._max: Optional[float] = None
        self.collapsed = 0           # low-bucket collapse events (size bound)

    # -------------------------------------------------------------- insert
    def _key(self, x: float) -> int:
        return math.ceil(math.log(x) / self._log_gamma)

    def insert(self, x: float, n: int = 1) -> None:
        """O(1): one log, one dict bump. ``n`` inserts ``x`` with weight."""
        if n <= 0:
            return
        x = float(x)
        self.count += n
        self.sum += x * n
        if self._min is None or x < self._min:
            self._min = x
        if self._max is None or x > self._max:
            self._max = x
        if x <= self.min_value:
            self._zero += n
            return
        key = self._key(x)
        b = self._buckets
        b[key] = b.get(key, 0) + n
        if len(b) > self.max_bins:
            self._collapse()

    def _collapse(self) -> None:
        """Fold the lowest buckets together until within ``max_bins``.
        Collapsing low keys keeps the upper quantiles — the operating
        signals — at full accuracy."""
        keys = sorted(self._buckets)
        while len(self._buckets) > self.max_bins and len(keys) > 1:
            lo = keys.pop(0)
            self._buckets[keys[0]] += self._buckets.pop(lo)
            self.collapsed += 1

    def extend(self, xs: Iterable[float]) -> None:
        for x in xs:
            self.insert(x)

    # --------------------------------------------------------------- merge
    def mergeable(self, other: "LogSketch") -> bool:
        return (abs(other.relative_accuracy - self.relative_accuracy)
                < 1e-12 and abs(other.min_value - self.min_value) < 1e-18)

    def merge(self, other: "LogSketch") -> "LogSketch":
        """Fold ``other`` in, losslessly: the merged sketch is bucket-for-
        bucket identical to one built from the concatenated stream (same
        gamma required), so merge order can never change a quantile."""
        if not self.mergeable(other):
            raise ValueError(
                f"cannot merge sketches with different resolution: "
                f"{self.relative_accuracy} vs {other.relative_accuracy}")
        b = self._buckets
        for key, n in other._buckets.items():
            b[key] = b.get(key, 0) + n
        self._zero += other._zero
        self.count += other.count
        self.sum += other.sum
        self.collapsed += other.collapsed
        if other._min is not None and (self._min is None
                                       or other._min < self._min):
            self._min = other._min
        if other._max is not None and (self._max is None
                                       or other._max > self._max):
            self._max = other._max
        if len(b) > self.max_bins:
            self._collapse()
        return self

    def copy(self) -> "LogSketch":
        out = LogSketch(self.relative_accuracy, min_value=self.min_value,
                        max_bins=self.max_bins)
        out._buckets = dict(self._buckets)
        out._zero = self._zero
        out.count = self.count
        out.sum = self.sum
        out._min = self._min
        out._max = self._max
        out.collapsed = self.collapsed
        return out

    # ------------------------------------------------------------ quantiles
    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1]; 0.0 on an empty sketch.
        Guaranteed within ``relative_accuracy`` of the exact stream
        quantile (for values above ``min_value``)."""
        if self.count == 0:
            return 0.0
        q = min(max(q, 0.0), 1.0)
        # nearest-rank over the ordered buckets: zero bucket first, then
        # log buckets ascending
        rank = q * (self.count - 1)
        if rank < self._zero:
            return 0.0
        seen = self._zero
        for key in sorted(self._buckets):
            seen += self._buckets[key]
            if rank < seen:
                # geometric mid-point of (gamma^(key-1), gamma^key]
                est = (2.0 * self._gamma ** key) / (1.0 + self._gamma)
                # clamp into the observed range: the bucket bound can
                # overshoot the true max by up to the relative error
                if self._max is not None:
                    est = min(est, self._max)
                if self._min is not None:
                    est = max(est, self._min)
                return est
        return self._max if self._max is not None else 0.0

    def p50(self) -> float:
        return self.quantile(0.50)

    def p95(self) -> float:
        return self.quantile(0.95)

    def p99(self) -> float:
        return self.quantile(0.99)

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def max(self) -> float:
        return self._max if self._max is not None else 0.0

    def min(self) -> float:
        return self._min if self._min is not None else 0.0

    def summary(self) -> dict:
        """The per-kind digest shape the trace summary already uses."""
        return {
            "count": self.count,
            "mean_s": self.mean(),
            "p50_s": self.p50(),
            "p95_s": self.p95(),
            "p99_s": self.p99(),
            "max_s": self.max(),
        }

    # ------------------------------------------------------------ wire form
    def to_wire(self) -> dict:
        """Compact JSON-able form: contiguous runs of bucket counts are the
        common case (latency streams are unimodal), so ship
        ``[start_key, [counts...]]`` runs instead of a key->count map."""
        runs: list[list] = []
        cur_start: Optional[int] = None
        cur: list[int] = []
        for key in sorted(self._buckets):
            if cur_start is not None and key == cur_start + len(cur):
                cur.append(self._buckets[key])
            else:
                if cur:
                    runs.append([cur_start, cur])
                cur_start, cur = key, [self._buckets[key]]
        if cur:
            runs.append([cur_start, cur])
        return {
            "schema": WIRE_SCHEMA,
            "ra": self.relative_accuracy,
            "min_value": self.min_value,
            "zero": self._zero,
            "count": self.count,
            "sum": self.sum,
            "min": self._min,
            "max": self._max,
            "runs": runs,
        }

    @classmethod
    def from_wire(cls, wire: dict) -> "LogSketch":
        if wire.get("schema") != WIRE_SCHEMA:
            raise ValueError(f"not a {WIRE_SCHEMA} wire form: "
                             f"{wire.get('schema')!r}")
        out = cls(wire["ra"], min_value=wire["min_value"])
        out._zero = int(wire["zero"])
        out.count = int(wire["count"])
        out.sum = float(wire["sum"])
        out._min = wire["min"]
        out._max = wire["max"]
        for start, counts in wire["runs"]:
            for i, n in enumerate(counts):
                out._buckets[start + i] = int(n)
        return out

    def __repr__(self) -> str:
        return (f"LogSketch(n={self.count}, bins={len(self._buckets)}, "
                f"p50={self.p50():.4g}, p99={self.p99():.4g})")

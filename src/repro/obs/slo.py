"""SLO specs and multi-window burn-rate alerting over latency streams.

EWMAs and even tail percentiles answer "how slow is it *now*"; an operator
pages on a different question — "at the current error rate, how fast is
this window burning through the SLO's error budget?" (the SRE-workbook
multi-window multi-burn-rate discipline). This module evaluates exactly
that, per pipeline:

* an :class:`SLOSpec` declares the objective: a latency metric ("ttft" /
  "decode" / any stream name), a per-request threshold (a request slower
  than ``threshold_s`` is *bad*), and a target good fraction
  (``objective``, e.g. 0.99 -> 1% error budget);
* an :class:`SLOTracker` buckets good/bad counts on a coarse time grid
  (bounded ring — O(windows/bucket) state regardless of traffic), computes
  ``burn_rate(window) = bad_fraction(window) / error_budget``, and holds
  the alert state machine: an alert **fires** when the burn rate exceeds
  ``burn_threshold`` in BOTH the long window and the short window (the
  short window gates stale alerts: once the regression stops, the short
  window recovers first and the alert clears without waiting out the long
  window), and **clears** when the short window drops back under;
* an :class:`SLOMonitor` owns the trackers for one pipeline, fans one
  observed latency into every spec on that metric, and renders the
  ``slo`` Prometheus group. Alert transitions are returned as structured
  events so the caller (ElasticController) can put them in the flight
  recorder next to the scale decisions they should explain.

All evaluation takes an explicit ``now`` so tests and replay benches run
on virtual time; live callers pass ``time.monotonic()``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

__all__ = ["SLOSpec", "BurnRatePolicy", "SLOTracker", "SLOMonitor",
           "DEFAULT_BURN_POLICIES"]


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """One latency objective: requests under ``threshold_s`` are good;
    ``objective`` of them must be (error budget = 1 - objective)."""

    name: str                    # e.g. "ttft_p99"
    metric: str                  # latency stream: "ttft" | "decode" | ...
    threshold_s: float           # per-request good/bad cut
    objective: float = 0.99      # target good fraction

    def __post_init__(self) -> None:
        if not 0.0 < self.objective < 1.0:
            raise ValueError(f"objective must be in (0, 1): "
                             f"{self.objective}")

    @property
    def error_budget(self) -> float:
        return 1.0 - self.objective


@dataclasses.dataclass(frozen=True)
class BurnRatePolicy:
    """One multi-window burn rule: fire when burn >= ``burn_threshold`` in
    both windows. ``severity`` labels the emitted events ("page"/"ticket").
    """

    long_window_s: float
    short_window_s: float
    burn_threshold: float
    severity: str = "page"


#: the classic SRE-workbook pairing, time-compressed for serving loops
#: (production would use 1h/5m and 6h/30m): a fast-burn page and a
#: slow-burn ticket
DEFAULT_BURN_POLICIES = (
    BurnRatePolicy(long_window_s=60.0, short_window_s=5.0,
                   burn_threshold=14.4, severity="page"),
    BurnRatePolicy(long_window_s=300.0, short_window_s=30.0,
                   burn_threshold=6.0, severity="ticket"),
)


class _WindowCounts:
    """Good/bad counts on a coarse time grid: a bounded ring of
    ``(bucket_index, good, bad)`` triples covering the longest window.
    O(1) observe, O(buckets) window query — buckets, not requests."""

    def __init__(self, horizon_s: float, bucket_s: float) -> None:
        self.bucket_s = bucket_s
        self.n_buckets = max(2, int(math.ceil(horizon_s / bucket_s)) + 1)
        self._idx = [0] * self.n_buckets      # absolute bucket index
        self._good = [0] * self.n_buckets
        self._bad = [0] * self.n_buckets

    def observe(self, now: float, good: bool, n: int = 1) -> None:
        b = int(now / self.bucket_s)
        slot = b % self.n_buckets
        if self._idx[slot] != b:
            self._idx[slot] = b
            self._good[slot] = 0
            self._bad[slot] = 0
        if good:
            self._good[slot] += n
        else:
            self._bad[slot] += n

    def window(self, now: float, window_s: float) -> tuple[int, int]:
        """(good, bad) over the trailing ``window_s``."""
        b_now = int(now / self.bucket_s)
        b_min = int((now - window_s) / self.bucket_s)
        good = bad = 0
        for slot in range(self.n_buckets):
            b = self._idx[slot]
            if b_min < b <= b_now:
                good += self._good[slot]
                bad += self._bad[slot]
        return good, bad


class SLOTracker:
    """Burn-rate evaluation + alert state machine for one spec."""

    def __init__(self, spec: SLOSpec,
                 policies: tuple[BurnRatePolicy, ...] = DEFAULT_BURN_POLICIES,
                 *, bucket_s: Optional[float] = None) -> None:
        self.spec = spec
        self.policies = tuple(policies)
        horizon = max(p.long_window_s for p in self.policies)
        if bucket_s is None:
            # resolve the shortest window into >= 4 buckets
            bucket_s = max(min(p.short_window_s
                               for p in self.policies) / 4.0, 1e-3)
        self._counts = _WindowCounts(horizon, bucket_s)
        self.good_total = 0
        self.bad_total = 0
        #: firing state per policy index
        self._firing = [False] * len(self.policies)
        self.alerts_fired = 0
        self.alerts_cleared = 0

    # ------------------------------------------------------------- observe
    def observe(self, value_s: float, now: float) -> None:
        good = value_s <= self.spec.threshold_s
        if good:
            self.good_total += 1
        else:
            self.bad_total += 1
        self._counts.observe(now, good)

    # ------------------------------------------------------------ evaluate
    def burn_rate(self, window_s: float, now: float) -> float:
        """bad_fraction(window) / error_budget; 0.0 on an empty window.
        Burn 1.0 = exactly consuming the budget over the SLO period;
        14.4 = the classic "2% of a 30-day budget in one hour" page."""
        good, bad = self._counts.window(now, window_s)
        total = good + bad
        if total == 0:
            return 0.0
        return (bad / total) / self.spec.error_budget

    def firing(self) -> bool:
        return any(self._firing)

    def evaluate(self, now: float) -> list[dict]:
        """Advance the alert state machine; returns one structured event
        per transition (kind ``slo_alert`` on fire, ``slo_clear`` on
        clear) — the caller records them in its flight recorder."""
        events: list[dict] = []
        for i, pol in enumerate(self.policies):
            long_burn = self.burn_rate(pol.long_window_s, now)
            short_burn = self.burn_rate(pol.short_window_s, now)
            if not self._firing[i]:
                if (long_burn >= pol.burn_threshold
                        and short_burn >= pol.burn_threshold):
                    self._firing[i] = True
                    self.alerts_fired += 1
                    events.append({
                        "kind": "slo_alert", "slo": self.spec.name,
                        "metric": self.spec.metric,
                        "severity": pol.severity,
                        "burn_long": long_burn, "burn_short": short_burn,
                        "threshold": pol.burn_threshold,
                        "window_s": pol.long_window_s,
                    })
            else:
                # the short window recovering is the all-clear: the long
                # window still carries the incident's debris, but no new
                # budget is burning
                if short_burn < pol.burn_threshold:
                    self._firing[i] = False
                    self.alerts_cleared += 1
                    events.append({
                        "kind": "slo_clear", "slo": self.spec.name,
                        "metric": self.spec.metric,
                        "severity": pol.severity,
                        "burn_long": long_burn, "burn_short": short_burn,
                        "threshold": pol.burn_threshold,
                        "window_s": pol.long_window_s,
                    })
        return events


class SLOMonitor:
    """Per-pipeline SLO evaluation: trackers keyed by spec name, one
    observation fan-out per metric stream, one Prometheus group out."""

    def __init__(self, specs: tuple[SLOSpec, ...] = (), *,
                 pipeline: str = "pipe",
                 policies: tuple[BurnRatePolicy, ...] = DEFAULT_BURN_POLICIES,
                 bucket_s: Optional[float] = None) -> None:
        self.pipeline = pipeline
        self.policies = tuple(policies)
        self._bucket_s = bucket_s
        self.trackers: dict[str, SLOTracker] = {}
        for spec in specs:
            self.add_spec(spec)

    def add_spec(self, spec: SLOSpec) -> SLOTracker:
        if spec.name in self.trackers:
            raise ValueError(f"duplicate SLO spec {spec.name!r}")
        tr = SLOTracker(spec, self.policies, bucket_s=self._bucket_s)
        self.trackers[spec.name] = tr
        return tr

    def observe(self, metric: str, value_s: float, now: float) -> None:
        for tr in self.trackers.values():
            if tr.spec.metric == metric:
                tr.observe(value_s, now)

    def evaluate(self, now: float) -> list[dict]:
        events: list[dict] = []
        for tr in self.trackers.values():
            events.extend(tr.evaluate(now))
        return events

    def firing(self) -> list[str]:
        return [name for name, tr in self.trackers.items() if tr.firing()]

    def metrics(self, now: float) -> dict:
        """The ``slo`` Prometheus group: per-spec burn rates (labelled by
        window), firing state, and cumulative good/bad counts."""
        out: dict = {}
        for name, tr in self.trackers.items():
            pol = tr.policies[0]
            out[f"{name}_burn_long"] = tr.burn_rate(pol.long_window_s, now)
            out[f"{name}_burn_short"] = tr.burn_rate(pol.short_window_s, now)
            out[f"{name}_firing"] = int(tr.firing())
            out[f"{name}_good_total"] = tr.good_total
            out[f"{name}_bad_total"] = tr.bad_total
            out[f"{name}_alerts_fired_total"] = tr.alerts_fired
        return out

"""MultiWorld core: elastic, fault-tolerant collective communication.

JAX reproduction of *Enabling Elastic Model Serving with MultiWorld*
(Lee, Jajoo, Kompella — Cisco Research, 2024).
"""
from .cluster import Cluster, Placement, Topology, Worker
from .communicator import REDUCE_OPS, WorldCommunicator
from .fault import (
    FailureKind,
    FaultInjector,
    MultiWorldError,
    RemoteError,
    RendezvousTimeout,
    WorldBrokenError,
    WorldNotFoundError,
)
from .online import OnlineInstantiator, WorldSpec
from .store import Store
from .transport import (
    Codec,
    CopyCodec,
    IPCCodec,
    PlacementCost,
    SerializeCodec,
    Transport,
)
from .watchdog import Watchdog
from .world import World, WorldStatus
from .world_manager import WorldManager

__all__ = [
    "Cluster", "Placement", "Topology", "Worker",
    "WorldCommunicator", "REDUCE_OPS",
    "FailureKind", "FaultInjector", "MultiWorldError", "RemoteError",
    "RendezvousTimeout", "WorldBrokenError", "WorldNotFoundError",
    "OnlineInstantiator", "WorldSpec", "Store",
    "Codec", "CopyCodec", "IPCCodec", "PlacementCost", "SerializeCodec",
    "Transport",
    "Watchdog", "World", "WorldStatus", "WorldManager",
]

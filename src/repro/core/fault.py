"""Fault model for MultiWorld.

The paper distinguishes two failure surfaces (§3.2 "Reliable fault detection"):

* host-to-host NCCL failures raise ``ncclRemoteError`` -> we model this as a
  :class:`RemoteError` raised synchronously out of a transport operation, and
* intra-host shared-memory failures that hang silently -> we model this as a
  worker that simply stops producing heartbeats/messages; only the watchdog
  can detect it.

``FaultInjector`` produces both kinds on demand so tests and benchmarks can
reproduce the paper's Fig. 4 scenario deterministically.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable


class MultiWorldError(Exception):
    """Base class for all MultiWorld errors."""


class WorldBrokenError(MultiWorldError):
    """A collective op was aborted because its world was fenced as broken.

    Analogue of the exception the WorldManager raises into pending collective
    operations after the watchdog flags a world (paper §3.3, World Manager).
    """

    def __init__(self, world: str, reason: str = ""):
        self.world = world
        self.reason = reason
        super().__init__(f"world '{world}' is broken{': ' + reason if reason else ''}")


class RemoteError(MultiWorldError):
    """Analogue of ``ncclRemoteError``: the remote end died mid-operation."""

    def __init__(self, world: str, rank: int):
        self.world = world
        self.rank = rank
        super().__init__(f"remote rank {rank} in world '{world}' failed")


class WorldNotFoundError(MultiWorldError):
    def __init__(self, world: str):
        self.world = world
        super().__init__(f"world '{world}' does not exist (or was removed)")


class RendezvousTimeout(MultiWorldError):
    def __init__(self, world: str, have: int, want: int):
        self.world = world
        super().__init__(
            f"rendezvous for world '{world}' timed out: {have}/{want} ranks arrived"
        )


class FailureKind(enum.Enum):
    #: Worker process dies; peers on the OS-networking path observe an error
    #: on their next transport op (``ncclRemoteError`` analogue).
    CRASH_DETECTABLE = "crash_detectable"
    #: Worker wedges silently (the NCCL shared-memory case): no error is ever
    #: raised on the data path; only heartbeat loss reveals it.
    SILENT_HANG = "silent_hang"


@dataclasses.dataclass
class FailureEvent:
    worker_id: str
    kind: FailureKind
    at_time: float


class FaultInjector:
    """Kills workers in controlled ways.

    Tests/benchmarks register the cluster's kill hooks; ``kill`` fires them.
    """

    def __init__(self) -> None:
        self._kill_hooks: list[Callable[[str, FailureKind], None]] = []
        self.events: list[FailureEvent] = []

    def register(self, hook: Callable[[str, FailureKind], None]) -> None:
        self._kill_hooks.append(hook)

    def kill(self, worker_id: str, kind: FailureKind = FailureKind.SILENT_HANG,
             at_time: float = 0.0) -> None:
        self.events.append(FailureEvent(worker_id, kind, at_time))
        for hook in self._kill_hooks:
            hook(worker_id, kind)

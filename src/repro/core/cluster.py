"""Cluster: the in-process stand-in for a multi-host deployment.

Owns the shared Store (control plane), the Transport (data plane), the fault
injector, and the per-worker WorldManagers. Tests, benchmarks and examples
create one Cluster per scenario; on real hardware the same roles are played
by an actual TCPStore endpoint + ICI/NCCL, and workers are real processes.

Topology: every worker carries a :class:`Placement` (host + NUMA domain).
On real hardware a same-host edge is shared memory / NVLink and a cross-host
edge is the datacenter network — orders of magnitude apart in cost per byte.
The :class:`Topology` labels workers so the transport's
:class:`~repro.core.transport.PlacementCost` can price every edge and the
state-moving paths (migration survivor choice, warm-bootstrap peer choice,
snapshot restore targets, heal replacement placement) can prefer cheap ones.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
from typing import Awaitable, Callable, Optional

from .fault import FailureKind, FaultInjector
from .store import Store
from .transport import Codec, PlacementCost, Transport
from .world_manager import WorldManager


@dataclasses.dataclass(frozen=True)
class Placement:
    """Where a worker runs: host label + NUMA domain within that host."""

    host: str = "host0"
    numa: int = 0


class Topology:
    """host/NUMA labels for workers, plus a policy for placing new ones.

    Workers appear dynamically (scale-up, heal), so unknown workers are
    auto-placed on first sight: ``near=`` pins a new worker to another
    worker's host (the heal path keeps a replacement on the failed
    replica's host so its state stays local); otherwise ``policy`` decides
    — ``"pack"`` fills the first host, ``"spread"`` round-robins across
    hosts. Explicit :meth:`assign` always wins and may be called before or
    after the worker exists.
    """

    def __init__(self, hosts: tuple[str, ...] = ("host0",), *,
                 numa_per_host: int = 1, policy: str = "pack") -> None:
        if not hosts:
            raise ValueError("topology needs at least one host")
        if policy not in ("pack", "spread"):
            raise ValueError(f"unknown placement policy {policy!r}")
        self.hosts = tuple(hosts)
        self.numa_per_host = max(1, numa_per_host)
        self.policy = policy
        self._placements: dict[str, Placement] = {}
        self._rr = itertools.count()
        #: per-host NUMA round-robin so packed workers still spread domains
        self._numa_rr: dict[str, itertools.count] = {}

    def assign(self, worker_id: str, host: str, numa: int = 0) -> Placement:
        p = Placement(host=host, numa=numa)
        self._placements[worker_id] = p
        return p

    def place_on(self, worker_id: str, host: str) -> Placement:
        """Pin a worker to a host while keeping the per-host NUMA
        round-robin (a bare ``assign`` would pile every pinned worker onto
        domain 0 and skew the cost model)."""
        rr = self._numa_rr.setdefault(host, itertools.count())
        return self.assign(worker_id, host, next(rr) % self.numa_per_host)

    def lookup(self, worker_id: str) -> Optional[Placement]:
        """Non-mutating read: None for unknown workers. The cost model uses
        this so pricing an edge against a retired (forgotten) worker never
        re-registers it on a default host."""
        return self._placements.get(worker_id)

    def forget(self, worker_id: str) -> None:
        """Drop a retired worker's label — worker ids are never reused, so
        keeping them would leak one entry per scale/heal cycle. Callers
        that need a successor on the retiree's host read the host *before*
        teardown and pass it explicitly."""
        self._placements.pop(worker_id, None)

    def place(self, worker_id: str, *,
              near: Optional[str] = None) -> Placement:
        """Placement of ``worker_id``, auto-assigning unknown workers."""
        p = self._placements.get(worker_id)
        if p is not None:
            return p
        if near is not None and near in self._placements:
            host = self._placements[near].host
        elif self.policy == "spread":
            host = self.hosts[next(self._rr) % len(self.hosts)]
        else:
            host = self.hosts[0]
        return self.place_on(worker_id, host)

    def placement(self, worker_id: str) -> Placement:
        return self.place(worker_id)

    def host_of(self, worker_id: str) -> str:
        return self.place(worker_id).host

    def same_host(self, a: str, b: str) -> bool:
        return self.place(a).host == self.place(b).host

    def same_numa(self, a: str, b: str) -> bool:
        pa, pb = self.place(a), self.place(b)
        return pa.host == pb.host and pa.numa == pb.numa


class Worker:
    """An async actor owning a WorldManager (one 'process' of the paper)."""

    def __init__(self, cluster: "Cluster", worker_id: str,
                 near: Optional[str] = None) -> None:
        self.cluster = cluster
        self.worker_id = worker_id
        self.placement = cluster.topology.place(worker_id, near=near)
        self.manager = WorldManager(
            worker_id, cluster.store, cluster.transport,
            heartbeat_interval=cluster.heartbeat_interval,
            heartbeat_timeout=cluster.heartbeat_timeout)
        self.comm = self.manager.communicator()
        self._tasks: list[asyncio.Task] = []
        self.alive = True

    def spawn(self, coro: Awaitable) -> asyncio.Task:
        task = asyncio.ensure_future(coro)
        self._tasks.append(task)
        return task

    def kill(self) -> None:
        """Hard-stop this worker: cancel its tasks and silence its watchdog.

        Models process death — the worker stops beating; whether peers see an
        error on the data path depends on the FailureKind given to the
        injector (transport handles that part).
        """
        self.alive = False
        self.manager.watchdog.stop()
        for t in self._tasks:
            if not t.done():
                t.cancel()

    async def drain(self) -> None:
        for t in self._tasks:
            try:
                await t
            except (asyncio.CancelledError, Exception):
                pass


class Cluster:
    def __init__(
        self,
        *,
        codec: Codec | None = None,
        heartbeat_interval: float = 0.02,
        heartbeat_timeout: float = 0.25,
        topology: Topology | None = None,
        placement_cost: PlacementCost | None = None,
    ) -> None:
        self.store = Store()
        self.topology = topology or Topology()
        self.placement = placement_cost or PlacementCost(self.topology)
        self.transport = Transport(codec=codec, placement=self.placement)
        self.injector = FaultInjector()
        self.injector.register(self._on_kill)
        self.heartbeat_interval = heartbeat_interval
        self.heartbeat_timeout = heartbeat_timeout
        self.workers: dict[str, Worker] = {}

    def worker(self, worker_id: str, *, near: Optional[str] = None) -> Worker:
        w = self.workers.get(worker_id)
        if w is None:
            w = self.workers[worker_id] = Worker(self, worker_id, near=near)
        return w

    def kill(self, worker_id: str,
             kind: FailureKind = FailureKind.SILENT_HANG) -> None:
        self.injector.kill(worker_id, kind)

    def _on_kill(self, worker_id: str, kind: FailureKind) -> None:
        self.transport.mark_dead(worker_id, kind)
        w = self.workers.get(worker_id)
        if w is not None:
            w.kill()

    def shutdown(self) -> None:
        for w in self.workers.values():
            w.kill()
            w.manager.shutdown()

"""Cluster: the in-process stand-in for a multi-host deployment.

Owns the shared Store (control plane), the Transport (data plane), the fault
injector, and the per-worker WorldManagers. Tests, benchmarks and examples
create one Cluster per scenario; on real hardware the same roles are played
by an actual TCPStore endpoint + ICI/NCCL, and workers are real processes.
"""
from __future__ import annotations

import asyncio
from typing import Awaitable, Callable, Optional

from .fault import FailureKind, FaultInjector
from .store import Store
from .transport import Codec, Transport
from .world_manager import WorldManager


class Worker:
    """An async actor owning a WorldManager (one 'process' of the paper)."""

    def __init__(self, cluster: "Cluster", worker_id: str) -> None:
        self.cluster = cluster
        self.worker_id = worker_id
        self.manager = WorldManager(
            worker_id, cluster.store, cluster.transport,
            heartbeat_interval=cluster.heartbeat_interval,
            heartbeat_timeout=cluster.heartbeat_timeout)
        self.comm = self.manager.communicator()
        self._tasks: list[asyncio.Task] = []
        self.alive = True

    def spawn(self, coro: Awaitable) -> asyncio.Task:
        task = asyncio.ensure_future(coro)
        self._tasks.append(task)
        return task

    def kill(self) -> None:
        """Hard-stop this worker: cancel its tasks and silence its watchdog.

        Models process death — the worker stops beating; whether peers see an
        error on the data path depends on the FailureKind given to the
        injector (transport handles that part).
        """
        self.alive = False
        self.manager.watchdog.stop()
        for t in self._tasks:
            if not t.done():
                t.cancel()

    async def drain(self) -> None:
        for t in self._tasks:
            try:
                await t
            except (asyncio.CancelledError, Exception):
                pass


class Cluster:
    def __init__(
        self,
        *,
        codec: Codec | None = None,
        heartbeat_interval: float = 0.02,
        heartbeat_timeout: float = 0.25,
    ) -> None:
        self.store = Store()
        self.transport = Transport(codec=codec)
        self.injector = FaultInjector()
        self.injector.register(self._on_kill)
        self.heartbeat_interval = heartbeat_interval
        self.heartbeat_timeout = heartbeat_timeout
        self.workers: dict[str, Worker] = {}

    def worker(self, worker_id: str) -> Worker:
        w = self.workers.get(worker_id)
        if w is None:
            w = self.workers[worker_id] = Worker(self, worker_id)
        return w

    def kill(self, worker_id: str,
             kind: FailureKind = FailureKind.SILENT_HANG) -> None:
        self.injector.kill(worker_id, kind)

    def _on_kill(self, worker_id: str, kind: FailureKind) -> None:
        self.transport.mark_dead(worker_id, kind)
        w = self.workers.get(worker_id)
        if w is not None:
            w.kill()

    def shutdown(self) -> None:
        for w in self.workers.values():
            w.kill()
            w.manager.shutdown()

"""Online instantiation: adding workers to a live job (paper §3.1, Fig. 2c).

"Via a controller, a new worker can be created and added back to the existing
pipeline by configuring [it] to inherit the exact role of [the failed worker]
and other workers to set up new worlds with [it]."

The paper scopes the controller itself out ("we leave it as future work") and
contributes the *functionalities* that make it possible. We implement those
functionalities — concurrent multi-party world creation that never disturbs
existing worlds — plus a minimal controller so the Fig. 2 rhombus scenario is
runnable end to end (examples/serve_pipeline.py, benchmarks/bench_online.py).
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import time
from typing import Sequence

from .cluster import Cluster


@dataclasses.dataclass(frozen=True)
class WorldSpec:
    """One world to create: name + ordered (worker_id, rank) membership."""

    name: str
    members: tuple[tuple[str, int], ...]

    @staticmethod
    def pair(name: str, a: str, b: str) -> "WorldSpec":
        """Paper default: one world per pipeline edge, ranks (0, 1)."""
        return WorldSpec(name, ((a, 0), (b, 1)))


class OnlineInstantiator:
    """Minimal controller: creates worlds among live workers concurrently.

    Every participant's ``initialize_world`` runs as its own coroutine; the
    rendezvous happens through the store exactly as at cold start — existing
    worlds keep moving traffic meanwhile (validated by bench_online.py, the
    Fig. 5 reproduction).
    """

    def __init__(self, cluster: Cluster) -> None:
        self.cluster = cluster
        self._uid = itertools.count()
        #: (t, world, join_latency_s) for Fig.5-style reporting
        self.joins: list[tuple[float, str, float]] = []

    def fresh_world_name(self, hint: str = "w") -> str:
        return f"{hint}-online-{next(self._uid)}"

    async def instantiate(self, specs: Sequence[WorldSpec],
                          timeout: float = 10.0) -> None:
        """Create all worlds in ``specs``; returns when every rendezvous is done."""
        coros = []
        for spec in specs:
            size = len(spec.members)
            for worker_id, rank in spec.members:
                mgr = self.cluster.worker(worker_id).manager
                coros.append(
                    mgr.initialize_world(spec.name, rank, size, timeout=timeout))
        t0 = time.monotonic()
        await asyncio.gather(*coros)
        dt = time.monotonic() - t0
        for spec in specs:
            self.joins.append((time.monotonic(), spec.name, dt))

    async def replace(
        self,
        failed_worker: str,
        new_worker: str,
        peers: Sequence[str],
        name_hint: str = "repl",
        timeout: float = 10.0,
    ) -> list[WorldSpec]:
        """Fig. 2c: give ``new_worker`` the failed worker's role by creating a
        fresh pairwise world with each peer. Returns the created specs so the
        application can wire its stage logic onto them."""
        specs = [
            WorldSpec.pair(self.fresh_world_name(f"{name_hint}-{peer}"),
                           peer, new_worker)
            for peer in peers
        ]
        await self.instantiate(specs, timeout=timeout)
        return specs

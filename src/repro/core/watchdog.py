"""Watchdog: out-of-band liveness monitoring (paper §3.3).

"It is a threaded daemon that checks whether worlds that a worker belongs to
are broken or not. It relies on TCPStore ... A watchdog updates the worker's
health periodically to the stores for all the worlds the worker belongs to.
If health updates are missed for a certain duration (e.g., 3 seconds), the
watchdog informs the world manager."

Here the daemon is an asyncio task co-scheduled with the worker (workers are
in-process actors); heartbeats are TTL'd keys in the :class:`~repro.core.store.Store`.
The detection path is deliberately *not* on the data plane: it is the only
mechanism that catches the silent shared-memory-style hang.
"""
from __future__ import annotations

import asyncio
import time
from typing import Callable

from .store import Store
from .world import World


class Watchdog:
    def __init__(
        self,
        worker_id: str,
        store: Store,
        *,
        interval: float = 0.02,
        timeout: float = 0.25,
        clock=time.monotonic,
    ) -> None:
        self.worker_id = worker_id
        self.store = store
        self.interval = interval
        self.timeout = timeout
        self._clock = clock
        #: world name -> (World, my rank, watch start time)
        self._watched: dict[str, tuple[World, int, float]] = {}
        self._on_broken: Callable[[str, str], None] | None = None
        self._task: asyncio.Task | None = None
        self._alive = False
        #: diagnostics: world -> detection latency (s) once detected
        self.detections: dict[str, float] = {}

    def on_broken(self, cb: Callable[[str, str], None]) -> None:
        """cb(world_name, reason) — wired to WorldManager fencing."""
        self._on_broken = cb

    # -- membership ----------------------------------------------------------
    def watch(self, world: World, my_rank: int) -> None:
        self._watched[world.name] = (world, my_rank, self._clock())
        self._beat_world(world, my_rank)  # publish liveness immediately

    def unwatch(self, world_name: str) -> None:
        self._watched.pop(world_name, None)

    # -- daemon ---------------------------------------------------------------
    def start(self) -> None:
        if self._task is None or self._task.done():
            self._alive = True
            self._task = asyncio.ensure_future(self._run())

    def stop(self) -> None:
        self._alive = False
        if self._task is not None:
            self._task.cancel()
            self._task = None

    async def _run(self) -> None:
        last_cycle = self._clock()
        try:
            while self._alive:
                now = self._clock()
                starved = now - last_cycle > self.timeout
                self.beat()
                # If the event loop was starved past the heartbeat TTL (e.g.
                # a long jit compile blocked every coroutine), peers' beats
                # may be missing for the same local reason. Skip one check
                # round so everyone re-beats first — suppresses false
                # positives without weakening real detection.
                if not starved:
                    self.check()
                last_cycle = self._clock()
                await asyncio.sleep(self.interval)
        except asyncio.CancelledError:
            pass

    # -- mechanics -------------------------------------------------------------
    def _beat_world(self, world: World, rank: int) -> None:
        self.store.set(world.heartbeat_key(rank), self._clock(), ttl=self.timeout)

    def beat(self) -> None:
        for world, rank, _start in self._watched.values():
            if world.healthy or world.status.value == "initializing":
                self._beat_world(world, rank)

    def check(self) -> None:
        now = self._clock()
        for name, (world, my_rank, start) in list(self._watched.items()):
            if not world.healthy:
                continue
            if now - start < self.timeout:
                continue  # grace period: peers may not have beaten yet
            for rank in range(world.size):
                if rank == my_rank:
                    continue
                if self.store.get(world.heartbeat_key(rank)) is None:
                    reason = f"rank {rank} missed heartbeats > {self.timeout}s"
                    self.detections[name] = now - start
                    if self._on_broken is not None:
                        self._on_broken(name, reason)
                    break

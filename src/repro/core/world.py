"""World: a named process group with its own fault domain.

The paper's central abstraction: a worker may belong to many worlds; a worker
failure breaks only the worlds it belongs to (§3.1). Each world optionally
carries a ``jax.sharding.Mesh`` over a device subset — that is the TPU
analogue of "one NCCL communicator per world": collectives issued in this
world are compiled against this mesh and never touch devices of other worlds.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional


class WorldStatus(enum.Enum):
    INITIALIZING = "initializing"
    HEALTHY = "healthy"
    BROKEN = "broken"
    REMOVED = "removed"


@dataclasses.dataclass
class World:
    name: str
    size: int
    #: rank -> worker id. Filled in as ranks rendezvous.
    members: dict[int, str] = dataclasses.field(default_factory=dict)
    status: WorldStatus = WorldStatus.INITIALIZING
    #: optional JAX mesh backing this world's on-device collectives
    mesh: Optional[Any] = None
    #: why the world broke (for diagnostics / Fig.4-style timelines)
    broken_reason: str = ""

    def rank_of(self, worker_id: str) -> Optional[int]:
        for rank, wid in self.members.items():
            if wid == worker_id:
                return rank
        return None

    @property
    def healthy(self) -> bool:
        return self.status is WorldStatus.HEALTHY

    def key_prefix(self) -> str:
        return f"world/{self.name}"

    # -- store key helpers (shared by manager + watchdog) --------------------
    def member_key(self, rank: int) -> str:
        return f"{self.key_prefix()}/members/{rank}"

    def heartbeat_key(self, rank: int) -> str:
        return f"{self.key_prefix()}/hb/{rank}"

    def config_key(self) -> str:
        return f"{self.key_prefix()}/config"

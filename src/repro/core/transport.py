"""Transport: the simulated data plane under the communicator.

On real hardware this layer is NCCL (paper) / TPU ICI transfers (our target):
``send``/``recv`` move device buffers between workers of one world. In this
CPU container, workers are in-process async actors, so the default transport
passes JAX array references zero-copy through per-(world, src, dst) channels.

Failure semantics mirror the paper's two NCCL paths (§3.2):

* ``CRASH_DETECTABLE`` (host-to-host / OS networking): any transport op that
  touches the dead peer raises :class:`RemoteError` — the ``ncclRemoteError``
  analogue, catchable by the communicator.
* ``SILENT_HANG`` (intra-host shared memory): ops involving the dead peer
  neither fail nor complete. Only the watchdog can detect this.

Codecs exist to reproduce the paper's strawmen: ``SerializeCodec`` models the
Kafka/message-bus path of Fig. 1 (full serialize + host-copy per hop) and the
MultiProcessing IPC path of Figs. 6-7.
"""
from __future__ import annotations

import pickle
import threading
from collections import deque
from typing import Any

import numpy as np

from .fault import FailureKind, RemoteError


def payload_nbytes(obj: Any) -> int:
    """Bytes moved by one payload, whatever shape it takes.

    Accepts raw arrays (``nbytes``), encoded wire buffers (``len``), objects
    exposing an ``nbytes`` property (serving envelopes), and containers of
    any of those. The old ``getattr(payload, "nbytes", 0)`` recorded 0 for
    every pipeline payload — tuples have no ``nbytes`` — so ``bytes_sent``
    was silently zero for all pipeline traffic.
    """
    if obj is None:
        return 0
    if isinstance(obj, (bytes, bytearray, memoryview)):
        return len(obj)
    n = getattr(obj, "nbytes", None)
    if n is not None and not callable(n):
        try:
            return int(n)
        except TypeError:
            pass
    if isinstance(obj, (tuple, list)):
        return sum(payload_nbytes(o) for o in obj)
    if isinstance(obj, dict):
        return sum(payload_nbytes(v) for v in obj.values())
    return 0


class Codec:
    """Payload transformation applied on the wire. Default: zero-copy."""

    name = "zero_copy"

    def encode(self, payload: Any) -> Any:
        return payload

    def decode(self, wire: Any) -> Any:
        return wire


class CopyCodec(Codec):
    """Wire emulation: one memcpy per hop (the cost structure of a DMA/NVLink
    transfer, without serialization). Used by the Fig. 6/7 benchmarks so that
    MultiWorld bookkeeping is measured against a *real* per-byte transfer
    cost on both sides — zero-copy reference passing would make any
    bookkeeping look infinitely expensive.

    The wire buffer is persistent per (shape, dtype) — a DMA engine writes
    into a fixed remote buffer; reallocating 4 MB per message would measure
    the host allocator, not the transport."""

    name = "copy"

    def __init__(self) -> None:
        self._bufs: dict = {}

    def encode(self, payload: Any) -> Any:
        src = np.asarray(payload)
        key = (src.shape, src.dtype.str)
        buf = self._bufs.get(key)
        if buf is None:
            buf = self._bufs[key] = np.empty_like(src)
        np.copyto(buf, src)
        return buf

    def decode(self, wire: Any) -> Any:
        return wire


class SerializeCodec(Codec):
    """Message-bus strawman: device->host copy + serialize, then the reverse.

    Reproduces the overhead structure of the paper's Fig. 1 (Kafka) — "up to
    45% of the sender's time is spent copying the tensor from GPU memory to
    CPU memory and then serializing it" — as faithfully as a CPU container
    allows: a forced host materialization + pickle round-trip per hop.
    """

    name = "serialize"

    def encode(self, payload: Any) -> Any:
        host = np.asarray(payload)          # device -> host copy
        return pickle.dumps(host, protocol=pickle.HIGHEST_PROTOCOL)

    def decode(self, wire: Any) -> Any:
        import jax.numpy as jnp

        host = pickle.loads(wire)
        return jnp.asarray(host)            # host -> device copy


class IPCCodec(Codec):
    """MultiProcessing strawman (paper §4.3 "MP"): tensors traverse an extra

    process boundary via pickle + an extra intermediate copy. We add one more
    host copy than :class:`SerializeCodec` to model main-process <-> sub-process
    piping on top of serialization.
    """

    name = "ipc"

    def encode(self, payload: Any) -> Any:
        host = np.asarray(payload)
        staged = np.copy(host)              # IPC staging buffer copy
        return pickle.dumps(staged, protocol=pickle.HIGHEST_PROTOCOL)

    def decode(self, wire: Any) -> Any:
        import jax.numpy as jnp

        host = pickle.loads(wire)
        staged = np.copy(host)
        return jnp.asarray(staged)


class PlacementCost:
    """Per-edge transport cost model over a cluster topology.

    Relative cost per byte of moving data between two workers: same NUMA
    domain is cheapest (shared memory), same host is cheap (NVLink / ICI),
    cross-host is expensive (datacenter network). The absolute numbers are
    unitless ratios — what matters to every placement decision is the
    *ordering* and the rough magnitude gap, mirroring the transport-cost
    modeling that topology-aware collectives use at scale.

    :meth:`score` folds the cost of an impending transfer into a queue-load
    scalar so survivor/peer choice can rank candidates by
    ``(queue depth, placement cost of the bytes about to move)`` as one
    number: ``bytes_per_load`` says how many same-host-cost bytes weigh as
    much as one queued request.
    """

    def __init__(self, topology=None, *, same_numa: float = 0.2,
                 same_host: float = 1.0, cross_host: float = 8.0,
                 bytes_per_load: int = 256 * 1024) -> None:
        self.topology = topology
        self.same_numa = same_numa
        self.same_host = same_host
        self.cross_host = cross_host
        self.bytes_per_load = bytes_per_load

    def edge_cost(self, src_worker: str | None,
                  dst_worker: str | None) -> float:
        """Relative cost/byte of the (src, dst) edge; same-host when either
        endpoint is unknown or retired (the neutral default — a read-only
        lookup, so pricing an edge against a forgotten worker never
        re-registers it on a default host)."""
        if self.topology is None or src_worker is None or dst_worker is None:
            return self.same_host
        a = self.topology.lookup(src_worker)
        b = self.topology.lookup(dst_worker)
        if a is None or b is None:
            return self.same_host
        if a.host != b.host:
            return self.cross_host
        if a.numa == b.numa:
            return self.same_numa
        return self.same_host

    def is_cross_host(self, src_worker: str | None,
                      dst_worker: str | None) -> bool:
        if self.topology is None or src_worker is None or dst_worker is None:
            return False
        a = self.topology.lookup(src_worker)
        b = self.topology.lookup(dst_worker)
        return a is not None and b is not None and a.host != b.host

    def transfer_load(self, src_worker: str | None, dst_worker: str | None,
                      nbytes: int) -> float:
        """Queue-load equivalent of moving ``nbytes`` over the (src, dst)
        edge: cost ratio x bytes, normalized by ``bytes_per_load``."""
        return (self.edge_cost(src_worker, dst_worker) * nbytes
                / max(1, self.bytes_per_load))

    def score(self, load: float, src_worker: str | None,
              dst_worker: str | None, nbytes: int) -> float:
        """Rank key for a transfer target: queue load + placement cost of
        the bytes about to move. Lower is better."""
        return load + self.transfer_load(src_worker, dst_worker, nbytes)


class _Channel:
    """SPSC queue. deque.append/popleft are GIL-atomic, so the hot path is
    lock-free; only channel-map mutation takes the transport lock."""

    __slots__ = ("buf",)

    def __init__(self) -> None:
        self.buf: deque = deque()


class Transport:
    def __init__(self, codec: Codec | None = None,
                 placement: PlacementCost | None = None) -> None:
        self.codec = codec or Codec()
        #: edge cost model (None -> every edge priced as same-host)
        self.placement = placement
        self._channels: dict[tuple[str, int, int], _Channel] = {}
        self._lock = threading.Lock()
        #: worker_id -> FailureKind for dead workers
        self._dead: dict[str, FailureKind] = {}
        self.bytes_sent = 0
        self.messages_sent = 0
        #: bulk-transfer slice of bytes_sent: payloads tagged ``bulk=True``
        #: (snapshot/weight chunks) — lets dashboards separate state-transfer
        #: traffic from serving traffic on the same wires
        self.bulk_bytes_sent = 0
        self.bulk_messages_sent = 0
        # -- placement-cost accounting (bytes x edge cost; MetricsHub
        #    surfaces these so dashboards can see what elasticity events
        #    actually cost in topology terms) -----------------------------
        self.cost_weighted_bytes = 0.0
        self.cross_host_bytes_sent = 0
        self.cross_host_messages_sent = 0
        self.bulk_cross_host_bytes_sent = 0
        self.bulk_cost_weighted_bytes = 0.0
        #: messages discarded with their world (fencing/teardown) — the
        #: at-least-once resend path re-covers them; the counter makes the
        #: loss observable instead of silent
        self.messages_dropped = 0

    # -- fault hooks ---------------------------------------------------------
    def mark_dead(self, worker_id: str, kind: FailureKind) -> None:
        with self._lock:
            self._dead[worker_id] = kind

    def forget_dead(self, worker_id: str) -> None:
        """Reclaim the death record of a fully torn-down worker: its worlds
        and channels are gone, so nothing can consult the entry again —
        keeping it would grow the map by one worker per heal forever."""
        with self._lock:
            self._dead.pop(worker_id, None)

    def is_dead(self, worker_id: str) -> FailureKind | None:
        return self._dead.get(worker_id)

    def detectably_dead(self, worker_id: str) -> bool:
        return self._dead.get(worker_id) is FailureKind.CRASH_DETECTABLE

    # -- channels -------------------------------------------------------------
    def _channel(self, world: str, src: int, dst: int) -> _Channel:
        key = (world, src, dst)
        ch = self._channels.get(key)          # GIL-atomic read
        if ch is not None:
            return ch
        with self._lock:
            ch = self._channels.get(key)
            if ch is None:
                ch = self._channels[key] = _Channel()
            return ch

    def send(self, world: str, src: int, dst: int, payload: Any,
             dst_worker: str | None = None,
             src_worker: str | None = None) -> None:
        """Post one message. Raises RemoteError iff dst is detectably dead."""
        if self._dead and dst_worker is not None \
                and self._dead.get(dst_worker) is FailureKind.CRASH_DETECTABLE:
            raise RemoteError(world, dst)
        wire = self.codec.encode(payload)
        self._channel(world, src, dst).buf.append(wire)
        self.messages_sent += 1
        # count what actually crosses the wire: the encoded size under a
        # serializing codec (pickle bytes), the leaf-tensor bytes otherwise
        nbytes = payload_nbytes(wire)
        self.bytes_sent += nbytes
        bulk = getattr(payload, "bulk", False)
        if bulk:
            self.bulk_bytes_sent += nbytes
            self.bulk_messages_sent += 1
        if self.placement is not None:
            weighted = nbytes * self.placement.edge_cost(src_worker,
                                                         dst_worker)
            self.cost_weighted_bytes += weighted
            if self.placement.is_cross_host(src_worker, dst_worker):
                self.cross_host_bytes_sent += nbytes
                self.cross_host_messages_sent += 1
                if bulk:
                    self.bulk_cross_host_bytes_sent += nbytes
            if bulk:
                self.bulk_cost_weighted_bytes += weighted

    def recv_nowait(self, world: str, src: int, dst: int,
                    src_worker: str | None = None) -> tuple[bool, Any]:
        """Non-blocking receive: (True, payload) or (False, None).

        Raises RemoteError iff src is *detectably* dead and no data is
        buffered (a silently-hung peer just returns (False, None) forever —
        that is the shared-memory hang the watchdog exists for).
        """
        buf = self._channel(world, src, dst).buf
        if buf:
            return True, self.codec.decode(buf.popleft())
        if src_worker is not None and self.detectably_dead(src_worker):
            raise RemoteError(world, src)
        return False, None

    def pending(self, world: str) -> int:
        """Messages buffered across all channels of one world. The drain path
        of scale-down polls this to guarantee no payload is dropped between
        an upstream send and the downstream pump."""
        with self._lock:
            return sum(len(ch.buf) for (w, _s, _d), ch in
                       self._channels.items() if w == world)

    def pending_bytes(self, world: str) -> int:
        """Bytes buffered across all channels of one world. Bulk senders
        (snapshot/weight streaming) poll this for backpressure: pause when
        the receiver has fallen more than a window behind, instead of
        dumping a whole KV cache into the channel in one burst."""
        with self._lock:
            return sum(payload_nbytes(wire)
                       for (w, _s, _d), ch in self._channels.items()
                       if w == world for wire in ch.buf)

    def drop_world(self, world: str) -> int:
        """Discard all channels of a removed/broken world. Returns #messages dropped."""
        dropped = 0
        with self._lock:
            for key in [k for k in self._channels if k[0] == world]:
                dropped += len(self._channels[key].buf)
                del self._channels[key]
            self.messages_dropped += dropped
        return dropped

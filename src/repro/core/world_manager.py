"""WorldManager: initialization, termination and fencing of worlds (paper §3.3).

One manager per worker, mirroring the paper's per-process architecture
(Fig. 3). Provides the paper's three functions — ``initialize_world``,
``remove_world`` and ``communicator`` — plus the fencing path: "If the
watchdog alerts a world's failure, the manager prevents the broken world
being accessed by the world communicator. It then helps the communicator
abort any pending collective operation and raise an exception so that an
inference application can handle it."
"""
from __future__ import annotations

import asyncio
import time
from typing import Callable, Optional

from .communicator import WorldCommunicator
from .fault import RendezvousTimeout
from .store import Store
from .transport import Transport
from .watchdog import Watchdog
from .world import World, WorldStatus


class WorldManager:
    def __init__(
        self,
        worker_id: str,
        store: Store,
        transport: Transport,
        *,
        heartbeat_interval: float = 0.02,
        heartbeat_timeout: float = 0.25,
    ) -> None:
        self.worker_id = worker_id
        self.store = store
        self.transport = transport
        self.worlds: dict[str, World] = {}
        self.watchdog = Watchdog(
            worker_id, store, interval=heartbeat_interval, timeout=heartbeat_timeout)
        self.watchdog.on_broken(self.report_broken)
        self._communicator = WorldCommunicator(self)
        #: app-level callbacks fired on world break (world_name, reason)
        self._break_listeners: list[Callable[[str, str], None]] = []
        #: timeline of (t, event, world) for Fig.4/5-style reporting
        self.events: list[tuple[float, str, str]] = []
        #: structured subscribers fired on every event: cb(t, kind, world).
        #: The elastic control plane's MetricsHub subscribes here instead of
        #: re-scanning ``events`` each poll.
        self._event_listeners: list[Callable[[float, str, str], None]] = []

    # ---------------------------------------------------------------- paper API
    def communicator(self) -> WorldCommunicator:
        return self._communicator

    async def initialize_world(
        self,
        name: str,
        rank: int,
        size: int,
        *,
        timeout: float = 10.0,
        poll: float = 0.002,
        mesh=None,
    ) -> World:
        """Rendezvous-create a world; non-blocking w.r.t. other worlds.

        The paper runs blocking NCCL init on a separate thread so that
        traffic on existing worlds continues (§4.2, Fig. 5). The asyncio
        analogue is a coroutine that polls the store and yields — other
        worlds' ops interleave freely while this world waits for peers.
        """
        world = self.worlds.get(name)
        if world is None or world.status in (WorldStatus.REMOVED, WorldStatus.BROKEN):
            world = World(name=name, size=size, mesh=mesh)
            self.worlds[name] = world
        self.store.set(world.config_key(), {"size": size})
        self.store.set(world.member_key(rank), self.worker_id)
        self._event("init_begin", name)

        deadline = time.monotonic() + timeout
        member_keys = [world.member_key(r) for r in range(size)]
        while True:
            present = [k for k in member_keys if self.store.get(k) is not None]
            if len(present) == size:
                break
            if time.monotonic() > deadline:
                raise RendezvousTimeout(name, len(present), size)
            await asyncio.sleep(poll)

        for r in range(size):
            world.members[r] = self.store.get(world.member_key(r))
        world.status = WorldStatus.HEALTHY
        self.watchdog.watch(world, rank)
        self.watchdog.start()
        self._event("init_done", name)
        return world

    def initialize_world_blocking(self, name: str, rank: int, size: int,
                                  **kw) -> World:
        """Thread-style blocking variant (for callers not on the event loop)."""
        loop = asyncio.new_event_loop()
        try:
            return loop.run_until_complete(
                self.initialize_world(name, rank, size, **kw))
        finally:
            loop.close()

    def remove_world(self, name: str) -> None:
        """Graceful teardown of one world; other worlds are untouched.

        Store hygiene: besides its own member/heartbeat keys, the last member
        out also deletes the world's ``config`` key and any stale peer keys —
        without this a long-lived elastic cluster leaks one key set per
        retired world. A *broken* world is purged outright: its dead peer can
        never delete its own keys, and every live member has already fenced
        (or will, once our heartbeat key vanishes).
        """
        world = self.worlds.get(name)
        if world is None:
            return
        was_broken = world.status is WorldStatus.BROKEN
        rank = world.rank_of(self.worker_id)
        world.status = WorldStatus.REMOVED
        self.watchdog.unwatch(name)
        if rank is not None:
            self.store.delete(world.member_key(rank))
            self.store.delete(world.heartbeat_key(rank))
        # note the trailing "/": world "x" must not purge sibling "x2"
        remaining = self.store.keys(f"{world.key_prefix()}/members/")
        if was_broken or not remaining:
            for key in self.store.keys(f"{world.key_prefix()}/"):
                self.store.delete(key)
        self.transport.drop_world(name)
        self._event("removed", name)

    # ------------------------------------------------------------------ fencing
    def report_broken(self, name: str, reason: str) -> None:
        """Fence a broken world: pending communicator ops abort on their next
        poll; the world becomes inaccessible; channels are dropped."""
        world = self.worlds.get(name)
        if world is None or world.status is not WorldStatus.HEALTHY:
            return
        world.status = WorldStatus.BROKEN
        world.broken_reason = reason
        self.watchdog.unwatch(name)
        self.transport.drop_world(name)
        self._event("broken", name)
        for cb in self._break_listeners:
            cb(name, reason)

    def on_world_broken(self, cb: Callable[[str, str], None]) -> None:
        self._break_listeners.append(cb)

    def on_event(self, cb: Callable[[float, str, str], None]) -> None:
        """Subscribe to the structured event stream: cb(t, kind, world) for
        every init_begin/init_done/broken/removed transition."""
        self._event_listeners.append(cb)

    # ------------------------------------------------------------------- misc
    def healthy_worlds(self) -> list[str]:
        return [n for n, w in self.worlds.items() if w.healthy]

    def shutdown(self) -> None:
        self.watchdog.stop()
        for name in list(self.worlds):
            self.remove_world(name)

    def _event(self, kind: str, world: str) -> None:
        t = time.monotonic()
        self.events.append((t, kind, world))
        # an elastic cluster churns worlds for the process lifetime; readers
        # (plots, subscribers) only ever need the recent window
        if len(self.events) > 8192:
            del self.events[:4096]
        for cb in self._event_listeners:
            cb(t, kind, world)

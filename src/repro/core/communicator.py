"""WorldCommunicator: async, fault-tolerant collective operations (paper §3.3).

Supports the paper's 8 collective operations — ``send``, ``recv``,
``broadcast``, ``all_reduce``, ``reduce``, ``all_gather``, ``gather``,
``scatter`` — each taking the world name as an argument (the paper's
backward-compatible API: "including a world name as a function argument
suffices").

Non-blocking execution model: every op is a coroutine driven by busy-wait
polling with an explicit scheduler yield per poll (``await asyncio.sleep(0)``)
— the paper's "we mitigate the throughput loss of polling via busy waiting,
but at the same time we make sure that other tasks can be scheduled
immediately if the operation is pending". This is what prevents the rhombus
deadlock of Fig. 2: a pending ``recv`` from P2 never blocks a ``recv`` from P3.

Fault semantics: every poll iteration re-checks the world's status. When the
watchdog/WorldManager fences a world, all pending ops on it abort with
:class:`WorldBrokenError` on their next poll; a detectable remote crash
(``RemoteError``, the ncclRemoteError analogue) is caught, reported to the
manager (which fences the world), and surfaced as ``WorldBrokenError`` too.

Ordering contract (same as NCCL): all ranks of a world must issue collectives
in the same order; point-to-point ops between a (src, dst) pair are FIFO.
"""
from __future__ import annotations

import asyncio
import time
from typing import Any, Callable, Sequence

import jax.numpy as jnp

from .fault import RemoteError, WorldBrokenError, WorldNotFoundError
from .world import World, WorldStatus

ReduceFn = Callable[[Any, Any], Any]

REDUCE_OPS: dict[str, ReduceFn] = {
    "sum": lambda a, b: a + b,
    "prod": lambda a, b: a * b,
    "max": jnp.maximum,
    "min": jnp.minimum,
}


class WorldCommunicator:
    def __init__(self, manager) -> None:
        self._manager = manager
        self.worker_id = manager.worker_id
        #: world -> number of in-flight ops (introspection; the manager's
        #: abort path is status-based, so no future plumbing is needed)
        self.pending: dict[str, int] = {}
        self.ops_completed = 0
        self.ops_aborted = 0
        self._ops_since_yield = 0
        self._rank_cache: dict[str, tuple[World, int]] = {}

    #: fairness: an op that completes without ever pending still yields to
    #: the scheduler every N ops, so a tight send/recv loop cannot starve
    #: watchdog heartbeats and timers on the shared event loop
    FAIRNESS_EVERY = 64

    # ------------------------------------------------------------------ utils
    def _world(self, name: str) -> tuple[World, int]:
        """Resolve (world, my rank); hot path — memoized per world object.

        The cache is keyed on the World instance so re-initialized worlds
        (new object under the same name) re-resolve, and status is *always*
        re-checked by the caller's poll loop, never cached.
        """
        world = self._manager.worlds.get(name)
        if world is None or world.status is WorldStatus.REMOVED:
            self._rank_cache.pop(name, None)
            # removed worlds never see another op: drop their pending counter
            # too, or every scale/heal cycle leaks one dict entry per world
            self.pending.pop(name, None)
            raise WorldNotFoundError(name)
        cached = self._rank_cache.get(name)
        if cached is not None and cached[0] is world:
            return world, cached[1]
        rank = world.rank_of(self.worker_id)
        if rank is None:
            raise WorldNotFoundError(f"{name} (worker {self.worker_id} not a member)")
        self._rank_cache[name] = (world, rank)
        return world, rank

    def _check_broken(self, world: World) -> None:
        if world.status is WorldStatus.BROKEN:
            raise WorldBrokenError(world.name, world.broken_reason)
        if world.status is WorldStatus.REMOVED:
            raise WorldNotFoundError(world.name)

    def _attempt(self, world: World, fn: Callable[[], tuple[bool, Any]]
                 ) -> tuple[bool, Any]:
        try:
            return fn()
        except RemoteError as e:
            # ncclRemoteError path: report, fence, abort (paper §3.2)
            self._manager.report_broken(world.name, str(e))
            raise WorldBrokenError(world.name, str(e)) from e

    async def _finish(self, value: Any) -> Any:
        self.ops_completed += 1
        self._ops_since_yield += 1
        if self._ops_since_yield >= self.FAIRNESS_EVERY:
            self._ops_since_yield = 0
            await asyncio.sleep(0)
        return value

    async def _poll(self, world: World, fn: Callable[[], tuple[bool, Any]],
                    timeout: float | None) -> Any:
        """Busy-wait poll ``fn`` until it reports done, aborting if the world
        breaks. One scheduler yield per pending iteration."""
        try:
            # fast path: most ops complete on the first attempt — skip all
            # pending bookkeeping and deadline setup
            self._check_broken(world)
            done, value = self._attempt(world, fn)
            if done:
                return await self._finish(value)

            self.pending[world.name] = self.pending.get(world.name, 0) + 1
            deadline = None if timeout is None else time.monotonic() + timeout
            try:
                while True:
                    await asyncio.sleep(0)
                    self._check_broken(world)
                    done, value = self._attempt(world, fn)
                    if done:
                        return await self._finish(value)
                    if deadline is not None and time.monotonic() > deadline:
                        raise TimeoutError(
                            f"op on world '{world.name}' timed out after "
                            f"{timeout}s")
            finally:
                # prune on zero: ``pending`` holds only worlds with in-flight
                # ops, instead of growing one permanent key per world ever
                # used across every scale/heal cycle
                n = self.pending.get(world.name, 1) - 1
                if n <= 0:
                    self.pending.pop(world.name, None)
                else:
                    self.pending[world.name] = n
        except WorldBrokenError:
            self.ops_aborted += 1
            raise

    # ----------------------------------------------------------- point-to-point
    async def send(self, tensor: Any, dst: int, world_name: str,
                   timeout: float | None = None) -> None:
        world, rank = self._world(world_name)

        def _try() -> tuple[bool, Any]:
            self._manager.transport.send(
                world_name, rank, dst, tensor,
                dst_worker=world.members.get(dst),
                src_worker=world.members.get(rank))
            return True, None

        await self._poll(world, _try, timeout)

    async def recv(self, src: int, world_name: str,
                   timeout: float | None = None) -> Any:
        world, rank = self._world(world_name)

        def _try() -> tuple[bool, Any]:
            return self._manager.transport.recv_nowait(
                world_name, src, rank, src_worker=world.members.get(src))

        return await self._poll(world, _try, timeout)

    # --------------------------------------------------------------- collectives
    async def broadcast(self, tensor: Any, root: int, world_name: str,
                        timeout: float | None = None) -> Any:
        world, rank = self._world(world_name)
        if rank == root:
            for r in range(world.size):
                if r != root:
                    await self.send(tensor, r, world_name, timeout)
            return tensor
        return await self.recv(root, world_name, timeout)

    async def reduce(self, tensor: Any, root: int, world_name: str,
                     op: str = "sum", timeout: float | None = None) -> Any:
        world, rank = self._world(world_name)
        fn = REDUCE_OPS[op]
        if rank == root:
            acc = tensor
            for r in range(world.size):
                if r != root:
                    acc = fn(acc, await self.recv(r, world_name, timeout))
            return acc
        await self.send(tensor, root, world_name, timeout)
        return tensor

    async def all_reduce(self, tensor: Any, world_name: str, op: str = "sum",
                         timeout: float | None = None) -> Any:
        world, rank = self._world(world_name)
        reduced = await self.reduce(tensor, 0, world_name, op, timeout)
        return await self.broadcast(reduced if rank == 0 else None, 0,
                                    world_name, timeout)

    async def gather(self, tensor: Any, root: int, world_name: str,
                     timeout: float | None = None) -> list[Any] | None:
        world, rank = self._world(world_name)
        if rank == root:
            out: list[Any] = [None] * world.size
            out[root] = tensor
            for r in range(world.size):
                if r != root:
                    out[r] = await self.recv(r, world_name, timeout)
            return out
        await self.send(tensor, root, world_name, timeout)
        return None

    async def all_gather(self, tensor: Any, world_name: str,
                         timeout: float | None = None) -> list[Any]:
        world, rank = self._world(world_name)
        gathered = await self.gather(tensor, 0, world_name, timeout)
        return await self.broadcast(gathered if rank == 0 else None, 0,
                                    world_name, timeout)

    async def scatter(self, tensors: Sequence[Any] | None, root: int,
                      world_name: str, timeout: float | None = None) -> Any:
        world, rank = self._world(world_name)
        if rank == root:
            assert tensors is not None and len(tensors) == world.size, (
                f"scatter at root needs {world.size} tensors")
            for r in range(world.size):
                if r != root:
                    await self.send(tensors[r], r, world_name, timeout)
            return tensors[root]
        return await self.recv(root, world_name, timeout)

"""In-process analogue of PyTorch's TCPStore.

One ``Store`` instance plays the role the paper assigns to "one TCPStore
instance ... associated with one world" (§3.3 Watchdog) — except that, being a
single-host simulation, we use one namespaced store for the whole cluster and
give each world its own key prefix. The API mirrors TCPStore: ``set``/``get``,
atomic ``add``, ``wait``-for-keys, plus TTL'd keys for heartbeats.

Thread-safe: the serving pipeline runs workers on one asyncio loop, but
``initialize_world`` may run from a side thread (paper §4.2 does blocking
world init on a separate thread), so all mutation takes a lock.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Iterator


class Store:
    def __init__(self, clock=time.monotonic) -> None:
        self._clock = clock
        self._lock = threading.Lock()
        self._data: dict[str, Any] = {}
        self._expiry: dict[str, float] = {}  # key -> absolute deadline

    # -- basic KV ----------------------------------------------------------
    def set(self, key: str, value: Any, ttl: float | None = None) -> None:
        with self._lock:
            self._data[key] = value
            if ttl is not None:
                self._expiry[key] = self._clock() + ttl
            else:
                self._expiry.pop(key, None)

    def get(self, key: str, default: Any = None) -> Any:
        with self._lock:
            self._expire_locked()
            return self._data.get(key, default)

    def delete(self, key: str) -> bool:
        with self._lock:
            self._expiry.pop(key, None)
            return self._data.pop(key, None) is not None

    def delete_prefix(self, prefix: str) -> int:
        """Drop every key under ``prefix``; returns the number deleted.

        Namespace GC primitive: long-lived elastic clusters accumulate
        per-session / per-world key families (snapshots, heartbeats), and
        deleting them key-by-key from call sites is exactly how the PR 1
        world-state leak happened. Callers must pass a trailing delimiter
        (e.g. ``"snap/pipe/7/"``) so sibling namespaces sharing a textual
        prefix are not swept along.
        """
        with self._lock:
            dead = [k for k in self._data if k.startswith(prefix)]
            for k in dead:
                self._data.pop(k, None)
                self._expiry.pop(k, None)
            return len(dead)

    def add(self, key: str, amount: int = 1) -> int:
        """Atomic counter, like TCPStore.add."""
        with self._lock:
            self._expire_locked()
            value = int(self._data.get(key, 0)) + amount
            self._data[key] = value
            return value

    def keys(self, prefix: str = "") -> list[str]:
        with self._lock:
            self._expire_locked()
            return sorted(k for k in self._data if k.startswith(prefix))

    def items(self, prefix: str = "") -> Iterator[tuple[str, Any]]:
        with self._lock:
            self._expire_locked()
            snapshot = [(k, v) for k, v in self._data.items() if k.startswith(prefix)]
        return iter(sorted(snapshot))

    # -- rendezvous helper --------------------------------------------------
    def wait(self, keys: list[str], timeout: float = 10.0, poll: float = 0.001) -> bool:
        """Block until all ``keys`` exist (TCPStore.wait). Returns False on timeout."""
        deadline = self._clock() + timeout
        while True:
            with self._lock:
                self._expire_locked()
                if all(k in self._data for k in keys):
                    return True
            if self._clock() >= deadline:
                return False
            time.sleep(poll)

    # -- TTL ---------------------------------------------------------------
    def ttl_remaining(self, key: str) -> float | None:
        """Seconds until expiry, None if key absent or non-expiring."""
        with self._lock:
            self._expire_locked()
            if key not in self._data or key not in self._expiry:
                return None
            return max(0.0, self._expiry[key] - self._clock())

    def _expire_locked(self) -> None:
        now = self._clock()
        dead = [k for k, t in self._expiry.items() if t <= now]
        for k in dead:
            self._expiry.pop(k, None)
            self._data.pop(k, None)

"""Model substrate: configs, parameter-spec machinery, shared ops.

Parameters are plain pytrees (nested dicts of jnp arrays). Every leaf is
described by a :class:`ParamSpec` carrying its *logical* sharding axes; the
distributed layer maps logical axes to mesh axes (MaxText-style rules). The
same spec tree yields (a) real initialized params for smoke tests/examples,
and (b) ``ShapeDtypeStruct`` stand-ins for the multi-pod dry-run — full-size
configs are never allocated on this host.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

# ----------------------------------------------------------------- block kinds

DENSE = "dense"          # attn + swiglu mlp
MOE = "moe"              # attn + mixture-of-experts mlp
MAMBA2 = "mamba2"        # SSD state-space block
HYBRID = "hybrid"        # mamba2 backbone + shared attention block (zamba2)
GEMMA_PAIR = "gemma_pair"  # alternating local/global attention pair (gemma2)


@dataclasses.dataclass(frozen=True)
class BlockGroup:
    """A run of structurally-identical layers, scanned as one lax.scan."""

    kind: str
    count: int                      # number of scan steps
    #: sliding window for local attention (None = full/causal)
    window: Optional[int] = None
    #: HYBRID: how many mamba layers per scan step (shared attn fires once per step)
    mamba_per_step: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    groups: tuple[BlockGroup, ...] = ()

    # attention options
    qk_norm: bool = False
    attn_softcap: Optional[float] = None       # gemma2: 50.0
    final_softcap: Optional[float] = None      # gemma2: 30.0
    sliding_window: Optional[int] = None       # uniform SWA (mixtral)
    rope_theta: float = 1e4
    mrope_sections: Optional[tuple[int, int, int]] = None  # qwen2-vl (t,h,w)

    # MoE options
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    router_aux_coef: float = 0.01
    #: GShard-style per-expert capacity = tokens*k/E * this factor; overflow
    #: tokens are dropped (residual stream still carries them). Set to
    #: num_experts/experts_per_token (or higher) for dropless behaviour.
    moe_capacity_factor: float = 1.25

    # SSM (mamba2) options
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    ssm_groups: int = 1

    # hybrid (zamba2) options
    shared_attn_every: int = 6
    shared_attn_lora_rank: int = 0   # >0: per-invocation LoRA deltas on qkv

    # enc-dec (whisper) options
    encoder_layers: int = 0
    encoder_frames: int = 1500       # whisper-base source positions

    # numerics / impl
    param_dtype: Any = jnp.bfloat16
    activation_dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    gemma_norm_plus_one: bool = False
    attn_impl: str = "reference"     # reference | chunked | pallas | auto
    remat: bool = False              # activation checkpointing per scan step
    #: "per_layer": checkpoint each scan step (stores L residuals);
    #: "two_level": nested sqrt-N checkpointing — outer scan over layer
    #: blocks, inner scan over layers, both checkpointed: stores
    #: O(L/G + G) residuals at ~1 extra forward recompute. §Perf lever for
    #: memory-bound train combos.
    remat_policy: str = "per_layer"
    remat_block: int = 8             # two_level: layers per outer block
    source_cite: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Total trainable parameters (for 6ND model-FLOPs accounting)."""
        return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
            param_specs_fn(self)))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        total = self.param_count()
        if self.num_experts and self.experts_per_token:
            expert_p = 3 * self.d_model * self.moe_d_ff  # per expert, per layer
            n_moe_layers = self.num_layers
            inactive = (self.num_experts - self.experts_per_token) * expert_p \
                * n_moe_layers
            return total - inactive
        return total


# ----------------------------------------------------------------- param specs

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    #: logical axis name per dim (None = replicated dim). See distributed/sharding.py
    axes: tuple[Optional[str], ...]
    init: str = "normal"             # normal | zeros | ones
    #: fan-in for scaled init (0 -> last-but-one dim)
    fan_in: int = 0
    dtype: Any = None                # None -> cfg.param_dtype

    def initializer(self, key: jax.Array, cfg: ModelConfig) -> jax.Array:
        dtype = self.dtype or cfg.param_dtype
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan = self.fan_in or (self.shape[-2] if len(self.shape) >= 2 else self.shape[-1])
        scale = 1.0 / math.sqrt(max(fan, 1))
        return (jax.random.normal(key, self.shape, jnp.float32) * scale).astype(dtype)


def init_from_specs(specs, key: jax.Array, cfg: ModelConfig):
    leaves, treedef = jax.tree.flatten(specs,
                                       is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [s.initializer(k, cfg) for s, k in zip(leaves, keys)])


def abstract_from_specs(specs, cfg: ModelConfig):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or cfg.param_dtype),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def axes_from_specs(specs):
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# late-bound to avoid circular import (transformer.py registers it)
param_specs_fn: Callable[[ModelConfig], Any] = lambda cfg: (_ for _ in ()).throw(
    RuntimeError("param_specs_fn not registered"))


def register_param_specs(fn) -> None:
    global param_specs_fn
    param_specs_fn = fn


# ----------------------------------------------------------------- shared ops

def rms_norm(x: jax.Array, w: jax.Array, eps: float, plus_one: bool = False
             ) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (y * scale).astype(dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., seq, hd/2)
    angles = angles[..., None, :]                       # add head axis
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: tuple[int, int, int]) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL §2.1): the head_dim/2 frequency slots are
    split into (temporal, height, width) sections, each rotated by its own
    position stream. positions: (3, ..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    assert sum(sections) == hd // 2, (sections, hd)
    # per-frequency-slot section selector: slot i rotates by positions[sec(i)]
    sec_id = jnp.asarray(np.repeat(np.arange(3), np.asarray(sections)))  # (hd/2,)
    pos = jnp.moveaxis(positions, 0, -1).astype(jnp.float32)    # (..., seq, 3)
    pos = pos[..., sec_id]                               # (..., seq, hd/2)
    angles = pos * freqs                                 # (..., seq, hd/2)
    angles = angles[..., None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings (f32)."""
    log_timescale = math.log(10000.0) / (dim // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(dim // 2, dtype=jnp.float32))
    scaled = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=-1)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array
           ) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x: jax.Array, w_in: jax.Array, b_in: jax.Array,
             w_out: jax.Array, b_out: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ w_in + b_in) @ w_out + b_out


def cross_entropy_loss(logits: jax.Array, targets: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    """logits (..., V) f32-accumulated; targets int (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)

"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment carve-out, the audio frontend (log-mel + conv feature
extractor) is a stub: the model consumes precomputed frame embeddings
(B, T_frames, d_model) from ``input_specs``. This module implements the
transformer backbone: bidirectional encoder, causal decoder with per-layer
cross-attention, sinusoidal positions, GELU MLPs, tied decoder embeddings.
(Norms are RMSNorm rather than LayerNorm — uniform with the rest of the zoo;
dims/attention structure follow whisper-base.)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from . import attention as attn
from .common import (
    ModelConfig,
    ParamSpec,
    abstract_from_specs,
    axes_from_specs,
    cross_entropy_loss,
    gelu_mlp,
    init_from_specs,
    rms_norm,
    sinusoidal_positions,
)
from .transformer import _attn_specs, _norm, _stack_tree

PS = ParamSpec


def _mlp_bias_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_in": PS((d, f), ("embed", "mlp")),
        "b_in": PS((f,), ("mlp",), init="zeros"),
        "w_out": PS((f, d), ("mlp", "embed")),
        "b_out": PS((d,), (None,), init="zeros"),
    }


def _enc_layer_specs(cfg: ModelConfig) -> dict:
    return {"ln1": _norm(cfg.d_model), "attn": _attn_specs(cfg),
            "ln2": _norm(cfg.d_model), "mlp": _mlp_bias_specs(cfg)}


def _dec_layer_specs(cfg: ModelConfig) -> dict:
    s = _enc_layer_specs(cfg)
    s["lnx"] = _norm(cfg.d_model)
    s["xattn"] = _attn_specs(cfg)
    return s


def whisper_param_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": PS((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                    fan_in=cfg.d_model),
        "enc": _stack_tree(_enc_layer_specs(cfg), cfg.encoder_layers),
        "dec": _stack_tree(_dec_layer_specs(cfg), cfg.num_layers),
        "enc_norm": _norm(cfg.d_model),
        "final_norm": _norm(cfg.d_model),
    }


def _mlp(p, x):
    return gelu_mlp(x, p["w_in"], p["b_in"], p["w_out"], p["b_out"])


def encode(cfg: ModelConfig, params, frames: jax.Array) -> jax.Array:
    """frames (B, T, D) stub-frontend embeddings -> encoder states (B, T, D)."""
    t = frames.shape[1]
    x = frames.astype(cfg.activation_dtype)
    x = x + sinusoidal_positions(t, cfg.d_model).astype(x.dtype)[None]
    x = constrain(x, "batch", "frames", "act_embed")

    def step(x, p):
        h = attn.self_attention_prefill(
            cfg, p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), None,
            causal=False, use_rope=False)
        x = x + h
        x = x + _mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, None

    if cfg.remat:
        step = jax.checkpoint(step)
    x, _ = jax.lax.scan(step, x, params["enc"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(cfg: ModelConfig, p_x, enc: jax.Array):
    k = jnp.einsum("btd,dhk->bthk", enc, p_x["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc, p_x["wv"])
    return k, v


def decoder_forward(cfg: ModelConfig, params, tokens: jax.Array,
                    enc: jax.Array) -> jax.Array:
    """tokens (B, S) -> logits (B, S, V)."""
    bsz, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.activation_dtype)
    x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)[None]
    x = constrain(x, "batch", "act_seq", "act_embed")

    def step(x, p):
        h = attn.self_attention_prefill(
            cfg, p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), None,
            causal=True, use_rope=False)
        x = x + h
        xk, xv = _cross_kv(cfg, p["xattn"], enc)
        x = x + attn.cross_attention(
            cfg, p["xattn"], rms_norm(x, p["lnx"], cfg.norm_eps), xk, xv)
        x = x + _mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, None

    if cfg.remat:
        step = jax.checkpoint(step)
    x, _ = jax.lax.scan(step, x, params["dec"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]).astype(jnp.float32)
    return constrain(logits, "batch", "act_seq", "vocab")


class WhisperModel:
    """Enc-dec handle mirroring the LanguageModel API where it can."""

    def __init__(self, cfg: ModelConfig) -> None:
        self.cfg = cfg

    def param_specs(self):
        return whisper_param_specs(self.cfg)

    def init(self, key):
        return init_from_specs(self.param_specs(), key, self.cfg)

    def abstract_params(self):
        return abstract_from_specs(self.param_specs(), self.cfg)

    def logical_axes(self):
        return axes_from_specs(self.param_specs())

    def forward(self, params, tokens, *, frames=None, **_):
        enc = encode(self.cfg, params, frames)
        return decoder_forward(self.cfg, params, tokens, enc), jnp.float32(0.0)

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch["tokens"],
                                   frames=batch["frames"])
        ce = cross_entropy_loss(logits, batch["targets"], batch.get("mask"))
        return ce, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------- decoding
    def _cache_shapes(self, batch: int, max_len: int, dtype):
        cfg = self.cfg
        kv = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.hd)
        xkv = (cfg.num_layers, batch, cfg.encoder_frames, cfg.num_kv_heads,
               cfg.hd)
        return {"self_k": (kv, dtype), "self_v": (kv, dtype),
                "cross_k": (xkv, dtype), "cross_v": (xkv, dtype)}

    def init_cache(self, batch, max_len, dtype=None):
        dtype = dtype or self.cfg.activation_dtype
        return {k: jnp.zeros(sh, dt) for k, (sh, dt)
                in self._cache_shapes(batch, max_len, dtype).items()}

    def abstract_cache(self, batch, max_len, dtype=None):
        dtype = dtype or self.cfg.activation_dtype
        return {k: jax.ShapeDtypeStruct(sh, dt) for k, (sh, dt)
                in self._cache_shapes(batch, max_len, dtype).items()}

    def cache_logical_axes(self, batch, max_len):
        kv = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
        xkv = ("layers", "batch", "frames", "kv_heads", "head_dim")
        return {"self_k": kv, "self_v": kv, "cross_k": xkv, "cross_v": xkv}

    def prime_cache(self, params, cache, frames):
        """Fill cross-attention K/V from the encoder (prefill-time)."""
        cfg = self.cfg
        enc = encode(cfg, params, frames)

        def step(_, p):
            return None, _cross_kv(cfg, p["xattn"], enc)

        _, (xk, xv) = jax.lax.scan(step, None, params["dec"])
        return dict(cache, cross_k=xk, cross_v=xv)

    def decode_step(self, params, cache, tokens, t, **_):
        """tokens (B,1) -> (logits (B,V), new cache)."""
        cfg = self.cfg
        x = params["embed"][tokens].astype(cfg.activation_dtype)
        pos_table = sinusoidal_positions(cache["self_k"].shape[2],
                                         cfg.d_model).astype(x.dtype)
        x = x + jax.lax.dynamic_slice_in_dim(pos_table, t, 1, axis=0)[None]

        def step(x, layer):
            p, ck, cv, xk, xv = layer
            h, nc = attn.self_attention_decode(
                cfg, p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                {"k": ck, "v": cv}, t, window=None, use_rope=False)
            x = x + h
            x = x + attn.cross_attention(
                cfg, p["xattn"], rms_norm(x, p["lnx"], cfg.norm_eps), xk, xv)
            x = x + _mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
            return x, (nc["k"], nc["v"])

        x, (nk, nv) = jax.lax.scan(
            step, x, (params["dec"], cache["self_k"], cache["self_v"],
                      cache["cross_k"], cache["cross_v"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])[:, 0]
        return logits.astype(jnp.float32), dict(cache, self_k=nk, self_v=nv)

"""Attention: GQA with RoPE / M-RoPE, sliding window, softcap, qk-norm.

Two data paths:
* ``prefill`` — full-sequence causal (or bidirectional for encoders),
* ``decode`` — one new token against a KV cache. Sliding-window layers use a
  ring-buffer cache of size ``window`` (slot for position p is ``p % window``),
  which is what makes ``long_500k`` decode tractable for SWA architectures.

``cfg.attn_impl`` selects the reference jnp path or the Pallas flash kernels
(kernels/flash_attention.py, kernels/decode_attention.py). The reference path
is the oracle and the dry-run path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, apply_mrope, apply_rope, rms_norm, softcap

NEG_INF = -2.3819763e38  # ~ -max bf16


# ------------------------------------------------------------------ projections

def qkv_project(cfg: ModelConfig, p, x: jax.Array, positions: Optional[jax.Array],
                mrope_positions: Optional[jax.Array] = None,
                use_rope: bool = True):
    """x: (B,S,D) -> q (B,S,H,hd), k/v (B,S,K,hd), roped + normed."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        if mrope_positions is not None:
            assert cfg.mrope_sections is not None
            q = apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        elif positions is not None:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def output_project(p, ctx: jax.Array) -> jax.Array:
    """ctx: (B,S,H,hd) -> (B,S,D)."""
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])


# ------------------------------------------------------------------- reference

def _grouped(q: jax.Array, num_kv: int) -> jax.Array:
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, d)


def attend_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     mask: jax.Array, cap: Optional[float],
                     scale: float) -> jax.Array:
    """q (B,S,H,hd), k/v (B,T,K,hd), mask (B?,S,T) or (S,T) bool -> (B,S,H,hd)."""
    num_kv = k.shape[2]
    qg = _grouped(q, num_kv)                                   # (B,S,K,G,hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    scores = softcap(scores, cap)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    b, s, kk, g, d = ctx.shape
    return ctx.reshape(b, s, kk * g, d)


def attend_flash_jnp(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool, window: Optional[int],
                     cap: Optional[float], scale: float,
                     q_offset=0, block_q: int = 256,
                     block_k: int = 1024) -> jax.Array:
    """Blockwise online-softmax attention in pure jnp ("flash in JAX").

    Never materializes (S, T) scores — the lowered graph's transient is one
    (BQ, BK) tile per head — which is what makes 32k/500k shapes *lowerable*
    for the dry-run (the Pallas kernel is the on-TPU twin of this math; this
    path is what GSPMD partitions). q (B,Sq,H,hd); k,v (B,T,K,hd);
    ``q_offset`` is the global position of q[0] (sequence-parallel callers
    pass their shard offset).
    """
    bsz, sq, h, hd = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    g = h // kv
    bq = min(block_q, sq)
    while sq % bq:
        bq -= 1
    bk = min(block_k, t)
    while t % bk:
        bk -= 1
    nq, nk = sq // bq, t // bk

    qb = q.reshape(bsz, nq, bq, kv, g, hd).astype(jnp.float32)
    kb = k.reshape(bsz, nk, bk, kv, hd).astype(jnp.float32)
    vb = v.reshape(bsz, nk, bk, kv, hd).astype(jnp.float32)

    def q_step(_, q_in):
        iq, qblk = q_in                                   # (B,BQ,K,G,hd)
        qpos = q_offset + iq * bq + jnp.arange(bq)

        def kv_step(carry, kv_in):
            m, l, acc = carry
            ik, kblk, vblk = kv_in
            s = jnp.einsum("bqkgd,bckd->bkgqc", qblk, kblk) * scale
            s = softcap(s, cap)
            kpos = ik * bk + jnp.arange(bk)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.where(mask[None, None, None], jnp.exp(s - m_new[..., None]),
                          0.0)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + \
                jnp.einsum("bkgqc,bckd->bkgqd", p, vblk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((bsz, kv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((bsz, kv, g, bq), jnp.float32)
        a0 = jnp.zeros((bsz, kv, g, bq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), kb.transpose(1, 0, 2, 3, 4),
             vb.transpose(1, 0, 2, 3, 4)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # (B,K,G,BQ,hd)
        return None, out.transpose(0, 3, 1, 2, 4)          # (B,BQ,K,G,hd)

    # checkpoint per q-chunk: backward recomputes the row's online softmax
    # instead of storing every (BQ, BK) tile — the flash-bwd trade.
    _, blocks = jax.lax.scan(jax.checkpoint(q_step), None,
                             (jnp.arange(nq), qb.transpose(1, 0, 2, 3, 4, 5)))
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(bsz, sq, h, hd)
    return out.astype(q.dtype)


def _flash_sharded(q, k, v, *, causal, window, cap, scale):
    """shard_map wrapper: batch over the 'batch' rule axes, q-sequence over
    'act_seq' axes; K/V gathered full per device. Balances prefill compute
    across ``model`` even when head counts don't divide the axis."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import (
        _CTX,
        _axis_size,
        _resolve,
        shard_map_compat,
    )

    mesh, rules = _CTX.mesh, _CTX.rules
    if mesh is None or rules is None:
        return attend_flash_jnp(q, k, v, causal=causal, window=window,
                                cap=cap, scale=scale)
    spec = _resolve(rules, mesh, ("batch", "act_seq", None, None),
                    tuple(q.shape))
    bspec, sspec = spec[0], spec[1]
    if sspec is None:
        seq_axes: tuple[str, ...] = ()
    else:
        seq_axes = (sspec,) if isinstance(sspec, str) else tuple(sspec)
    s_loc = q.shape[1] // max(_axis_size(mesh, seq_axes), 1)

    def body(ql, kl, vl):
        if seq_axes:
            idx = jax.lax.axis_index(seq_axes[0])
            for ax in seq_axes[1:]:
                idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
            offset = idx * s_loc
        else:
            offset = 0
        return attend_flash_jnp(ql, kl, vl, causal=causal, window=window,
                                cap=cap, scale=scale, q_offset=offset)

    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(bspec, sspec, None, None), P(bspec, None, None, None),
                  P(bspec, None, None, None)),
        out_specs=P(bspec, sspec, None, None),
        check_vma=False)
    return fn(q, k, v)


def causal_mask(s: int, t: int, window: Optional[int],
                offset: int = 0) -> jax.Array:
    """(s, t) bool mask. Query i attends key j iff j <= i+offset and, with a
    window, j > i+offset-window."""
    qpos = jnp.arange(s)[:, None] + offset
    kpos = jnp.arange(t)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


# --------------------------------------------------------------------- prefill

def self_attention_prefill(cfg: ModelConfig, p, x: jax.Array,
                           positions: jax.Array, *,
                           window: Optional[int] = None,
                           causal: bool = True,
                           mrope_positions: Optional[jax.Array] = None,
                           use_rope: bool = True,
                           return_kv: bool = False):
    q, k, v = qkv_project(cfg, p, x, positions, mrope_positions, use_rope)
    scale = cfg.hd ** -0.5
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "chunked" if x.shape[1] >= 2048 else "reference"
    if impl == "pallas":
        from repro.kernels import ops as kops
        ctx = kops.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=cfg.attn_softcap, scale=scale)
    elif impl == "chunked":
        ctx = _flash_sharded(q, k, v, causal=causal, window=window,
                             cap=cfg.attn_softcap, scale=scale)
    else:
        s = x.shape[1]
        if causal:
            mask = causal_mask(s, s, window)
        else:
            mask = jnp.ones((s, s), dtype=bool)
        ctx = attend_reference(q, k, v, mask=mask, cap=cfg.attn_softcap,
                               scale=scale)
    out = output_project(p, ctx)
    if return_kv:
        return out, (k, v)
    return out


def fill_kv_cache(cache: dict, k: jax.Array, v: jax.Array,
                  window: Optional[int]) -> dict:
    """Write prefill K/V (B,S,K,hd) into a fresh decode cache.

    Full caches store positions [0, S); ring caches (length == window) store
    position p at slot p % window — matching self_attention_decode's layout.
    """
    s = k.shape[1]
    length = cache["k"].shape[1]
    if window is not None and length == window and s >= window:
        tail = jnp.arange(s - window, s)
        slots = tail % window
        new_k = cache["k"].at[:, slots].set(k[:, tail].astype(cache["k"].dtype))
        new_v = cache["v"].at[:, slots].set(v[:, tail].astype(cache["v"].dtype))
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
    return {"k": new_k, "v": new_v}


def self_attention_verify(cfg: ModelConfig, p, x: jax.Array, cache: dict,
                          t: jax.Array, *,
                          use_rope: bool = True) -> tuple[jax.Array, dict]:
    """K-token cache continuation: the speculative-verify hot path.

    x (B,K,D) holds K known tokens for positions ``t .. t+K-1`` (the
    session's current token plus its draft proposals). Their K/V land in
    the cache with one slice update and all K queries attend the whole
    cache under a per-row causal offset mask — one fused matmul sweep
    with the same math as K sequential :func:`self_attention_decode`
    calls, which would cost K full passes over the weights. Full
    (non-ring, unwindowed) caches only: verification rollback relies on
    slot j never being read by positions < j, which ring buffers break.
    """
    bsz, kk = x.shape[:2]
    positions = jnp.broadcast_to(
        t + jnp.arange(kk, dtype=jnp.int32)[None, :], (bsz, kk))
    q, k_new, v_new = qkv_project(cfg, p, x, positions, None, use_rope)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, t, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, t, axis=1)
    from repro.distributed import constrain as _c
    k = _c(k, "batch", "cache_seq", "kv_heads", "head_dim")
    v = _c(v, "batch", "cache_seq", "kv_heads", "head_dim")
    new_cache = {"k": k, "v": v}

    length = k.shape[1]
    slots = jnp.arange(length, dtype=jnp.int32)
    # query row i sits at position t+i: attend slots <= t+i
    valid = slots[None, :] <= (t + jnp.arange(kk, dtype=jnp.int32))[:, None]
    mask = jnp.broadcast_to(valid[None], (bsz, kk, length))
    ctx = attend_reference(q, k, v, mask=mask, cap=cfg.attn_softcap,
                           scale=cfg.hd ** -0.5)
    return output_project(p, ctx), new_cache


def cross_attention(cfg: ModelConfig, p, x: jax.Array,
                    enc_k: jax.Array, enc_v: jax.Array) -> jax.Array:
    """Decoder cross-attn; enc_k/enc_v are pre-projected encoder states."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    t = enc_k.shape[1]
    mask = jnp.ones((x.shape[1], t), dtype=bool)
    ctx = attend_reference(q, enc_k, enc_v, mask=mask, cap=None,
                           scale=cfg.hd ** -0.5)
    return output_project(p, ctx)


# ---------------------------------------------------------------------- decode

def init_kv_cache(batch: int, length: int, num_kv: int, hd: int, dtype
                  ) -> dict:
    return {
        "k": jnp.zeros((batch, length, num_kv, hd), dtype),
        "v": jnp.zeros((batch, length, num_kv, hd), dtype),
    }


def abstract_kv_cache(batch: int, length: int, num_kv: int, hd: int, dtype
                      ) -> dict:
    return {
        "k": jax.ShapeDtypeStruct((batch, length, num_kv, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, length, num_kv, hd), dtype),
    }


def self_attention_decode(cfg: ModelConfig, p, x: jax.Array, cache: dict,
                          t: jax.Array, *, window: Optional[int] = None,
                          mrope_positions: Optional[jax.Array] = None,
                          use_rope: bool = True) -> tuple[jax.Array, dict]:
    """One-token decode. x: (B,1,D); t: scalar int32 current position.

    Full-attention layers use a length-``max_len`` cache indexed by t;
    sliding-window layers use a ring buffer of size ``window`` — slot
    ``t % window`` — so cache memory is O(window), not O(context).
    """
    positions = jnp.full((x.shape[0], 1), t, dtype=jnp.int32)
    q, k_new, v_new = qkv_project(cfg, p, x, positions, mrope_positions,
                                  use_rope)
    # §Perf (confirmed): when kv_heads doesn't divide the model axis the
    # cache stores head_dim-sharded; q must contract over the SAME sharded
    # head_dim or GSPMD all-gathers the whole cache per layer (measured:
    # ~37 GB/device/step on qwen3-8b decode_32k). Mirror the cache's
    # resolved layout onto q.
    from repro.distributed import logical_spec
    cache_spec = logical_spec(
        ("batch", "cache_seq", "kv_heads", "head_dim"),
        tuple(cache["k"].shape))
    if cache_spec and len(cache_spec) == 4 and cache_spec[3] is not None:
        from repro.distributed import constrain as _c0
        q = _c0(q, "batch", None, None, "head_dim")
        k_new = _c0(k_new, "batch", None, None, "head_dim")
        v_new = _c0(v_new, "batch", None, None, "head_dim")

    ring = window is not None and cache["k"].shape[1] == window
    slot = (jnp.mod(t, jnp.int32(window)) if ring else t).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    # pin updated cache to its storage layout — without this GSPMD has been
    # observed to replicate-and-repartition the whole cache per layer
    # ("involuntary full rematerialization")
    from repro.distributed import constrain as _c
    k = _c(k, "batch", "cache_seq", "kv_heads", "head_dim")
    v = _c(v, "batch", "cache_seq", "kv_heads", "head_dim")
    new_cache = {"k": k, "v": v}

    length = k.shape[1]
    slots = jnp.arange(length, dtype=jnp.int32)
    if ring:
        # slot s holds global position t - ((t - s) mod W); valid iff >= 0
        w = jnp.int32(window)
        slot_pos = t - jnp.mod(t - slots, w)
        valid = slot_pos >= 0
    else:
        valid = slots <= t
        if window is not None:  # windowed mask over a full cache
            valid &= slots > t - jnp.int32(window)
    mask = valid[None, None, :]                                  # (1,1,T)
    mask = jnp.broadcast_to(mask, (x.shape[0], 1, length))

    scale = cfg.hd ** -0.5
    if cfg.attn_impl == "pallas":
        from repro.kernels import ops as kops
        ctx = kops.decode_attention(q, k, v, mask=mask, softcap=cfg.attn_softcap,
                                    scale=scale)
    else:
        ctx = attend_reference(q, k, v, mask=mask, cap=cfg.attn_softcap,
                               scale=scale)
    return output_project(p, ctx), new_cache

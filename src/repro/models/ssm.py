"""Mamba2 block — SSD (state-space duality) per arXiv:2405.21060.

Prefill uses the chunked SSD algorithm (quadratic within chunks via the
semiseparable decay matrix, linear across chunks via state recurrence);
decode is the O(1)-per-token recurrence on the (H, P, N) state. The chunked
scan here (``ssd_reference``) is pure jnp and doubles as the oracle for the
Pallas ``ssd_scan`` kernel; ``cfg.attn_impl == 'pallas'`` switches the block
to the kernel.

TPU adaptation notes:

* The canonical CUDA implementation fuses one ``in_proj`` over the packed
  (z | x | B | C | dt) output. We *split* the projection (and the depthwise
  conv) per semantic part: the big d_inner parts shard cleanly over the
  ``model`` mesh axis while the small B/C/dt parts stay replicated —
  a packed matrix cannot be given a single valid PartitionSpec because its
  output dim mixes differently-sharded segments. Depthwise conv is
  channelwise, so splitting it is exact.
* Heads (H = d_inner/head_dim) shard over ``model``; the decode state
  (B, H, P, N) is tiny (mamba2-2.7b: 80·64·128 ≈ 2.6 MB/seq), which is
  exactly why SSM stages are the best case for MultiWorld online
  instantiation — replica spin-up moves megabytes, not a 32k KV cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from .common import ModelConfig, rms_norm

NEG_INF = -1e30


def segsum(x: jax.Array) -> jax.Array:
    """(..., L) -> (..., L, L): out[i, j] = sum_{j < m <= i} x[m], -inf above diag."""
    l = x.shape[-1]
    cum = jnp.cumsum(x, axis=-1)
    d = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, d, NEG_INF)


def ssd_reference(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                  c: jax.Array, chunk: int,
                  initial_state: Optional[jax.Array] = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan (ssd_minimal_discrete of the Mamba2 paper).

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); a: (H,) negative decay;
    b, c: (B, S, N) (single group, broadcast over heads).
    Returns y (B, S, H, P), final_state (B, H, P, N).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xd = x * dt[..., None]                              # discretized input
    da = dt * a[None, None, :]                          # (B,S,H) log-decay
    # chunked views
    xc = xd.reshape(bsz, nc, chunk, h, p)
    dac = da.reshape(bsz, nc, chunk, h).transpose(0, 3, 1, 2)   # (B,H,C,L)
    bc_ = b.reshape(bsz, nc, chunk, n)
    cc_ = c.reshape(bsz, nc, chunk, n)

    da_cum = jnp.cumsum(dac, axis=-1)                   # (B,H,C,L)
    decay = jnp.exp(segsum(dac))                        # (B,H,C,L,L)

    # intra-chunk (quadratic) term
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        cc_, bc_, decay, xc)

    # chunk-final states
    decay_states = jnp.exp(da_cum[..., -1:] - da_cum)   # (B,H,C,L)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", bc_, decay_states, xc)

    # inter-chunk recurrence
    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, p, n), states.dtype)
    states = jnp.concatenate([initial_state[:, None], states], axis=1)  # (B,C+1,H,P,N)
    chunk_decay = jnp.exp(
        segsum(jnp.pad(da_cum[..., -1], ((0, 0), (0, 0), (1, 0)))))     # (B,H,C+1,C+1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", chunk_decay, states)
    prev_states, final_state = new_states[:, :-1], new_states[:, -1]

    # inter-chunk (linear) output term
    state_decay_out = jnp.exp(da_cum)                   # (B,H,C,L)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", cc_, prev_states,
                       state_decay_out)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final_state


def ssd_step(state: jax.Array, x: jax.Array, dt: jax.Array, a: jax.Array,
             b: jax.Array, c: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One-token recurrence. state (B,H,P,N); x (B,H,P); dt (B,H); b,c (B,N)."""
    da = jnp.exp(dt * a[None, :])                       # (B,H)
    incr = jnp.einsum("bh,bn,bhp->bhpn", dt, b, x)
    state = state * da[..., None, None] + incr
    y = jnp.einsum("bhpn,bn->bhp", state, c)
    return state, y


# ----------------------------------------------------------------- full block

def _causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (B,S,C); w (C,W); bias (C,)."""
    width = w.shape[-1]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    acc = jnp.zeros_like(x, dtype=jnp.float32)
    s = x.shape[1]
    for i in range(width):
        acc = acc + pad[:, i:i + s, :].astype(jnp.float32) * \
            w[None, None, :, i].astype(jnp.float32)
    return (acc + bias[None, None, :].astype(jnp.float32)).astype(x.dtype)


def _conv_step(x_new: jax.Array, conv_state: jax.Array, w: jax.Array,
               bias: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One-token depthwise conv. x_new (B,C); conv_state (B,W-1,C)."""
    window = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)  # (B,W,C)
    out = jnp.einsum("bwc,cw->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32)) + bias.astype(jnp.float32)
    return out.astype(x_new.dtype), window[:, 1:]


def _proj_parts(cfg: ModelConfig, p, x: jax.Array):
    """Split projections (see module docstring): z, x_in, b, c, dt_raw."""
    z = x @ p["in_z"]
    xr = x @ p["in_x"]
    b = x @ p["in_b"]
    c = x @ p["in_c"]
    dt_raw = x @ p["in_dt"]
    return z, xr, b, c, dt_raw


def mamba2_prefill(cfg: ModelConfig, p, x: jax.Array,
                   return_state: bool = False):
    """x (B,S,D) -> (B,S,D) [, decode-ready state]."""
    bsz, s, _ = x.shape
    h, pd, n = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state

    z, xr, b_pre, c_pre, dt_raw = _proj_parts(cfg, p, x)
    xr = constrain(xr, "batch", "seq", "ssm_inner")
    xin = jax.nn.silu(_causal_conv(xr, p["conv_x_w"], p["conv_x_b"]))
    b = jax.nn.silu(_causal_conv(b_pre, p["conv_b_w"], p["conv_b_b"]))
    c = jax.nn.silu(_causal_conv(c_pre, p["conv_c_w"], p["conv_c_b"]))

    xin = xin.reshape(bsz, s, h, pd)
    xin = constrain(xin, "batch", "seq", "ssm_heads", None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    chunk = min(cfg.ssm_chunk, s)
    if cfg.attn_impl == "pallas":
        from repro.kernels import ops as kops
        y, final_state = kops.ssd_scan(
            xin.astype(jnp.float32), dt, a, b.astype(jnp.float32),
            c.astype(jnp.float32), chunk=chunk)
    else:
        y, final_state = ssd_reference(
            xin.astype(jnp.float32), dt, a, b.astype(jnp.float32),
            c.astype(jnp.float32), chunk=chunk)
    y = y.astype(x.dtype) + p["d_skip"][None, None, :, None].astype(x.dtype) * xin
    y = y.reshape(bsz, s, cfg.ssm_d_inner)

    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if not return_state:
        return out
    # decode-ready state: SSD state + the last W-1 *pre-conv* inputs
    w = cfg.ssm_conv_width
    state = {
        "ssm": final_state,
        "conv_x": _conv_tail(xr, w, x.dtype),
        "conv_b": _conv_tail(b_pre, w, x.dtype),
        "conv_c": _conv_tail(c_pre, w, x.dtype),
    }
    return out, state


def _conv_tail(pre: jax.Array, width: int, dtype) -> jax.Array:
    """Last width-1 positions of the pre-conv stream (B,S,C) -> (B,W-1,C),
    left-padded with zeros when S < W-1 (matching causal conv padding)."""
    bsz, s, ch = pre.shape
    if s >= width - 1:
        return pre[:, s - (width - 1):, :].astype(dtype)
    pad = jnp.zeros((bsz, width - 1 - s, ch), dtype)
    return jnp.concatenate([pad, pre.astype(dtype)], axis=1)


def mamba2_state_shapes(cfg: ModelConfig, batch: int, dtype) -> dict:
    h, pd, n = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    gn = cfg.ssm_groups * n
    w = cfg.ssm_conv_width
    return {
        "ssm": ((batch, h, pd, n), jnp.float32),
        "conv_x": ((batch, w - 1, cfg.ssm_d_inner), dtype),
        "conv_b": ((batch, w - 1, gn), dtype),
        "conv_c": ((batch, w - 1, gn), dtype),
    }


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    return {k: jnp.zeros(sh, dt)
            for k, (sh, dt) in mamba2_state_shapes(cfg, batch, dtype).items()}


def mamba2_abstract_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    return {k: jax.ShapeDtypeStruct(sh, dt)
            for k, (sh, dt) in mamba2_state_shapes(cfg, batch, dtype).items()}


def mamba2_decode(cfg: ModelConfig, p, x: jax.Array, state: dict
                  ) -> tuple[jax.Array, dict]:
    """x (B,1,D) -> (y (B,1,D), new state)."""
    bsz = x.shape[0]
    h, pd, n = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state

    z, xr, b, c, dt_raw = _proj_parts(cfg, p, x[:, 0])
    xin, new_cx = _conv_step(xr, state["conv_x"], p["conv_x_w"], p["conv_x_b"])
    b, new_cb = _conv_step(b, state["conv_b"], p["conv_b_w"], p["conv_b_b"])
    c, new_cc = _conv_step(c, state["conv_c"], p["conv_c_w"], p["conv_c_b"])
    xin, b, c = jax.nn.silu(xin), jax.nn.silu(b), jax.nn.silu(c)

    xin = xin.reshape(bsz, h, pd)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    new_ssm, y = ssd_step(state["ssm"], xin.astype(jnp.float32), dt, a,
                          b.astype(jnp.float32), c.astype(jnp.float32))
    y = y.astype(x.dtype) + p["d_skip"][None, :, None].astype(x.dtype) * xin
    y = y.reshape(bsz, cfg.ssm_d_inner)

    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"ssm": new_ssm, "conv_x": new_cx, "conv_b": new_cb,
                 "conv_c": new_cc}

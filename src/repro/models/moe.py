"""Mixture-of-Experts block with capacity-based local dispatch.

Two sharding modes, both exposed as first-class configs (the MoE layout is a
§Perf lever):

* ``tensor`` — every device holds an F/|model| slice of *every* expert;
  tokens stay data-sharded; combine = psum over ``model``. Right when
  num_experts does not divide the model axis (mixtral: 8 experts, 16-way TP).
* ``expert`` — each device owns num_experts/|model| full experts; tokens are
  replicated across ``model``, each rank computes only its owned experts'
  assignments; combine = psum over ``model``. Right for large expert counts
  (qwen3-moe: 128 experts -> 8 per device).

Dispatch is sort-based (argsort by expert id + static per-expert capacity
buffers + batched ``ecd,edf`` einsums), NOT one-hot einsums and NOT
``lax.ragged_dot``: one-hot dispatch adds O(T·E·C·D) fake FLOPs, and
ragged_dot's portable lowering computes *every* group densely (measured: HLO
FLOPs scale linearly with group count), which would corrupt the roofline by
16x for 128 experts. The sort is always device-local (inside shard_map), so
no sharded-axis sort ever reaches GSPMD.

Capacity-overflow tokens are dropped GShard-style (their expert contribution
is zero; the residual stream still carries them). ``capacity_factor``
controls the trade-off.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import current_mesh, current_rules, shard_map_compat
from .common import ModelConfig

def _local_moe(cfg: ModelConfig, x, router_w, w_gate, w_up, w_down,
               *, e_offset, e_local, capacity, model_axis: Optional[str],
               pmean_axes: tuple[str, ...] = (), scatter_seq: bool = False):
    """Per-device MoE. x: (b_loc, s, D). Expert weights are local slices:
    w_gate/w_up (e_local, D, F_loc), w_down (e_local, F_loc, D)."""
    b, s, d = x.shape
    k = cfg.experts_per_token
    e_global = cfg.num_experts
    xf = x.reshape(b * s, d)
    t = b * s

    # -- routing (replicated math: identical on every model rank) ----------
    logits = (xf @ router_w).astype(jnp.float32)               # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)                     # (T, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    weights = weights.astype(x.dtype)

    # load-balance aux loss (Switch-style), computed on the full router
    counts = jnp.zeros((e_global,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(t * k, 1)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e_global * jnp.sum(frac_tokens * frac_probs)

    # -- ownership filter (expert mode drops non-owned choices) ------------
    flat_ids = ids.reshape(-1)                                  # (T*k,)
    local_ids = flat_ids - e_offset
    owned = (local_ids >= 0) & (local_ids < e_local)
    sort_key = jnp.where(owned, local_ids, e_local)             # dropped -> tail

    # -- sort-based dispatch ------------------------------------------------
    order = jnp.argsort(sort_key)                               # stable
    sorted_ids = sort_key[order]                                # (T*k,)
    starts = jnp.searchsorted(sorted_ids, jnp.arange(e_local),
                              side="left")
    pos = jnp.arange(t * k) - starts[jnp.clip(sorted_ids, 0, e_local - 1)]
    valid = (sorted_ids < e_local) & (pos < capacity)
    slot = jnp.where(valid, sorted_ids * capacity + pos, e_local * capacity)

    # slot -> source choice index (sentinel row = t*k)
    buf_choice = jnp.full((e_local * capacity + 1,), t * k, jnp.int32)
    buf_choice = buf_choice.at[slot].set(order.astype(jnp.int32),
                                         mode="drop")
    buf_choice = buf_choice[:-1]
    buf_tok = jnp.minimum(buf_choice // k, t)                   # sentinel -> pad row
    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xbuf = x_pad[buf_tok].reshape(e_local, capacity, d)         # (E_l, C, D)

    # -- expert computation (honest FLOPs: E_l x C x D x F_loc) ------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xbuf, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", xbuf, w_up)
    ybuf = jnp.einsum("ecf,efd->ecd", h, w_down)                # (E_l, C, D)

    # -- combine: weighted scatter-add straight into (T, D) ------------------
    # §Perf: folding the routing weight in before the scatter removes two
    # (T*k, D) temporaries vs the unsort-reshape-reduce formulation.
    y_flat = ybuf.reshape(e_local * capacity, d)
    w_sorted = weights.reshape(-1)[order]
    w_eff = jnp.where(valid, w_sorted, 0).astype(x.dtype)
    y_sorted = y_flat[jnp.minimum(slot, e_local * capacity - 1)] \
        * w_eff[:, None]                                        # (T*k, D)
    tok_sorted = jnp.minimum(order // k, t - 1)
    y = jnp.zeros((t, d), x.dtype).at[tok_sorted].add(y_sorted)

    if model_axis is not None:
        if scatter_seq:
            # §Perf: the combine is followed by a sequence-sharded residual
            # add, so reduce-scatter along seq instead of all-reduce — half
            # the wire, and the result lands already sharded (Megatron-SP).
            y3 = y.reshape(b, s, d)
            y = jax.lax.psum_scatter(y3, model_axis, scatter_dimension=1,
                                     tiled=True)
            if pmean_axes:
                aux = jax.lax.pmean(aux, pmean_axes)
            return y, aux
        y = jax.lax.psum(y, model_axis)
    if pmean_axes:
        aux = jax.lax.pmean(aux, pmean_axes)
    return y.reshape(b, s, d), aux


def moe_block(cfg: ModelConfig, p, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """p: router (D,E), w_gate/w_up (E,D,F), w_down (E,F,D). Returns (y, aux)."""
    mesh = current_mesh()
    rules = current_rules()
    mode = "expert" if cfg.num_experts % _model_size(mesh) == 0 and \
        _model_size(mesh) > 1 else "tensor"

    if mesh is None or "model" not in mesh.axis_names or \
            mesh.shape["model"] == 1:
        cap = _capacity(cfg, x.shape[0] * x.shape[1], cfg.num_experts)
        return _local_moe(cfg, x, p["router"], p["w_gate"], p["w_up"],
                          p["w_down"], e_offset=0, e_local=cfg.num_experts,
                          capacity=cap, model_axis=None)

    m = mesh.shape["model"]
    # batch sharding for tokens: follow the 'batch' rule if divisible
    bspec = _batch_spec(rules, mesh, x.shape[0])
    dp = _spec_size(mesh, bspec)
    t_loc = (x.shape[0] // dp) * x.shape[1]
    # sequence-sharded residual stream outside -> reduce-scatter the combine
    seq_target = (rules or {}).get("act_seq")
    scatter_seq = (seq_target == "model" and x.shape[1] % m == 0)
    out_seq_spec = "model" if scatter_seq else None

    if mode == "expert":
        e_local = cfg.num_experts // m
        cap = _capacity(cfg, t_loc, cfg.num_experts)
        w_specs = (P("model", None, None), P("model", None, None),
                   P("model", None, None))

        def body(xl, rw, wg, wu, wd):
            off = jax.lax.axis_index("model") * e_local
            return _local_moe(cfg, xl, rw, wg, wu, wd, e_offset=off,
                              e_local=e_local, capacity=cap,
                              model_axis="model", scatter_seq=scatter_seq,
                              pmean_axes=tuple(mesh.axis_names))
    else:
        e_local = cfg.num_experts
        cap = _capacity(cfg, t_loc, cfg.num_experts)
        w_specs = (P(None, None, "model"), P(None, None, "model"),
                   P(None, "model", None))

        def body(xl, rw, wg, wu, wd):
            return _local_moe(cfg, xl, rw, wg, wu, wd, e_offset=0,
                              e_local=e_local, capacity=cap,
                              model_axis="model", scatter_seq=scatter_seq,
                              pmean_axes=tuple(mesh.axis_names))

    xspec = P(bspec, None, None)
    yspec = P(bspec, out_seq_spec, None)
    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(xspec, P(None, None), *w_specs),
        out_specs=(yspec, P()),
        check_vma=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _capacity(cfg: ModelConfig, t_loc: int, e_global: int) -> int:
    raw = t_loc * cfg.experts_per_token / e_global * cfg.moe_capacity_factor
    return max(8, int(math.ceil(raw)))


def _model_size(mesh) -> int:
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return mesh.shape["model"]


def _batch_spec(rules, mesh, batch: int):
    target = (rules or {}).get("batch")
    if target is None:
        return None
    names = (target,) if isinstance(target, str) else tuple(target)
    names = tuple(n for n in names if n in mesh.axis_names)
    total = 1
    for n in names:
        total *= mesh.shape[n]
    if not names or batch % total != 0:
        return None
    return names if len(names) > 1 else names[0]


def _spec_size(mesh, spec) -> int:
    if spec is None:
        return 1
    names = (spec,) if isinstance(spec, str) else spec
    total = 1
    for n in names:
        total *= mesh.shape[n]
    return total

from .common import (
    DENSE,
    GEMMA_PAIR,
    HYBRID,
    MAMBA2,
    MOE,
    BlockGroup,
    ModelConfig,
    ParamSpec,
)
from .transformer import LanguageModel
from .whisper import WhisperModel


def build_model(cfg: ModelConfig):
    """Uniform constructor: enc-dec for audio, decoder-only otherwise."""
    if cfg.family == "audio":
        return WhisperModel(cfg)
    return LanguageModel(cfg)


__all__ = [
    "DENSE", "GEMMA_PAIR", "HYBRID", "MAMBA2", "MOE",
    "BlockGroup", "ModelConfig", "ParamSpec",
    "LanguageModel", "WhisperModel", "build_model",
]

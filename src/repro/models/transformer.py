"""Decoder-only language model assembly for all architecture families.

A model is a sequence of :class:`BlockGroup`\\ s; each group is a run of
structurally identical layers executed as one ``lax.scan`` over stacked
parameters (and stacked caches at decode). Group kinds:

* ``dense``       — attn + SwiGLU MLP (llama3.2 / qwen3 / yi / qwen2-vl)
* ``moe``         — attn + mixture-of-experts MLP (mixtral / qwen3-moe)
* ``gemma_pair``  — [local-SWA layer, global layer] per scan step, sandwich
                    norms + softcaps (gemma2)
* ``mamba2``      — SSD block (mamba2)
* ``hybrid``      — zamba2: one shared-parameter attention block (invoked with
                    per-step LoRA deltas) + ``mamba_per_step`` mamba2 layers
                    per scan step

Scanning keeps the HLO size O(groups), not O(layers) — a 94-layer qwen3-moe
lowered at 512 devices stays tractable — and is what makes remat policies and
per-layer cache threading uniform.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from . import attention as attn
from . import moe as moe_mod
from . import ssm
from .common import (
    DENSE,
    GEMMA_PAIR,
    HYBRID,
    MAMBA2,
    MOE,
    BlockGroup,
    ModelConfig,
    ParamSpec,
    abstract_from_specs,
    axes_from_specs,
    cross_entropy_loss,
    init_from_specs,
    register_param_specs,
    rms_norm,
    softcap,
    swiglu,
)

PS = ParamSpec


# =============================================================== param specs

def _attn_specs(cfg: ModelConfig) -> dict:
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    s = {
        "wq": PS((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": PS((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wv": PS((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wo": PS((h, hd, d), ("heads", "head_dim", "embed"), fan_in=h * hd),
    }
    if cfg.qk_norm:
        s["q_norm"] = PS((hd,), (None,), init="ones")
        s["k_norm"] = PS((hd,), (None,), init="ones")
    return s


def _mlp_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": PS((d, f), ("embed", "mlp")),
        "w_up": PS((d, f), ("embed", "mlp")),
        "w_down": PS((f, d), ("mlp", "embed")),
    }


def _moe_specs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    return {
        "router": PS((d, e), ("embed", None), dtype=jnp.float32),
        "w_gate": PS((e, d, f), ("experts", "embed", "mlp"), fan_in=d),
        "w_up": PS((e, d, f), ("experts", "embed", "mlp"), fan_in=d),
        "w_down": PS((e, f, d), ("experts", "mlp", "embed"), fan_in=f),
    }


def _mamba_specs(cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.ssm_d_inner
    h, n, gn = cfg.ssm_nheads, cfg.ssm_state, cfg.ssm_groups * cfg.ssm_state
    w = cfg.ssm_conv_width
    return {
        "in_z": PS((d, di), ("embed", "ssm_inner")),
        "in_x": PS((d, di), ("embed", "ssm_inner")),
        "in_b": PS((d, gn), ("embed", None)),
        "in_c": PS((d, gn), ("embed", None)),
        "in_dt": PS((d, h), ("embed", "ssm_heads")),
        "conv_x_w": PS((di, w), ("ssm_inner", None)),
        "conv_x_b": PS((di,), ("ssm_inner",), init="zeros"),
        "conv_b_w": PS((gn, w), (None, None)),
        "conv_b_b": PS((gn,), (None,), init="zeros"),
        "conv_c_w": PS((gn, w), (None, None)),
        "conv_c_b": PS((gn,), (None,), init="zeros"),
        "a_log": PS((h,), ("ssm_heads",), init="zeros"),
        "d_skip": PS((h,), ("ssm_heads",), init="ones"),
        "dt_bias": PS((h,), ("ssm_heads",), init="zeros"),
        "norm_w": PS((di,), ("ssm_inner",), init="ones"),
        "out_proj": PS((di, d), ("ssm_inner", "embed")),
    }


def _norm(d: int) -> PS:
    return PS((d,), (None,), init="ones")


def _dense_layer_specs(cfg: ModelConfig, moe: bool) -> dict:
    s = {
        "ln1": _norm(cfg.d_model),
        "ln2": _norm(cfg.d_model),
        "attn": _attn_specs(cfg),
        "mlp": _moe_specs(cfg) if moe else _mlp_specs(cfg),
    }
    if cfg.gemma_norm_plus_one:  # gemma2 sandwich norms
        s["ln1_post"] = _norm(cfg.d_model)
        s["ln2_post"] = _norm(cfg.d_model)
    return s


def _lora_specs(cfg: ModelConfig) -> dict:
    d, h, kvh, hd, r = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd,
                        cfg.shared_attn_lora_rank)
    s = {}
    for name, heads in (("q", h), ("k", kvh), ("v", kvh)):
        s[f"{name}_a"] = PS((d, r), ("embed", None))
        s[f"{name}_b"] = PS((r, heads, hd), (None, "heads", "head_dim"),
                            init="zeros")
    return s


def _group_step_specs(cfg: ModelConfig, g: BlockGroup) -> dict:
    if g.kind == DENSE:
        return _dense_layer_specs(cfg, moe=False)
    if g.kind == MOE:
        return _dense_layer_specs(cfg, moe=True)
    if g.kind == GEMMA_PAIR:
        return {"local": _dense_layer_specs(cfg, moe=False),
                "global": _dense_layer_specs(cfg, moe=False)}
    if g.kind == MAMBA2:
        return {"ln": _norm(cfg.d_model), "mamba": _mamba_specs(cfg)}
    if g.kind == HYBRID:
        step = {
            "mamba_ln": _stack(_norm(cfg.d_model), g.mamba_per_step),
            "mamba": _stack_tree(_mamba_specs(cfg), g.mamba_per_step),
            "attn_ln": _norm(cfg.d_model),
        }
        if cfg.shared_attn_lora_rank:
            step["lora"] = _lora_specs(cfg)
        return step
    raise ValueError(f"unknown group kind {g.kind}")


def _stack(spec: PS, n: int) -> PS:
    return dataclasses.replace(spec, shape=(n, *spec.shape),
                               axes=("layers", *spec.axes))


def _stack_tree(tree, n: int):
    return jax.tree.map(lambda s: _stack(s, n), tree,
                        is_leaf=lambda x: isinstance(x, PS))


def param_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    specs: dict[str, Any] = {
        "embed": PS((v, d), ("vocab", "embed"), fan_in=d),
        "final_norm": _norm(d),
        "groups": [
            _stack_tree(_group_step_specs(cfg, g), g.count)
            for g in cfg.groups
        ],
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = PS((d, v), ("embed", "vocab"))
    if any(g.kind == HYBRID for g in cfg.groups):
        specs["shared_attn"] = {
            "attn": _attn_specs(cfg),
            "mlp": _mlp_specs(cfg),
            "ln2": _norm(d),
        }
    return specs


register_param_specs(param_specs)


# ============================================================== layer bodies

def _dense_block(cfg: ModelConfig, g: BlockGroup, p, x, positions, *,
                 window, mrope, is_moe: bool):
    plus1 = cfg.gemma_norm_plus_one
    h = attn.self_attention_prefill(
        cfg, p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps, plus1),
        positions, window=window, mrope_positions=mrope)
    if "ln1_post" in p:
        h = rms_norm(h, p["ln1_post"], cfg.norm_eps, plus1)
    x = x + h
    x = constrain(x, "batch", "act_seq", "act_embed")
    z = rms_norm(x, p["ln2"], cfg.norm_eps, plus1)
    if is_moe:
        y, aux = moe_mod.moe_block(cfg, p["mlp"], z)
    else:
        y, aux = swiglu(z, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                        p["mlp"]["w_down"]), 0.0
    if "ln2_post" in p:
        y = rms_norm(y, p["ln2_post"], cfg.norm_eps, plus1)
    return x + y, aux


def _fold_lora(p_attn: dict, lora: Optional[dict]) -> dict:
    """Fold per-invocation LoRA deltas into effective qkv weights (zamba2):
    W_eff = W_shared + A @ B. Exact, and lets both prefill and decode reuse
    the standard attention paths."""
    if lora is None:
        return p_attn
    eff = dict(p_attn)
    for name, w in (("q", "wq"), ("k", "wk"), ("v", "wv")):
        delta = jnp.einsum("dr,rhk->dhk", lora[f"{name}_a"],
                           lora[f"{name}_b"]).astype(p_attn[w].dtype)
        eff[w] = p_attn[w] + delta
    return eff


def _shared_attn_block(cfg: ModelConfig, shared, lora, x, xn, positions):
    """zamba2 shared transformer block; x = residual, xn = pre-normed input."""
    p_attn = _fold_lora(shared["attn"], lora)
    h = attn.self_attention_prefill(cfg, p_attn, xn, positions,
                                    window=cfg.sliding_window)
    x = x + h
    z2 = rms_norm(x, shared["ln2"], cfg.norm_eps)
    mlpp = shared["mlp"]
    return x + swiglu(z2, mlpp["w_gate"], mlpp["w_up"], mlpp["w_down"])


# ============================================================ prefill forward

def _group_prefill(cfg: ModelConfig, g: BlockGroup, gp, x, positions, *,
                   mrope, shared):
    """Run one block group via lax.scan over its stacked params."""

    def step(carry, layer_p):
        x, aux = carry
        if g.kind == DENSE:
            x, a = _dense_block(cfg, g, layer_p, x, positions,
                                window=g.window, mrope=mrope, is_moe=False)
        elif g.kind == MOE:
            x, a = _dense_block(cfg, g, layer_p, x, positions,
                                window=g.window, mrope=mrope, is_moe=True)
        elif g.kind == GEMMA_PAIR:
            x, a1 = _dense_block(cfg, g, layer_p["local"], x, positions,
                                 window=cfg.sliding_window, mrope=mrope,
                                 is_moe=False)
            x, a2 = _dense_block(cfg, g, layer_p["global"], x, positions,
                                 window=None, mrope=mrope, is_moe=False)
            a = a1 + a2
        elif g.kind == MAMBA2:
            x = x + ssm.mamba2_prefill(
                cfg, layer_p["mamba"], rms_norm(x, layer_p["ln"], cfg.norm_eps))
            a = 0.0
        elif g.kind == HYBRID:
            xn = rms_norm(x, layer_p["attn_ln"], cfg.norm_eps)
            x = _shared_attn_block(cfg, shared, layer_p.get("lora"), x, xn,
                                   positions)
            for i in range(g.mamba_per_step):
                sub = jax.tree.map(lambda a_: a_[i], layer_p["mamba"])
                ln = layer_p["mamba_ln"][i]
                x = x + ssm.mamba2_prefill(cfg, sub,
                                           rms_norm(x, ln, cfg.norm_eps))
            a = 0.0
        else:
            raise ValueError(g.kind)
        x = constrain(x, "batch", "act_seq", "act_embed")
        return (x, aux + a), None

    carry0 = (x, jnp.float32(0.0))
    if cfg.remat and cfg.remat_policy == "two_level" and \
            g.count % cfg.remat_block == 0 and g.count > cfg.remat_block:
        # nested sqrt-N checkpointing: outer scan over blocks of layers,
        # inner scan over layers within a block; residual footprint drops
        # from O(L) to O(L/G + G) at one extra forward recompute.
        blocks = g.count // cfg.remat_block

        def block_step(carry, block_params):
            return jax.lax.scan(jax.checkpoint(step), carry, block_params)

        gp_blocked = jax.tree.map(
            lambda a: a.reshape(blocks, cfg.remat_block, *a.shape[1:]), gp)
        (x, aux), _ = jax.lax.scan(jax.checkpoint(block_step), carry0,
                                   gp_blocked)
        return x, aux
    if cfg.remat:
        step = jax.checkpoint(step)
    (x, aux), _ = jax.lax.scan(step, carry0, gp)
    return x, aux


def embed_tokens(cfg: ModelConfig, params, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.gemma_norm_plus_one:           # gemma scales embeddings
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x.astype(cfg.activation_dtype)


def lm_logits(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.gemma_norm_plus_one)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    # f32 logits are the single biggest training tensor; pin them sharded
    # (act_seq claims 'model' when S divides; decode's S=1 falls back to
    # vocab->model) instead of letting GSPMD replicate.
    return constrain(logits, "batch", "act_seq", "vocab")


def forward(cfg: ModelConfig, params, tokens: jax.Array, *,
            input_embeds: Optional[jax.Array] = None,
            mrope_positions: Optional[jax.Array] = None,
            last_only: bool = False) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits (B,S,V) f32, moe aux loss).

    ``last_only``: project logits for the final position only (serving
    prefill) — avoids materializing the (B,S,V) tensor.
    """
    if input_embeds is not None:
        x = input_embeds.astype(cfg.activation_dtype)
    else:
        x = embed_tokens(cfg, params, tokens)
    x = constrain(x, "batch", "act_seq", "act_embed")
    bsz, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (bsz, s))
    shared = params.get("shared_attn")
    aux_total = jnp.float32(0.0)
    for g, gp in zip(cfg.groups, params["groups"]):
        x, aux = _group_prefill(cfg, g, gp, x, positions,
                                mrope=mrope_positions, shared=shared)
        aux_total = aux_total + aux
    if last_only:
        x = x[:, -1:]
    return lm_logits(cfg, params, x), aux_total


def loss_fn(cfg: ModelConfig, params, batch: dict) -> tuple[jax.Array, dict]:
    logits, aux = forward(
        cfg, params, batch["tokens"],
        input_embeds=batch.get("input_embeds"),
        mrope_positions=batch.get("mrope_positions"))
    ce = cross_entropy_loss(logits, batch["targets"], batch.get("mask"))
    total = ce + cfg.router_aux_coef * aux
    return total, {"ce": ce, "aux": aux}


# =================================================================== caching

def _kv_shapes(cfg: ModelConfig, batch: int, max_len: int, window, dtype):
    length = min(window, max_len) if window is not None else max_len
    return ((batch, length, cfg.num_kv_heads, cfg.hd), dtype)


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int, dtype) -> list:
    """Per-group cache shape trees, mirroring params['groups'] structure."""
    out = []
    for g in cfg.groups:
        if g.kind in (DENSE, MOE):
            sh, dt = _kv_shapes(cfg, batch, max_len, g.window, dtype)
            entry = {"k": ((g.count, *sh), dt), "v": ((g.count, *sh), dt)}
        elif g.kind == GEMMA_PAIR:
            lsh, _ = _kv_shapes(cfg, batch, max_len, cfg.sliding_window, dtype)
            gsh, _ = _kv_shapes(cfg, batch, max_len, None, dtype)
            entry = {
                "local": {"k": ((g.count, *lsh), dtype),
                          "v": ((g.count, *lsh), dtype)},
                "global": {"k": ((g.count, *gsh), dtype),
                           "v": ((g.count, *gsh), dtype)},
            }
        elif g.kind == MAMBA2:
            st = ssm.mamba2_state_shapes(cfg, batch, dtype)
            entry = {k: ((g.count, *sh), dt) for k, (sh, dt) in st.items()}
        elif g.kind == HYBRID:
            st = ssm.mamba2_state_shapes(cfg, batch, dtype)
            sh, dt = _kv_shapes(cfg, batch, max_len, cfg.sliding_window, dtype)
            entry = {
                "mamba": {k: ((g.count, g.mamba_per_step, *s_), d_)
                          for k, (s_, d_) in st.items()},
                "attn": {"k": ((g.count, *sh), dt), "v": ((g.count, *sh), dt)},
            }
        else:
            raise ValueError(g.kind)
        out.append(entry)
    return out


def _map_shapes(tree, fn):
    return jax.tree.map(lambda leaf: fn(*leaf), tree,
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                        and isinstance(x[0], tuple))


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.activation_dtype
    return _map_shapes(cache_shapes(cfg, batch, max_len, dtype), jnp.zeros)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.activation_dtype
    return _map_shapes(cache_shapes(cfg, batch, max_len, dtype),
                       jax.ShapeDtypeStruct)


def cache_logical_axes(cfg: ModelConfig, batch: int, max_len: int):
    """Logical axes tree matching the cache structure."""
    kv_axes = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
    ssm_axes = {
        "ssm": ("layers", "batch", "ssm_heads", None, None),
        "conv_x": ("layers", "batch", None, "ssm_inner"),
        "conv_b": ("layers", "batch", None, None),
        "conv_c": ("layers", "batch", None, None),
    }
    out = []
    for g in cfg.groups:
        if g.kind in (DENSE, MOE):
            entry = {"k": kv_axes, "v": kv_axes}
        elif g.kind == GEMMA_PAIR:
            entry = {"local": {"k": kv_axes, "v": kv_axes},
                     "global": {"k": kv_axes, "v": kv_axes}}
        elif g.kind == MAMBA2:
            entry = dict(ssm_axes)
        elif g.kind == HYBRID:
            entry = {
                "mamba": {k: (v[0], None, *v[1:]) for k, v in ssm_axes.items()},
                "attn": {"k": kv_axes, "v": kv_axes},
            }
        out.append(entry)
    return out


# ===================================================== prefill-with-cache

def _dense_block_cached(cfg: ModelConfig, p, x, positions, fresh_cache, *,
                        window, mrope):
    """Prefill step that also fills the decode cache for this layer."""
    plus1 = cfg.gemma_norm_plus_one
    h, (k, v) = attn.self_attention_prefill(
        cfg, p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps, plus1),
        positions, window=window, mrope_positions=mrope, return_kv=True)
    new_cache = attn.fill_kv_cache(fresh_cache, k, v, window)
    if "ln1_post" in p:
        h = rms_norm(h, p["ln1_post"], cfg.norm_eps, plus1)
    x = x + h
    z = rms_norm(x, p["ln2"], cfg.norm_eps, plus1)
    if "router" in p["mlp"]:
        y, _ = moe_mod.moe_block(cfg, p["mlp"], z)
    else:
        y = swiglu(z, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    if "ln2_post" in p:
        y = rms_norm(y, p["ln2_post"], cfg.norm_eps, plus1)
    return x + y, new_cache


def _group_prefill_cached(cfg: ModelConfig, g: BlockGroup, gp, gcache, x,
                          positions, *, mrope, shared):
    """Prefill one group while producing its decode cache (scan ys)."""

    def step(x, layer):
        layer_p, fresh = layer
        if g.kind in (DENSE, MOE):
            x, nc = _dense_block_cached(cfg, layer_p, x, positions, fresh,
                                        window=g.window, mrope=mrope)
        elif g.kind == GEMMA_PAIR:
            x, nc_l = _dense_block_cached(cfg, layer_p["local"], x, positions,
                                          fresh["local"],
                                          window=cfg.sliding_window, mrope=mrope)
            x, nc_g = _dense_block_cached(cfg, layer_p["global"], x, positions,
                                          fresh["global"], window=None,
                                          mrope=mrope)
            nc = {"local": nc_l, "global": nc_g}
        elif g.kind == MAMBA2:
            y, st = ssm.mamba2_prefill(
                cfg, layer_p["mamba"], rms_norm(x, layer_p["ln"], cfg.norm_eps),
                return_state=True)
            x = x + y
            nc = jax.tree.map(lambda f, s: s.astype(f.dtype), fresh, st)
        elif g.kind == HYBRID:
            xn = rms_norm(x, layer_p["attn_ln"], cfg.norm_eps)
            p_attn = _fold_lora(shared["attn"], layer_p.get("lora"))
            h, (k, v) = attn.self_attention_prefill(
                cfg, p_attn, xn, positions, window=cfg.sliding_window,
                return_kv=True)
            nc_attn = attn.fill_kv_cache(fresh["attn"], k, v,
                                         cfg.sliding_window)
            x = x + h
            z2 = rms_norm(x, shared["ln2"], cfg.norm_eps)
            mlpp = shared["mlp"]
            x = x + swiglu(z2, mlpp["w_gate"], mlpp["w_up"], mlpp["w_down"])
            new_m = []
            for i in range(g.mamba_per_step):
                sub = jax.tree.map(lambda a_: a_[i], layer_p["mamba"])
                ln = layer_p["mamba_ln"][i]
                fresh_i = jax.tree.map(lambda a_: a_[i], fresh["mamba"])
                y, st = ssm.mamba2_prefill(cfg, sub,
                                           rms_norm(x, ln, cfg.norm_eps),
                                           return_state=True)
                x = x + y
                new_m.append(jax.tree.map(lambda f, s: s.astype(f.dtype),
                                          fresh_i, st))
            nc = {"mamba": jax.tree.map(lambda *a_: jnp.stack(a_), *new_m),
                  "attn": nc_attn}
        else:
            raise ValueError(g.kind)
        x = constrain(x, "batch", "act_seq", "act_embed")
        return x, nc

    x, new_cache = jax.lax.scan(step, x, (gp, gcache))
    return x, new_cache


def prefill(cfg: ModelConfig, params, tokens: jax.Array, max_len: int, *,
            input_embeds: Optional[jax.Array] = None,
            mrope_positions: Optional[jax.Array] = None,
            cache=None, cache_dtype=None):
    """Full-sequence forward that also builds a decode-ready cache.

    Returns (logits (B,S,V) f32, cache at position S).
    """
    if input_embeds is not None:
        x = input_embeds.astype(cfg.activation_dtype)
        bsz, s = x.shape[:2]
    else:
        bsz, s = tokens.shape
        x = embed_tokens(cfg, params, tokens)
    if cache is None:
        cache = init_cache(cfg, bsz, max_len, cache_dtype)
    x = constrain(x, "batch", "act_seq", "act_embed")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (bsz, s))
    shared = params.get("shared_attn")
    new_caches = []
    for g, gp, gc in zip(cfg.groups, params["groups"], cache):
        x, nc = _group_prefill_cached(cfg, g, gp, gc, x, positions,
                                      mrope=mrope_positions, shared=shared)
        new_caches.append(nc)
    return lm_logits(cfg, params, x), new_caches


# ============================================================ decode forward

def _dense_block_decode(cfg: ModelConfig, p, x, cache, t, *, window, mrope):
    plus1 = cfg.gemma_norm_plus_one
    h, new_cache = attn.self_attention_decode(
        cfg, p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps, plus1), cache, t,
        window=window, mrope_positions=mrope)
    if "ln1_post" in p:
        h = rms_norm(h, p["ln1_post"], cfg.norm_eps, plus1)
    x = x + h
    z = rms_norm(x, p["ln2"], cfg.norm_eps, plus1)
    if "router" in p["mlp"]:
        y, _ = moe_mod.moe_block(cfg, p["mlp"], z)
    else:
        y = swiglu(z, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    if "ln2_post" in p:
        y = rms_norm(y, p["ln2_post"], cfg.norm_eps, plus1)
    return x + y, new_cache


def _group_decode(cfg: ModelConfig, g: BlockGroup, gp, gcache, x, t, *,
                  mrope, shared):
    def step(x, layer):
        layer_p, layer_c = layer
        if g.kind in (DENSE, MOE):
            x, nc = _dense_block_decode(cfg, layer_p, x, layer_c, t,
                                        window=g.window, mrope=mrope)
        elif g.kind == GEMMA_PAIR:
            x, nc_l = _dense_block_decode(cfg, layer_p["local"], x,
                                          layer_c["local"], t,
                                          window=cfg.sliding_window, mrope=mrope)
            x, nc_g = _dense_block_decode(cfg, layer_p["global"], x,
                                          layer_c["global"], t,
                                          window=None, mrope=mrope)
            nc = {"local": nc_l, "global": nc_g}
        elif g.kind == MAMBA2:
            y, nc = ssm.mamba2_decode(
                cfg, layer_p["mamba"],
                rms_norm(x, layer_p["ln"], cfg.norm_eps), layer_c)
            x = x + y
        elif g.kind == HYBRID:
            xa = rms_norm(x, layer_p["attn_ln"], cfg.norm_eps)
            x, nc_attn = _shared_attn_decode(cfg, shared, layer_p.get("lora"),
                                             x, xa, layer_c["attn"], t)
            new_m = []
            for i in range(g.mamba_per_step):
                sub_p = jax.tree.map(lambda a_: a_[i], layer_p["mamba"])
                sub_c = jax.tree.map(lambda a_: a_[i], layer_c["mamba"])
                ln = layer_p["mamba_ln"][i]
                y, nm = ssm.mamba2_decode(cfg, sub_p,
                                          rms_norm(x, ln, cfg.norm_eps), sub_c)
                x = x + y
                new_m.append(nm)
            nc = {"mamba": jax.tree.map(lambda *a_: jnp.stack(a_), *new_m),
                  "attn": nc_attn}
        else:
            raise ValueError(g.kind)
        return x, nc

    x, new_cache = jax.lax.scan(step, x, (gp, gcache))
    return x, new_cache


def _dense_block_verify(cfg: ModelConfig, p, x, cache, t):
    """K-position teacher-forced continuation of one dense/moe block: same
    math as K sequential :func:`_dense_block_decode` calls, one weight
    pass (the speculative-verify hot path). Full caches only."""
    plus1 = cfg.gemma_norm_plus_one
    h, new_cache = attn.self_attention_verify(
        cfg, p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps, plus1), cache, t)
    if "ln1_post" in p:
        h = rms_norm(h, p["ln1_post"], cfg.norm_eps, plus1)
    x = x + h
    z = rms_norm(x, p["ln2"], cfg.norm_eps, plus1)
    if "router" in p["mlp"]:
        y, _ = moe_mod.moe_block(cfg, p["mlp"], z)
    else:
        y = swiglu(z, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    if "ln2_post" in p:
        y = rms_norm(y, p["ln2_post"], cfg.norm_eps, plus1)
    return x + y, new_cache


def _group_verify(cfg: ModelConfig, g: BlockGroup, gp, gcache, x, t):
    """Verify-sweep one group: x (B,K,D) known tokens at positions
    t..t+K-1. Only full-cache attention groups qualify (dense/moe,
    no window) — exactly the gate serving places on paged/speculative
    executors via ``StageExecutor.full_cache``."""
    if g.kind not in (DENSE, MOE) or g.window is not None:
        raise ValueError(
            f"verify sweep needs full-cache attention, got {g.kind}")

    def step(x, layer):
        layer_p, layer_c = layer
        x, nc = _dense_block_verify(cfg, layer_p, x, layer_c, t)
        return x, nc

    x, new_cache = jax.lax.scan(step, x, (gp, gcache))
    return x, new_cache


def _shared_attn_decode(cfg: ModelConfig, shared, lora, x, xn, cache, t):
    p_attn = _fold_lora(shared["attn"], lora)
    h, new_cache = attn.self_attention_decode(
        cfg, p_attn, xn, cache, t, window=cfg.sliding_window)
    x = x + h
    z2 = rms_norm(x, shared["ln2"], cfg.norm_eps)
    mlpp = shared["mlp"]
    return x + swiglu(z2, mlpp["w_gate"], mlpp["w_up"], mlpp["w_down"]), \
        new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens: jax.Array,
                t: jax.Array, *,
                mrope_positions: Optional[jax.Array] = None
                ) -> tuple[jax.Array, Any]:
    """One decode step. tokens (B, 1) int32; t scalar int32 position.

    Returns (logits (B, V) f32, new cache).
    """
    x = embed_tokens(cfg, params, tokens)
    shared = params.get("shared_attn")
    new_caches = []
    for g, gp, gc in zip(cfg.groups, params["groups"], cache):
        x, nc = _group_decode(cfg, g, gp, gc, x, t,
                              mrope=mrope_positions, shared=shared)
        new_caches.append(nc)
    logits = lm_logits(cfg, params, x)[:, 0]
    return logits, new_caches


# ================================================================ public API

class LanguageModel:
    """Uniform handle over all decoder-only families."""

    def __init__(self, cfg: ModelConfig) -> None:
        self.cfg = cfg

    # params
    def param_specs(self):
        return param_specs(self.cfg)

    def init(self, key: jax.Array):
        return init_from_specs(self.param_specs(), key, self.cfg)

    def abstract_params(self):
        return abstract_from_specs(self.param_specs(), self.cfg)

    def logical_axes(self):
        return axes_from_specs(self.param_specs())

    # compute
    def forward(self, params, tokens, **kw):
        return forward(self.cfg, params, tokens, **kw)

    def prefill(self, params, tokens, max_len, **kw):
        return prefill(self.cfg, params, tokens, max_len, **kw)

    def loss(self, params, batch):
        return loss_fn(self.cfg, params, batch)

    def decode_step(self, params, cache, tokens, t, **kw):
        return decode_step(self.cfg, params, cache, tokens, t, **kw)

    # cache
    def init_cache(self, batch, max_len, dtype=None):
        return init_cache(self.cfg, batch, max_len, dtype)

    def abstract_cache(self, batch, max_len, dtype=None):
        return abstract_cache(self.cfg, batch, max_len, dtype)

    def cache_logical_axes(self, batch, max_len):
        return cache_logical_axes(self.cfg, batch, max_len)

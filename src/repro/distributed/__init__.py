from .sharding import (
    AxisRules,
    INFER_RULES,
    LONG_DECODE_RULES,
    TRAIN_RULES,
    axis_rules,
    constrain,
    current_mesh,
    current_rules,
    logical_sharding,
    logical_spec,
    shard_map_compat,
    tree_logical_sharding,
    tree_shardings,
)

__all__ = [
    "AxisRules", "INFER_RULES", "LONG_DECODE_RULES", "TRAIN_RULES",
    "axis_rules", "constrain", "current_mesh", "current_rules",
    "logical_sharding", "logical_spec", "shard_map_compat",
    "tree_logical_sharding", "tree_shardings",
]

"""Logical-axis sharding rules (MaxText-style) for the production meshes.

Model code annotates params/activations with *logical* axis names
(ParamSpec.axes, ``constrain``). A rule set maps logical names to mesh axes;
``axis_rules(rules, mesh)`` installs the mapping for the duration of a trace.
Outside any context (smoke tests on one CPU device) every helper degrades to
a no-op, so model code never branches on distribution.

Resolution is **divisibility-aware**: an axis whose dimension does not divide
its target mesh axes is skipped *without consuming* the mesh axis, so a later
axis can claim it. This is how GQA KV caches fall back from kv_heads->model
(zamba2: kv=32 over 16 ranks) to head_dim->model (llama/qwen/yi/gemma: kv<16)
with one annotation, and how odd vocabularies (50280, 51865) stay replicated
while clean ones shard.

Rule sets:

* ``TRAIN_RULES``   — batch over (pod, data); tensor parallel over ``model``;
  *sequence-parallel residual stream* (act_seq->model) so per-layer remat
  checkpoints stay O(S/16); FSDP over ``data`` via the ``embed`` dim of
  weights (required: yi-34b AdamW state would not fit data-replicated).
* ``INFER_RULES``   — params replicated over ``data``, TP over ``model``;
  act_seq->model balances prefill compute even when heads don't divide.
* ``LONG_DECODE_RULES`` — batch=1 long-context decode: KV-cache *sequence*
  over ``data``, heads/head_dim over ``model``; batch replicated.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, tuple[str, ...], None]
AxisRules = dict[str, MeshAxes]

TRAIN_RULES: AxisRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "act_seq": "model",       # sequence-parallel residual stream
    "embed": "data",          # FSDP: weight d_model dim sharded over data
    "act_embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": "model",      # claimed only when kv_heads does not divide
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "layers": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "ssm_inner": "model",
    "conv": "model",
    "cache_seq": None,
    "frames": None,
}

INFER_RULES: AxisRules = dict(TRAIN_RULES, embed=None)

LONG_DECODE_RULES: AxisRules = dict(INFER_RULES, batch=None, act_seq=None,
                                    cache_seq="data")


class _Ctx(threading.local):
    def __init__(self) -> None:
        self.rules: Optional[AxisRules] = None
        self.mesh: Optional[Mesh] = None


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(rules: AxisRules, mesh: Mesh):
    prev = (_CTX.rules, _CTX.mesh)
    _CTX.rules, _CTX.mesh = rules, mesh
    try:
        yield
    finally:
        _CTX.rules, _CTX.mesh = prev


def current_rules() -> Optional[AxisRules]:
    return _CTX.rules


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def shard_map_compat(body, *, mesh, in_specs, out_specs,
                     check_vma: bool = True):
    """``jax.shard_map`` across jax versions: the top-level API (with its
    ``check_vma`` flag) only exists in newer jax; older releases ship it as
    ``jax.experimental.shard_map`` with the flag named ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def _axis_size(mesh: Mesh, names: tuple[str, ...]) -> int:
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def _resolve(rules: AxisRules, mesh: Mesh, axes: tuple[Optional[str], ...],
             shape: Optional[tuple[int, ...]] = None) -> P:
    """Map logical axes to a PartitionSpec.

    Drops mesh axes the mesh lacks (e.g. 'pod' on the single-pod mesh),
    never assigns one mesh axis twice, and — when ``shape`` is given — skips
    (without consuming) mesh axes that do not divide the dimension.
    """
    used: set[str] = set()
    spec: list = []
    for i, ax in enumerate(axes):
        target = rules.get(ax) if ax is not None else None
        if target is None:
            spec.append(None)
            continue
        names = (target,) if isinstance(target, str) else tuple(target)
        names = tuple(n for n in names if n in mesh.axis_names and n not in used)
        if shape is not None and names:
            # largest prefix of the requested axes that divides the dim
            while names and shape[i] % _axis_size(mesh, names) != 0:
                names = names[:-1]
        used.update(names)
        if not names:
            spec.append(None)
        elif len(names) == 1:
            spec.append(names[0])
        else:
            spec.append(names)
    return P(*spec)


def logical_spec(axes: tuple[Optional[str], ...],
                 shape: Optional[tuple[int, ...]] = None) -> P:
    if _CTX.rules is None or _CTX.mesh is None:
        return P()
    return _resolve(_CTX.rules, _CTX.mesh, axes, shape)


def logical_sharding(axes: tuple[Optional[str], ...],
                     shape: Optional[tuple[int, ...]] = None
                     ) -> Optional[NamedSharding]:
    if _CTX.rules is None or _CTX.mesh is None:
        return None
    return NamedSharding(_CTX.mesh, _resolve(_CTX.rules, _CTX.mesh, axes,
                                             shape))


def tree_logical_sharding(axes_tree):
    """Map a pytree of logical-axes tuples to NamedShardings (or None).

    Shape-unaware (no divisibility skipping); prefer ``tree_shardings``.
    """
    if _CTX.rules is None or _CTX.mesh is None:
        return None
    return jax.tree.map(
        lambda axes: logical_sharding(tuple(axes)),
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))


def tree_shardings(shaped_tree, axes_tree):
    """Divisibility-aware shardings: ``shaped_tree`` leaves carry .shape
    (arrays or ShapeDtypeStructs), ``axes_tree`` the congruent logical axes."""
    if _CTX.rules is None or _CTX.mesh is None:
        return None

    def one(leaf, axes):
        axes = tuple(axes)
        assert len(axes) == len(leaf.shape), (axes, leaf.shape)
        return logical_sharding(axes, tuple(leaf.shape))

    axes_leaves = jax.tree.leaves(axes_tree,
                                  is_leaf=lambda x: isinstance(x, tuple))
    shaped_leaves, treedef = jax.tree.flatten(shaped_tree)
    assert len(axes_leaves) == len(shaped_leaves), \
        (len(axes_leaves), len(shaped_leaves))
    return jax.tree.unflatten(
        treedef, [one(l, a) for l, a in zip(shaped_leaves, axes_leaves)])


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a context."""
    if _CTX.rules is None or _CTX.mesh is None:
        return x
    spec = _resolve(_CTX.rules, _CTX.mesh, tuple(axes), tuple(x.shape))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

Lowers + compiles every (architecture × input shape) combination against the
production meshes — 16x16 single-pod and 2x16x16 multi-pod — and records
memory_analysis / cost_analysis / collective schedule per combo. This is the
deployment proof: a sharding mismatch, compile-time OOM, or unsupported
collective fails loudly here.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod --out artifacts/
"""
import argparse
import json
import sys
import time
import traceback

import jax  # noqa: E402  (device count already forced above)

from repro.configs import ARCH_IDS  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.launch.lowering import SkipCombo, run_combo  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", choices=list(ARCH_IDS))
    ap.add_argument("--shape", action="append", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true",
                    help="run ONLY the 2x16x16 multi-pod mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--attn-impl", default="auto")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    assert jax.device_count() == 512, jax.device_count()
    archs = args.arch or list(ARCH_IDS)
    shapes = args.shape or list(SHAPES)
    meshes = []
    if args.both_meshes:
        meshes = [False, True]
    else:
        meshes = [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_tag = "pod2" if multi_pod else "pod1"
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}__{shape}__{mesh_tag}"
                path = os.path.join(args.out, tag + ".json")
                t0 = time.monotonic()
                try:
                    result = run_combo(arch, shape, mesh,
                                       attn_impl=args.attn_impl)
                    result["status"] = "ok"
                    print(f"[ok]   {tag}: dominant={result['dominant']} "
                          f"compute={result['compute_s']:.4f}s "
                          f"memory={result['memory_s']:.4f}s "
                          f"collective={result['collective_s']:.4f}s "
                          f"state={result['peak_state_bytes_per_dev']/2**30:.2f}GiB "
                          f"({time.monotonic()-t0:.0f}s)")
                except SkipCombo as e:
                    result = {"arch": arch, "shape": shape, "status": "skip",
                              "reason": str(e)}
                    print(f"[skip] {tag}: {e}")
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures += 1
                    result = {"arch": arch, "shape": shape, "status": "fail",
                              "error": f"{type(e).__name__}: {e}",
                              "traceback": traceback.format_exc()[-4000:]}
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
                with open(path, "w") as f:
                    json.dump(result, f, indent=1, default=str)
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

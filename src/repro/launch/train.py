"""Training launcher: real steps on the local device(s), or distributed
when run under a TPU runtime (the mesh adapts to whatever jax sees).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \\
      --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import build_model
from repro.training import (
    AdamWConfig,
    init_opt_state,
    make_stream,
    make_train_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.arch_id} params={n_params/1e6:.1f}M "
          f"devices={jax.device_count()}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    stream = make_stream(cfg, args.batch, args.seq, seed=args.seed)

    t0 = time.monotonic()
    for step in range(1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == 1:
            dt = time.monotonic() - t0
            tok_s = step * args.batch * args.seq / dt
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"ce={float(metrics['ce']):.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} tok/s={tok_s:.0f}")
    if args.checkpoint_dir:
        out = save_checkpoint(args.checkpoint_dir, args.steps,
                              {"params": params, "opt": opt_state})
        print(f"checkpoint -> {out}")


if __name__ == "__main__":
    main()

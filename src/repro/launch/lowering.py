"""Dry-run lowering: build + lower + compile every (arch × shape × mesh)
combination, and extract the roofline terms from the compiled artifact.

Pure library (no device-count manipulation) — dryrun.py forces the 512
placeholder devices before importing this; tests use an 8-device mesh.

Step kinds:
* ``train``   — full train_step (fwd + bwd + AdamW), FSDP+TP+sequence-parallel.
* ``prefill`` — serving prefill: last-position logits + decode-ready cache.
* ``decode``  — serve_step: ONE token against a seq_len-deep cache.
"""
from __future__ import annotations

import dataclasses
import re
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.shapes import (
    SHAPES,
    InputShape,
    batch_logical_axes,
    batch_specs,
    decode_specs,
    shape_applicable,
)
from repro.distributed import (
    INFER_RULES,
    LONG_DECODE_RULES,
    TRAIN_RULES,
    axis_rules,
    logical_sharding,
    tree_shardings,
)
from repro.models import build_model
from repro.training import AdamWConfig, abstract_opt_state, make_train_step
from repro.training.optimizer import opt_logical_axes
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


class SkipCombo(Exception):
    pass


def rules_for(cfg, shape: InputShape) -> dict:
    if shape.kind == "train":
        rules = dict(TRAIN_RULES)
    elif shape.name == "long_500k":
        rules = dict(LONG_DECODE_RULES)
    else:
        rules = dict(INFER_RULES)
    if cfg.num_experts >= 64 and shape.kind != "train":
        # qwen3-moe: 454 GB expert bank cannot be data-replicated at
        # inference; FSDP the expert F dim over 'data' (gathered per layer)
        rules["mlp"] = "data"
    if cfg.family in ("ssm", "hybrid") and shape.kind == "train":
        # §Perf (measured): with ssm_inner tensor-parallel, every layer pays
        # a residual-sized all-reduce (out_proj contraction) — ~390 GB/dev of
        # wire on mamba2 train. A 2.7B model doesn't need TP: go
        # FSDP-everywhere — batch over ALL 256 chips, weights fully sharded
        # over (data, model), no TP contractions at all. Two-level remat
        # bounds the (now seq-unsharded) checkpoint memory.
        rules.update({
            "batch": ("pod", "data", "model"),
            "act_seq": None,
            "embed": ("data", "model"),
            "heads": None, "kv_heads": None, "head_dim": None,
            "mlp": None, "vocab": None,
            "ssm_heads": None, "ssm_inner": "data", "conv": None,
        })
    return rules


def overrides_for(cfg, shape: InputShape) -> dict:
    if shape.kind == "train" and cfg.family in ("ssm", "hybrid"):
        return {"remat_policy": "two_level"}
    return {}


def _decode_max_len(cfg, shape: InputShape) -> int:
    return shape.seq_len


def build_lowered(arch: str, shape_name: str, mesh, *,
                  attn_impl: str = "auto", overrides: Optional[dict] = None):
    """Returns (lowered, meta dict). Raises SkipCombo for sanctioned skips."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise SkipCombo(why)
    tuned = dict(overrides_for(cfg, shape))
    tuned.update(overrides or {})
    cfg = cfg.with_(attn_impl=attn_impl, remat=(shape.kind == "train"),
                    **tuned)
    model = build_model(cfg)
    rules = rules_for(cfg, shape)

    with axis_rules(rules, mesh):
        aparams = model.abstract_params()
        p_ax = model.logical_axes()
        p_sh = tree_shardings(aparams, p_ax)

        if shape.kind == "train":
            step = make_train_step(model, AdamWConfig())
            aopt = abstract_opt_state(aparams)
            o_sh = tree_shardings(aopt, opt_logical_axes(p_ax))
            batch = batch_specs(cfg, shape)
            b_sh = tree_shardings(batch, batch_logical_axes(cfg))
            lowered = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),       # params/opt update in place
            ).lower(aparams, aopt, batch)

        elif shape.kind == "prefill":
            batch = batch_specs(cfg, shape)
            b_sh = tree_shardings(batch, batch_logical_axes(cfg))
            if cfg.family == "audio":
                def fn(p, b):
                    logits, _ = model.forward(p, b["tokens"],
                                              frames=b["frames"])
                    return logits[:, -1]
            else:
                def fn(p, b):
                    logits, _ = model.forward(
                        p, b["tokens"],
                        input_embeds=b.get("input_embeds"),
                        mrope_positions=b.get("mrope_positions"),
                        last_only=True)
                    return logits[:, 0]
            lowered = jax.jit(fn, in_shardings=(p_sh, b_sh)).lower(
                aparams, batch)

        else:  # decode
            kw = decode_specs(cfg, shape, model)
            acache = kw["cache"]
            c_ax = model.cache_logical_axes(shape.global_batch, shape.seq_len)
            c_sh = tree_shardings(acache, c_ax)
            tok_sh = logical_sharding(("batch", None),
                                      tuple(kw["tokens"].shape))
            t_sh = logical_sharding((), ())
            if cfg.family == "vlm":
                mp_sh = logical_sharding((None, "batch", None),
                                         tuple(kw["mrope_positions"].shape))

                def fn(p, c, tk, t, mp):
                    return model.decode_step(p, c, tk, t, mrope_positions=mp)

                lowered = jax.jit(
                    fn, in_shardings=(p_sh, c_sh, tok_sh, t_sh, mp_sh),
                    out_shardings=(None, c_sh),
                    donate_argnums=(1,),     # KV cache updates in place
                ).lower(aparams, acache, kw["tokens"], kw["t"],
                        kw["mrope_positions"])
            else:
                def fn(p, c, tk, t):
                    return model.decode_step(p, c, tk, t)

                lowered = jax.jit(
                    fn, in_shardings=(p_sh, c_sh, tok_sh, t_sh),
                    out_shardings=(None, c_sh),
                    donate_argnums=(1,),     # KV cache updates in place
                ).lower(aparams, acache, kw["tokens"], kw["t"])

    meta = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "devices": mesh.devices.size,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "tokens": shape.global_batch * (shape.seq_len
                                        if shape.kind != "decode" else 1),
    }
    return lowered, meta


# ------------------------------------------------------ collective parsing

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"= (?P<shapes>[^=]*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> list[dict]:
    """Per-device wire bytes for every collective op in the compiled HLO."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        result_bytes = _shape_bytes(m.group("shapes"))
        gm = _GROUPS_RE.search(line)
        n = int(gm.group(2)) if gm else 1
        if n <= 1:
            wire = 0.0
        elif op == "all-reduce":
            wire = 2.0 * (n - 1) / n * result_bytes
        elif op == "all-gather":
            wire = (n - 1) / n * result_bytes        # result = gathered
        elif op == "reduce-scatter":
            wire = (n - 1) * result_bytes            # result = one shard
        elif op == "all-to-all":
            wire = (n - 1) / n * result_bytes
        else:                                        # collective-permute
            wire = float(result_bytes)
        out.append({"op": op, "bytes": result_bytes, "group": n,
                    "wire_bytes": wire, "line": line.strip()[:160]})
    return out


def analyze(lowered, compiled, meta: dict) -> dict:
    """Roofline terms (seconds, per device) from the compiled artifact.

    FLOPs/bytes/collectives come from the loop-aware HLO analyzer
    (launch/hlo_cost.py) — XLA's own cost_analysis counts while bodies once,
    which undercounts scanned-layer models by orders of magnitude; its
    numbers are still recorded as ``xla_*`` for reference. Peak memory comes
    from XLA's memory_analysis (loop bodies don't multiply residency).
    """
    from .hlo_cost import analyze_hlo_text

    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):
        # jax <= 0.4.x returns [dict] (one per device program); newer
        # releases return the dict directly
        xla_cost = xla_cost[0] if xla_cost else {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    own = analyze_hlo_text(hlo)

    flops = own["flops"]
    bytes_accessed = own["bytes"]
    wire = own["wire_bytes"]
    by_op = own["collectives_by_op"]

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_accessed / HBM_BW
    collective_s = wire / ICI_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]

    model_flops = 6 * meta["active_params"] * meta["tokens"]
    if meta["kind"] == "train":
        model_flops *= 1.0           # 6ND already includes fwd+bwd convention
    else:
        model_flops = 2 * meta["active_params"] * meta["tokens"]
    per_dev_model_flops = model_flops / meta["devices"]

    return {
        **meta,
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": bytes_accessed,
        "collective_wire_bytes_per_dev": wire,
        "collectives_by_op": by_op,
        "n_collectives": own["n_collectives"],
        "xla_flops_per_dev": float(xla_cost.get("flops", 0.0)),
        "xla_bytes_per_dev": float(xla_cost.get("bytes accessed", 0.0)),
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_per_dev": per_dev_model_flops,
        "useful_flops_ratio": (per_dev_model_flops / flops) if flops else 0.0,
        "argument_bytes_per_dev": mem.argument_size_in_bytes,
        "output_bytes_per_dev": mem.output_size_in_bytes,
        "temp_bytes_per_dev": mem.temp_size_in_bytes,
        "peak_state_bytes_per_dev": mem.argument_size_in_bytes
        + mem.temp_size_in_bytes,
    }


def run_combo(arch: str, shape_name: str, mesh, **kw) -> dict:
    t0 = time.monotonic()
    lowered, meta = build_lowered(arch, shape_name, mesh, **kw)
    t1 = time.monotonic()
    compiled = lowered.compile()
    t2 = time.monotonic()
    result = analyze(lowered, compiled, meta)
    result["lower_s"] = t1 - t0
    result["compile_s"] = t2 - t1
    return result

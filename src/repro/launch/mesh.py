"""Production meshes for the dry-run target (TPU v5e-class pods).

A function, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 CPU device; only dryrun.py forces
512 placeholder devices before its first jax import).
"""
from __future__ import annotations

import jax

#: hardware constants (v5e-class chip) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
HBM_BYTES = 16 * 2 ** 30        # per chip


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Reduced mesh for CI (8 placeholder devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)

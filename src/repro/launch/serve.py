"""Serving launcher: MultiWorld elastic pipeline on the local cluster.

Runs the paper's Fig. 2 scenario end-to-end with a real model: a staged
pipeline with a replicated middle stage, live traffic, an injected failure
(surviving replica keeps serving), then online instantiation of a
replacement.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \\
      --stages 1 2 1 --requests 20 --inject-failure
"""
from __future__ import annotations

import argparse
import asyncio
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke
from repro.core import Cluster, FailureKind
from repro.models import build_model
from repro.serving import PipelineServer


async def run(args) -> None:
    cfg = get_smoke(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cluster = Cluster(heartbeat_interval=0.02, heartbeat_timeout=0.2)
    server = PipelineServer(cluster, model, params, args.stages)
    await server.start()
    print(f"pipeline up: stages={args.stages} arch={cfg.arch_id}")

    rng = np.random.default_rng(0)
    latencies = []
    for i in range(args.requests):
        toks = rng.integers(0, cfg.vocab_size, (args.batch, args.seq))
        t0 = time.monotonic()
        await server.submit(toks, timeout=30.0)
        latencies.append(time.monotonic() - t0)
        print(f"req {i:3d} ok  {latencies[-1]*1e3:7.1f} ms")

        if args.inject_failure and i == args.requests // 3:
            stage = 1 if len(args.stages) > 2 else 0
            victim = server.replicas[stage][0].worker_id
            print(f"-- injecting SILENT_HANG failure into {victim} --")
            cluster.kill(victim, FailureKind.SILENT_HANG)
            await asyncio.sleep(0.5)
        if args.inject_failure and i == 2 * args.requests // 3:
            stage = 1 if len(args.stages) > 2 else 0
            new_id = await server.add_replica(stage)
            print(f"-- online instantiation: {new_id} joined stage {stage} --")

    print(f"served {args.requests} requests; "
          f"p50={np.percentile(latencies, 50)*1e3:.1f}ms "
          f"p95={np.percentile(latencies, 95)*1e3:.1f}ms")
    for si, reps in enumerate(server.replicas):
        for r in reps:
            status = "alive" if r.worker.alive else "DEAD"
            print(f"  stage {si} {r.worker_id}: {r.processed} payloads "
                  f"[{status}]")
    cluster.shutdown()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list(ARCH_IDS))
    ap.add_argument("--stages", type=int, nargs="+", default=[1, 2, 1])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--inject-failure", action="store_true")
    args = ap.parse_args()
    asyncio.run(run(args))


if __name__ == "__main__":
    main()

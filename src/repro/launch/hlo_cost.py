"""Loop-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of
trip count (measured: scan(1) == scan(10) flops). Every production model here
is a scan over layers — and the jnp-flash attention is a scan over kv blocks
— so XLA's numbers undercount by 1-3 orders of magnitude. This module walks
the optimized HLO text instead:

* ``while`` ops: body cost × trip count (parsed from the loop condition's
  ``compare(..., constant(N))``).
* ``dot``: 2 × numel(result) × contracted dims (from the lhs operand's shape
  and ``lhs_contracting_dims``); fusions are recursed for dots.
* bytes: counted at materialization boundaries (fusion/dot/copy/collective
  operands + results) — a fusion's internals are register/VMEM traffic, its
  operands and result are the HBM traffic.
* collectives: per-device wire bytes by op type and replica-group size,
  multiplied by the enclosing loops' trip counts.

This is a model, not ground truth — but it is *consistent* (same rules for
every combo) and loop-correct, which is what the roofline comparison needs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s+->")
_OP_LHS_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_ATTR_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_ATTR_TOAPPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_ATTR_COND = re.compile(r"condition=%?([\w.\-]+)")
_ATTR_BODY = re.compile(r"body=%?([\w.\-]+)")
_ATTR_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_ATTR_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_ATTR_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((-?\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _parse_shapes(s: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(s):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _parse_shapes(s):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    shape_str: str
    opcode: str
    rest: str                      # operands + attributes text
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    shapes: dict[str, str]         # op/param name -> shape string


def parse_module(text: str) -> tuple[dict[str, Computation], Optional[str]]:
    comps: dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                # parameter shapes from the header
                for pm in re.finditer(r"%?([\w.\-]+):\s*((?:\([^)]*\)|[\w\[\],{}]+))",
                                      m.group(2)):
                    cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        parsed = _parse_op_line(line)
        if parsed is not None:
            cur.ops.append(parsed)
            cur.shapes[parsed.name] = parsed.shape_str
    return comps, entry


def _parse_op_line(line: str) -> Optional[Op]:
    """Robustly split '%name = SHAPE opcode(args), attrs' — SHAPE may be a
    tuple containing commas and '/*index=N*/' comments (which contain '=')."""
    m = _OP_LHS_RE.match(line)
    if m is None:
        return None
    name, rest = m.group(1), m.group(2)
    if rest.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        shape_str, rem = rest[:end + 1], rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape_str, rem = rest[:sp], rest[sp + 1:].lstrip()
    om = _OPCODE_RE.match(rem)
    if om is None:
        return None
    return Op(name, shape_str, om.group(1), rem[om.end():],
              is_root=line.lstrip().startswith("ROOT"))


def _trip_count(comps: dict[str, Computation], cond_name: str) -> int:
    """Trip count of a scan-style loop: the largest positive integer constant
    in the condition computation (scan loops run 0..N with `compare LT N`)."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts: list[int] = []
    for op in cond.ops:
        if op.opcode == "constant":
            consts += [int(v) for v in
                       re.findall(r"constant\((-?\d+)\)",
                                  f"constant({op.rest}")]
    pos = [c for c in consts if c > 0]
    return max(pos) if pos else 1


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire: float = 0.0
    by_coll: dict = dataclasses.field(default_factory=dict)
    n_coll: int = 0

    def __iadd__(self, other: "Cost") -> "Cost":
        self.flops += other.flops
        self.bytes += other.bytes
        self.wire += other.wire
        for k, v in other.by_coll.items():
            self.by_coll[k] = self.by_coll.get(k, 0.0) + v
        self.n_coll += other.n_coll
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.wire * k,
                    {o: v * k for o, v in self.by_coll.items()},
                    self.n_coll * int(k))


def _dot_flops(comp: Computation, op: Op) -> float:
    result = _parse_shapes(op.shape_str)
    if not result:
        return 0.0
    numel = 1
    for d in result[0][1]:
        numel *= d
    lhs_m = _OPERAND_RE.search(op.rest)
    contract = _ATTR_CONTRACT.search(op.rest)
    k = 1
    if lhs_m and contract:
        lhs_shape = _parse_shapes(comp.shapes.get(lhs_m.group(1), ""))
        if lhs_shape:
            dims = lhs_shape[0][1]
            for idx in contract.group(1).split(","):
                if idx and int(idx) < len(dims):
                    k *= dims[int(idx)]
    return 2.0 * numel * k


def _operand_bytes(comp: Computation, op: Op) -> int:
    total = 0
    # operands are %refs before the first '),' attribute boundary
    args = op.rest.split("),", 1)[0]
    for ref in _OPERAND_RE.findall(args):
        total += _shape_bytes(comp.shapes.get(ref, ""))
    return total


_SLICE_OPS = {"dynamic-slice", "gather", "slice"}


def _fusion_bytes(comp: Computation, op: Op, fused: Computation) -> int:
    """HBM read traffic of a fusion:

    * a parameter consumed *only* through slicing ops inside the fusion
      contributes its slice sizes, not its full extent (a scan that
      dynamic-slices one KV block per step must not be charged the whole
      cache per step);
    * a parameter that is only the *base* of a dynamic-update-slice is an
      in-place aliased accumulator — traffic is the update, not the base
      (scan ys-stacking / cache writes).
    """
    args = op.rest.split("),", 1)[0]
    operand_names = _OPERAND_RE.findall(args)
    # fusion parameters are positional: parameter(i) corresponds to operand i
    param_ops = {o.name: int(re.search(r"parameter\((\d+)", f"parameter({o.rest}")
                              .group(1))
                 for o in fused.ops if o.opcode == "parameter"}
    sliced_bytes: dict[int, int] = {}
    dus_base: set[int] = set()
    full_params: set[int] = set()
    root_is_dus = any(o.is_root and o.opcode == "dynamic-update-slice"
                      for o in fused.ops)
    for fop in fused.ops:
        refs = _OPERAND_RE.findall(fop.rest.split("),", 1)[0])
        for pos, ref in enumerate(refs):
            if ref not in param_ops:
                continue
            idx = param_ops[ref]
            if fop.opcode in _SLICE_OPS and pos == 0:
                sliced_bytes[idx] = sliced_bytes.get(idx, 0) \
                    + _shape_bytes(fop.shape_str)
            elif fop.opcode == "dynamic-update-slice" and pos == 0:
                dus_base.add(idx)
            else:
                full_params.add(idx)
    total = 0
    for i, name in enumerate(operand_names):
        size = _shape_bytes(comp.shapes.get(name, ""))
        if i in full_params:
            total += size
        elif i in dus_base:
            continue                      # aliased in-place base
        elif i in sliced_bytes:
            total += min(size, sliced_bytes[i])
        else:
            total += size
    if root_is_dus:
        # the fusion result is the aliased accumulator; its traffic is the
        # written slice, already approximated by the non-base operands above
        return total
    return total


def _fusion_result_bytes(op: Op, fused: Computation) -> int:
    """Result-side traffic: full result, except dus-rooted fusions, where
    only the updated slice is written (result aliases the base operand)."""
    for o in fused.ops:
        if o.is_root and o.opcode == "dynamic-update-slice":
            refs = _OPERAND_RE.findall(o.rest.split("),", 1)[0])
            if len(refs) >= 2:
                upd = fused.shapes.get(refs[1], "")
                return _shape_bytes(upd)
    return _shape_bytes(op.shape_str)


def _collective_wire(op: Op) -> tuple[float, int]:
    result_bytes = _shape_bytes(op.shape_str)
    gm = _ATTR_GROUPS.search(op.rest)
    n = int(gm.group(2)) if gm else 1
    if n <= 1:
        return 0.0, n
    base = op.opcode.replace("-start", "")
    if base == "all-reduce":
        return 2.0 * (n - 1) / n * result_bytes, n
    if base == "all-gather":
        return (n - 1) / n * result_bytes, n
    if base == "reduce-scatter":
        return float((n - 1)) * result_bytes, n
    if base == "all-to-all":
        return (n - 1) / n * result_bytes, n
    return float(result_bytes), n          # collective-permute


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "bitcast-convert", "after-all", "iota",
               "partition-id", "replica-id"}
_HALF_BYTES = {"dynamic-update-slice", "dynamic-slice", "gather", "scatter"}


def _flops_only(comps, comp: Computation, memo) -> float:
    """Recursive dot-flops of a computation (for fusion internals)."""
    key = ("f", comp.name)
    if key in memo:
        return memo[key]
    total = 0.0
    for op in comp.ops:
        if op.opcode == "dot":
            total += _dot_flops(comp, op)
        cm = _ATTR_CALLS.search(op.rest) or _ATTR_TOAPPLY.search(op.rest)
        if cm and cm.group(1) in comps:
            total += _flops_only(comps, comps[cm.group(1)], memo)
        if op.opcode == "while":
            bm = _ATTR_BODY.search(op.rest)
            cdm = _ATTR_COND.search(op.rest)
            if bm and bm.group(1) in comps:
                trip = _trip_count(comps, cdm.group(1)) if cdm else 1
                total += trip * _flops_only(comps, comps[bm.group(1)], memo)
    memo[key] = total
    return total


def _cost_of(comps: dict[str, Computation], comp: Computation, memo) -> Cost:
    key = ("c", comp.name)
    if key in memo:
        return memo[key]
    cost = Cost()
    for op in comp.ops:
        opc = op.opcode
        base = opc.replace("-start", "")
        if opc.endswith("-done"):
            continue
        if base in COLLECTIVES:
            wire, n = _collective_wire(op)
            cost.wire += wire
            cost.by_coll[base] = cost.by_coll.get(base, 0.0) + wire
            cost.n_coll += 1
            cost.bytes += _shape_bytes(op.shape_str)
            continue
        if opc == "while":
            bm = _ATTR_BODY.search(op.rest)
            cdm = _ATTR_COND.search(op.rest)
            if bm and bm.group(1) in comps:
                trip = _trip_count(comps, cdm.group(1)) if cdm else 1
                cost += _cost_of(comps, comps[bm.group(1)], memo).scaled(trip)
            continue
        if opc == "conditional":
            brm = _ATTR_BRANCHES.search(op.rest)
            if brm:
                branches = [_cost_of(comps, comps[b.strip().lstrip("%")], memo)
                            for b in brm.group(1).split(",")
                            if b.strip().lstrip("%") in comps]
                if branches:
                    cost += max(branches, key=lambda c: c.flops + c.bytes)
            continue
        if opc == "call":
            cm = _ATTR_TOAPPLY.search(op.rest) or _ATTR_CALLS.search(op.rest)
            if cm and cm.group(1) in comps:
                cost += _cost_of(comps, comps[cm.group(1)], memo)
            continue
        if opc == "dot":
            cost.flops += _dot_flops(comp, op)
            cost.bytes += _operand_bytes(comp, op) + _shape_bytes(op.shape_str)
            continue
        if opc == "fusion":
            cm = _ATTR_CALLS.search(op.rest)
            if cm and cm.group(1) in comps:
                fused = comps[cm.group(1)]
                cost.flops += _flops_only(comps, fused, memo)
                cost.bytes += _fusion_bytes(comp, op, fused) \
                    + _fusion_result_bytes(op, fused)
            else:
                cost.bytes += _operand_bytes(comp, op) \
                    + _shape_bytes(op.shape_str)
            continue
        if opc in _SKIP_BYTES:
            continue
        if opc in _HALF_BYTES:
            # in-place slice update / gather: traffic ~ 2x the small side,
            # not the full base operand
            cost.bytes += 2 * _shape_bytes(op.shape_str)
            continue
        # generic materializing op (copy, broadcast, reduce, sort, ...)
        cost.bytes += _operand_bytes(comp, op) + _shape_bytes(op.shape_str)
        cm = _ATTR_TOAPPLY.search(op.rest)
        if cm and cm.group(1) in comps:
            cost.flops += _flops_only(comps, comps[cm.group(1)], memo)
    memo[key] = cost
    return cost


_META_RE = re.compile(r'op_name="([^"]+)"')


def top_contributors(text: str, top: int = 20) -> list[tuple[str, float, float]]:
    """(label, bytes, wire) of the heaviest ops, loop-trip-weighted.

    Labels are ``opcode @ <jax op_name tail>`` so a contributor maps straight
    back to model code. Diagnosis tool for §Perf iterations.
    """
    comps, entry = parse_module(text)
    if entry is None:
        return []
    acc: dict[str, list[float]] = {}

    def label(op: Op) -> str:
        m = _META_RE.search(op.rest)
        tail = "/".join(m.group(1).split("/")[-3:]) if m else "?"
        return f"{op.opcode} @ {tail}"

    def walk(comp: Computation, scale: float, seen: tuple) -> None:
        if comp.name in seen:
            return
        for op in comp.ops:
            opc = op.opcode
            base = opc.replace("-start", "")
            if opc.endswith("-done"):
                continue
            if opc == "while":
                bm = _ATTR_BODY.search(op.rest)
                cdm = _ATTR_COND.search(op.rest)
                if bm and bm.group(1) in comps:
                    trip = _trip_count(comps, cdm.group(1)) if cdm else 1
                    walk(comps[bm.group(1)], scale * trip,
                         seen + (comp.name,))
                continue
            if base in COLLECTIVES:
                wire, _ = _collective_wire(op)
                ent = acc.setdefault(label(op), [0.0, 0.0])
                ent[0] += scale * _shape_bytes(op.shape_str)
                ent[1] += scale * wire
                continue
            if opc == "fusion":
                cm = _ATTR_CALLS.search(op.rest)
                if cm and cm.group(1) in comps:
                    fused = comps[cm.group(1)]
                    b = _fusion_bytes(comp, op, fused) \
                        + _fusion_result_bytes(op, fused)
                else:
                    b = _operand_bytes(comp, op) + _shape_bytes(op.shape_str)
                acc.setdefault(label(op), [0.0, 0.0])[0] += scale * b
                continue
            if opc in _SKIP_BYTES:
                continue
            if opc in _HALF_BYTES:
                b = 2 * _shape_bytes(op.shape_str)
            else:
                b = _operand_bytes(comp, op) + _shape_bytes(op.shape_str)
            acc.setdefault(label(op), [0.0, 0.0])[0] += scale * b

    walk(comps[entry], 1.0, ())
    rows = [(k, v[0], v[1]) for k, v in acc.items()]
    rows.sort(key=lambda r: -(r[1] + 50.0 * r[2]))
    return rows[:top]


def analyze_hlo_text(text: str) -> dict:
    comps, entry = parse_module(text)
    if entry is None:
        for name, c in comps.items():
            if "main" in name:
                entry = name
                break
    if entry is None:
        raise ValueError("no ENTRY computation found")
    cost = _cost_of(comps, comps[entry], {})
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "wire_bytes": cost.wire,
        "collectives_by_op": dict(cost.by_coll),
        "n_collectives": cost.n_coll,
    }

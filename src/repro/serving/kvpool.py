"""Paged KV-cache pool with shared-prefix reuse.

One :class:`PagePool` serves one :class:`~repro.serving.executor.
StageExecutor` (and therefore all replicas sharing it). A *logical page* is
one ``page_size``-token slab of a session's whole stage cache tree — every
leaf contributes its slice along its structural sequence axis (from
:func:`~repro.serving.partition.stage_cache_seq_axes`), so page granularity
matches the delta-snapshot slicing discipline exactly. Physically the pool
holds, per cache leaf, one array of shape ``(num_pages, *lead, page_size,
*tail)``; a session owns a page table (list of physical page ids) instead of
a contiguous ``max_len`` buffer.

Allocation is a free list with per-page refcounts. A radix trie over
*content keys* — the chained digest of the raw per-page input chunks —
lets sessions whose prompts share a prefix map their leading full pages to
the same physical pages (refcount > 1). Only full pages are shareable; the
partial last page of a prompt is always private, so ordinary decode (which
writes positions >= length) never lands on a shared page. Writable access
still goes through :meth:`prepare_write`, which copy-on-writes any page that
is shared or trie-registered — the path a :meth:`fork` (parallel
sampling / beam split, which shares *all* pages including the partial tail)
takes on its first diverging token.

Physical page 0 is reserved as a scratch sink: pad lanes of a fused decode
dispatch carry all-zero page tables, so their gathers read and their
page-writebacks land on page 0, never on a session's real page.

Pool exhaustion is not an error: allocation failures report ``None`` /
``False`` upward and the executor degrades the session to a contiguous
cache (recording a ``page_alloc_failure`` flight event) — sessions never
crash because the pool is full.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import threading
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.statexfer.codec import PagedCachePayload
from .partition import StageSpec, stage_cache_seq_axes

_DIGEST_SIZE = 16


def prefix_chunk_keys(x: Any, length: int, page_size: int) -> list:
    """Content keys for the full pages of a prompt: per page a
    ``(chunk_digest, chain_digest)`` pair where the chain hashes the whole
    prefix up to and including that page. ``x`` is the *unpadded* prefill
    input (B, S[, D]) — tokens at stage 0, hidden states downstream; both
    are deterministic functions of the prompt prefix, and causal attention
    makes each page's KV content a function of the prefix alone, so equal
    chains imply equal page content."""
    host = np.asarray(x)
    keys = []
    chain = b""
    for i in range(length // page_size):
        chunk = np.ascontiguousarray(host[:, i * page_size:(i + 1) * page_size])
        tag = f"{chunk.shape}|{chunk.dtype}".encode()
        digest = hashlib.blake2b(tag + chunk.tobytes(),
                                 digest_size=_DIGEST_SIZE).digest()
        chain = hashlib.blake2b(chain + digest,
                                digest_size=_DIGEST_SIZE).digest()
        keys.append((digest, chain))
    return keys


def gather_pages(pool_leaves, axes, table, page_size: int):
    """Reassemble contiguous cache leaves from pool leaves through a page
    table (jit-safe; ``table`` may be traced). Table slots beyond a
    session's used pages should be 0 — they gather scratch-page garbage,
    which the decode validity mask (slots <= t) never looks at."""
    out = []
    for leaf, ax in zip(pool_leaves, axes):
        g = leaf[table]                       # (NP, *lead, page, *tail)
        g = jnp.moveaxis(g, 0, ax)            # (*lead, NP, page, *tail)
        shape = g.shape[:ax] + (g.shape[ax] * g.shape[ax + 1],) \
            + g.shape[ax + 2:]
        out.append(g.reshape(shape))
    return out


class _TrieNode:
    __slots__ = ("digest", "chain", "page", "parent", "children")

    def __init__(self, digest, chain, page, parent):
        self.digest = digest
        self.chain = chain
        self.page = page
        self.parent = parent
        self.children: dict = {}


@dataclasses.dataclass
class PagedCacheHandle:
    """A session's view into a :class:`PagePool`: the page table plus the
    decode cursor. Mutable — decode grows ``pages``/``length`` in place, so
    the pipeline's ``sess.cache`` reference stays valid across steps.
    Concurrent readers (snapshot sweep, handoff encode) must go through
    :meth:`freeze` first."""

    pool: "PagePool"
    pages: list                       # physical page id per logical slot
    keys: list                        # per slot: (digest, chain) | None
    length: int                       # valid tokens

    @property
    def nbytes(self) -> int:
        """Bytes a transfer of this session would move: used pages only
        (``payload_nbytes`` duck-typing for placement scoring)."""
        return len(self.pages) * self.pool.page_nbytes

    def freeze(self) -> "PagedView":
        """Snapshot-stable view: pool leaves are immutable jax arrays, so
        pinning the current (leaves, pages, length) triple is enough —
        later decode steps swap in new pool arrays instead of mutating
        these."""
        return PagedView(pool=self.pool, leaves=tuple(self.pool.leaves),
                         pages=tuple(self.pages), keys=tuple(self.keys),
                         length=self.length)

    def paged_payload(self) -> PagedCachePayload:
        return self.freeze().paged_payload()


@dataclasses.dataclass(frozen=True)
class PagedView:
    """Immutable capture of a handle at one instant (see
    :meth:`PagedCacheHandle.freeze`). Safe to encode from a worker thread
    while the serve loop keeps decoding."""

    pool: "PagePool"
    leaves: tuple
    pages: tuple
    keys: tuple
    length: int

    @property
    def nbytes(self) -> int:
        return len(self.pages) * self.pool.page_nbytes

    def paged_payload(self) -> PagedCachePayload:
        pool = self.pool
        idx = jnp.asarray(np.asarray(self.pages, np.int32))
        pages = [np.asarray(leaf[idx]) for leaf in self.leaves]
        return PagedCachePayload(
            page_size=pool.page_size, length=self.length,
            max_len=pool.max_len, skeleton=pool.skeleton,
            axes=list(pool.axes), shapes=list(pool.template_shapes),
            dtypes=list(pool.template_dtypes),
            logical=list(range(len(self.pages))), pages=pages,
            keys=list(self.keys))


def _locked(fn):
    """Serialize a PagePool method under the pool's reentrant lock."""
    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        with self.lock:
            return fn(self, *args, **kwargs)
    return wrapper


class PagePool:
    def __init__(self, cfg, spec: StageSpec, *, max_len: int, page_size: int,
                 num_pages: int,
                 on_event: Optional[Callable[..., Any]] = None) -> None:
        assert max_len % page_size == 0, (max_len, page_size)
        self.cfg = cfg
        self.spec = spec
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_seq = max_len // page_size
        #: physical pages including the reserved scratch page 0
        self.num_pages = max(int(num_pages), self.pages_per_seq + 2)
        self.on_event = on_event
        self.seq_axes = stage_cache_seq_axes(cfg, spec)

        # physical storage — built lazily from the first session's template
        self.leaves: Optional[list] = None
        self.axes: list = []
        self.skeleton: Any = None
        self.template_shapes: list = []
        self.template_dtypes: list = []
        self.page_nbytes = 0

        #: replicas share one executor (hence one pool) per stage and their
        #: serve loops run compute on worker threads — every refcount /
        #: free-list / trie / leaves mutation must be serialized. Reentrant
        #: so the executor can hold it across a whole decode dispatch
        #: (table prep -> jit -> leaves writeback) while calling back in.
        self.lock = threading.RLock()
        self.refcount = np.zeros(self.num_pages, np.int64)
        self._free = list(range(self.num_pages - 1, 0, -1))
        self._root = _TrieNode(None, b"", -1, None)
        self._page_node: dict = {}
        self._node_by_chain: dict = {}

        self.cow_splits = 0
        self.alloc_failures = 0
        self.prefix_pages_reused = 0
        self.installed_sessions = 0

    # ------------------------------------------------------------- template
    def _ensure_spec(self, skeleton, shapes, dtypes) -> bool:
        """Build (or compatibility-check) the physical pool arrays for a
        flat leaf spec. One pool serves one template — sessions with a
        different batch/dtype signature fall back to contiguous caches."""
        sig = (tuple(tuple(s) for s in shapes), tuple(map(str, dtypes)))
        if self.leaves is not None:
            have = (tuple(tuple(s) for s in self.template_shapes),
                    tuple(map(str, self.template_dtypes)))
            return sig == have
        structure = jax.tree.structure(skeleton)
        axes = [int(a) for a in structure.flatten_up_to(self.seq_axes)]
        if any(ax < 0 for ax in axes):
            return False            # a leaf without a seq axis can't page
        for shape, ax in zip(shapes, axes):
            if shape[ax] != self.max_len:
                return False
        self.axes = axes
        self.skeleton = skeleton
        self.template_shapes = [tuple(s) for s in shapes]
        self.template_dtypes = [np.dtype(d) for d in dtypes]
        self.leaves = []
        self.page_nbytes = 0
        for shape, dtype, ax in zip(self.template_shapes,
                                    self.template_dtypes, axes):
            pshape = (self.num_pages,) + shape[:ax] + (self.page_size,) \
                + shape[ax + 1:]
            self.leaves.append(jnp.zeros(pshape, dtype))
            self.page_nbytes += int(
                np.prod(pshape[1:], dtype=np.int64)) * dtype.itemsize
        return True

    def _ensure_from_cache(self, cache) -> Optional[list]:
        flat, treedef = jax.tree.flatten(cache)
        skeleton = jax.tree.unflatten(treedef, list(range(len(flat))))
        shapes = [tuple(leaf.shape) for leaf in flat]
        dtypes = [np.dtype(leaf.dtype) for leaf in flat]
        if not self._ensure_spec(skeleton, shapes, dtypes):
            return None
        return flat

    # ----------------------------------------------------------- alloc/free
    def _alloc(self) -> Optional[int]:
        if not self._free:
            return None
        return self._free.pop()

    def _event(self, kind: str, **fields) -> None:
        if self.on_event is not None:
            self.on_event(kind, **fields)

    def _alloc_failure(self, where: str) -> None:
        self.alloc_failures += 1
        self._event("page_alloc_failure", stage=self.spec.index, where=where,
                    pages_total=self.num_pages - 1, pages_free=0)

    def _unref(self, page: int) -> None:
        self.refcount[page] -= 1
        if self.refcount[page] > 0:
            return
        node = self._page_node.pop(page, None)
        if node is not None:
            # a node's page can only hit refcount 0 after every descendant's
            # did (any session holding a child page holds all its ancestors)
            assert not node.children, "freed a trie page with live children"
            node.parent.children.pop(node.digest, None)
            self._node_by_chain.pop(node.chain, None)
        self._free.append(page)

    def _write_pages(self, phys: list, page_trees: list) -> None:
        """Batch-write freshly allocated pages: one scatter per leaf.
        ``page_trees``: per entry a flat per-leaf list of page arrays."""
        if not phys:
            return
        idx = jnp.asarray(np.asarray(phys, np.int32))
        for leaf_i in range(len(self.leaves)):
            stacked = jnp.stack([jnp.asarray(pt[leaf_i])
                                 for pt in page_trees])
            self.leaves[leaf_i] = self.leaves[leaf_i].at[idx].set(stacked)

    def _cache_page(self, flat_cache, li: int) -> list:
        """Flat per-leaf list of logical page ``li`` sliced from a
        contiguous cache's leaves."""
        out = []
        for leaf, ax in zip(flat_cache, self.axes):
            out.append(jax.lax.dynamic_slice_in_dim(
                leaf, li * self.page_size, self.page_size, axis=ax))
        return out

    # -------------------------------------------------------------- install
    @_locked
    def install_prefill(self, cache, length: int,
                        keys: list) -> Optional[PagedCacheHandle]:
        """Move a freshly prefilled contiguous cache into the pool. Leading
        full pages whose content keys match the prefix trie reuse the
        existing physical pages (refcount++); everything else allocates.
        Returns None (caller keeps the contiguous cache) on template
        mismatch or pool exhaustion — never raises."""
        flat = self._ensure_from_cache(cache)
        if flat is None:
            return None
        n_used = -(-length // self.page_size)
        pages: list = []
        page_keys: list = []
        new_phys: list = []
        new_trees: list = []
        node = self._root
        for li in range(n_used):
            full = (li + 1) * self.page_size <= length
            key = keys[li] if full and li < len(keys) else None
            child = node.children.get(key[0]) if key is not None else None
            if child is not None:
                self.refcount[child.page] += 1
                self.prefix_pages_reused += 1
                pages.append(child.page)
                page_keys.append(key)
                node = child
                continue
            p = self._alloc()
            if p is None:
                for q in reversed(pages):
                    self._unref(q)
                self._alloc_failure("prefill")
                return None
            self.refcount[p] = 1
            new_phys.append(p)
            new_trees.append(self._cache_page(flat, li))
            if key is not None:
                child = _TrieNode(key[0], key[1], p, node)
                node.children[key[0]] = child
                self._node_by_chain[key[1]] = child
                self._page_node[p] = child
                node = child
            pages.append(p)
            page_keys.append(key)
        self._write_pages(new_phys, new_trees)
        self.installed_sessions += 1
        return PagedCacheHandle(pool=self, pages=pages, keys=page_keys,
                                length=length)

    @_locked
    def install_payload(self, payload: PagedCachePayload
                        ) -> Optional[PagedCacheHandle]:
        """Install a handed-off/restored paged payload. Full pages whose
        chain keys already live in this pool's trie are shared instead of
        re-stored — the cross-replica form of prefix reuse."""
        if payload.logical != list(range(len(payload.logical))):
            return None             # a bare delta cannot install on its own
        if not self._ensure_spec(payload.skeleton, payload.shapes,
                                 payload.dtypes):
            return None
        pages: list = []
        page_keys: list = []
        new_phys: list = []
        new_trees: list = []
        node: Optional[_TrieNode] = self._root
        for pos in range(len(payload.logical)):
            key = payload.keys[pos]
            if key is not None:
                known = self._node_by_chain.get(key[1])
                if known is not None:
                    self.refcount[known.page] += 1
                    self.prefix_pages_reused += 1
                    pages.append(known.page)
                    page_keys.append(key)
                    node = known
                    continue
            p = self._alloc()
            if p is None:
                for q in reversed(pages):
                    self._unref(q)
                self._alloc_failure("install")
                return None
            self.refcount[p] = 1
            new_phys.append(p)
            new_trees.append(payload.page_entry(pos))
            if key is not None and node is not None:
                child = _TrieNode(key[0], key[1], p, node)
                node.children[key[0]] = child
                self._node_by_chain[key[1]] = child
                self._page_node[p] = child
                node = child
            else:
                node = None         # keyless page: trie chain ends here
            pages.append(p)
            page_keys.append(key)
        self._write_pages(new_phys, new_trees)
        self.installed_sessions += 1
        return PagedCacheHandle(pool=self, pages=pages, keys=page_keys,
                                length=payload.length)

    # ------------------------------------------------------------- lifetime
    @_locked
    def prepare_write(self, handle: PagedCacheHandle, t: int) -> bool:
        """Make position ``t`` writable: grow the page table across page
        boundaries and copy-on-write a shared or trie-registered target
        page. False = pool exhausted (caller degrades to contiguous)."""
        li = t // self.page_size
        while len(handle.pages) <= li:
            p = self._alloc()
            if p is None:
                self._alloc_failure("decode")
                return False
            self.refcount[p] = 1
            handle.pages.append(p)
            handle.keys.append(None)
        page = handle.pages[li]
        if self.refcount[page] > 1 or page in self._page_node:
            fresh = self._alloc()
            if fresh is None:
                self._alloc_failure("cow")
                return False
            idx = jnp.asarray([page])
            for leaf_i in range(len(self.leaves)):
                src = self.leaves[leaf_i][idx]
                self.leaves[leaf_i] = \
                    self.leaves[leaf_i].at[jnp.asarray([fresh])].set(src)
            self.refcount[fresh] = 1
            self._unref(page)
            handle.pages[li] = fresh
            handle.keys[li] = None
            self.cow_splits += 1
        return True

    @_locked
    def fork(self, handle: PagedCacheHandle) -> PagedCacheHandle:
        """Share *all* pages of a session (parallel sampling / beam split).
        The partial tail page becomes shared too; the first diverging write
        on either branch copy-on-writes it via :meth:`prepare_write`."""
        for p in handle.pages:
            self.refcount[p] += 1
        self.installed_sessions += 1
        return PagedCacheHandle(pool=self, pages=list(handle.pages),
                                keys=list(handle.keys), length=handle.length)

    @_locked
    def release(self, handle: PagedCacheHandle) -> None:
        """Drop a session's references. Pages shared with live siblings
        survive; exclusively-owned pages return to the free list and leave
        the prefix trie. Idempotent — a degraded-then-dropped session
        releases once."""
        if not handle.pages:
            return
        # leaf-to-root: a trie node must lose its children before its own
        # page can be pruned from the trie
        for p in reversed(handle.pages):
            self._unref(p)
        handle.pages = []
        handle.keys = []
        self.installed_sessions -= 1

    @_locked
    def truncate(self, handle: PagedCacheHandle, length: int) -> None:
        """Roll a session back to ``length`` committed tokens: pop and
        unref every trailing page beyond the one holding the last kept
        slot. The speculative-verify rollback path — rejected-suffix
        writes may have grown/COW'd pages past the accepted prefix, and
        without this those exclusively-owned pages would sit refcounted
        until session end (an occupancy leak the pool's free list never
        sees). Content of the kept tail page is NOT rewound: decode's
        validity mask never reads slots ≥ ``length``, and the next write
        overwrites them, so page-granular truncation is exact."""
        keep = -(-max(int(length), 0) // self.page_size)
        while len(handle.pages) > keep:
            self._unref(handle.pages.pop())
            handle.keys.pop()
        handle.length = min(handle.length, int(length))

    # ------------------------------------------------------------------ view
    @_locked
    def materialize(self, handle: PagedCacheHandle):
        """Contiguous ``max_len`` cache tree for a handle (degrade path).
        Positions beyond the used pages gather scratch-page content — the
        decode validity mask never reads them."""
        table = np.zeros(self.pages_per_seq, np.int32)
        table[:len(handle.pages)] = handle.pages
        leaves = gather_pages(self.leaves, self.axes, jnp.asarray(table),
                              self.page_size)
        return jax.tree.unflatten(jax.tree.structure(self.skeleton), leaves)

    @_locked
    def stats(self) -> dict:
        total = self.num_pages - 1
        free = len(self._free)
        return {
            "kv_pages_total": total,
            "kv_pages_free": free,
            "kv_pages_used": total - free,
            "kv_pages_shared": int(np.sum(self.refcount > 1)),
            "cow_splits_total": self.cow_splits,
            "page_alloc_failures": self.alloc_failures,
            "prefix_pages_reused": self.prefix_pages_reused,
            "paged_sessions": self.installed_sessions,
        }

"""Single-replica serving engine: batched prefill + token-by-token decode.

The building block each MultiWorld pipeline stage replica runs internally;
also usable standalone (examples/quickstart.py). All compute — shape
bucketing, compile reuse, prefill/decode dispatch — lives in the shared
:class:`~repro.serving.executor.StageExecutor` (the whole model treated as a
single stage), the same executor every pipeline replica runs its own layer
slice on. The paper's NCCL-lazy-init throughput dip has its analogue here
as the first-call compile, which bench_online.py measures.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .executor import StageExecutor


def sample_tokens(logits: jax.Array, key: jax.Array, temperature: float
                  ) -> jax.Array:
    """logits (B, V) -> (B,) int32."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1) \
        .astype(jnp.int32)


class ServeEngine:
    def __init__(self, model, params, *, max_len: int = 256,
                 temperature: float = 0.0) -> None:
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self.executor = StageExecutor.for_model(model, params,
                                                max_len=max_len)
        # first_call_compile_s: wall time of the very first prefill + decode
        # dispatch (dominated by jit compilation — the analogue of the
        # paper's NCCL lazy-init dip). generate_s: total generate() wall
        # time across all calls.
        self.stats = {"prefill_calls": 0, "decode_steps": 0,
                      "tokens_out": 0, "first_call_compile_s": 0.0,
                      "generate_s": 0.0}

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 key: Optional[jax.Array] = None) -> np.ndarray:
        """prompts (B, S) int32 -> (B, max_new_tokens) int32."""
        key = key if key is not None else jax.random.PRNGKey(0)
        toks = jnp.asarray(prompts, jnp.int32)
        bsz, s = toks.shape
        assert s + max_new_tokens <= self.max_len

        t0 = time.monotonic()
        logits, cache = self.executor.prefill(toks)
        self.stats["prefill_calls"] += 1

        out = []
        key, sub = jax.random.split(key)
        next_tok = sample_tokens(logits[:, -1], sub, self.temperature)
        out.append(next_tok)
        t = s
        for _ in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            logits, cache = self.executor.decode(cache, next_tok[:, None], t)
            next_tok = sample_tokens(logits, sub, self.temperature)
            out.append(next_tok)
            t += 1
            self.stats["decode_steps"] += 1
        self.stats["tokens_out"] += bsz * max_new_tokens
        self.stats["first_call_compile_s"] = \
            self.executor.stats["first_call_compile_s"]
        self.stats["generate_s"] += time.monotonic() - t0
        return np.stack([np.asarray(o) for o in out], axis=1)

    def score(self, tokens: np.ndarray) -> np.ndarray:
        """Teacher-forced logits (B, S, V) — the pipeline's scoring payload."""
        return np.asarray(self.executor.score(jnp.asarray(tokens, jnp.int32)))

    # -------------------------------------------------- resumable sessions
    # Step-at-a-time greedy decoding with state that can leave the engine:
    # export_session/import_session move a mid-decode session across engine
    # restarts (or hosts) through the statexfer codec — the single-engine
    # proof of the pipeline's live-migration story, and the harness the
    # codec round-trip tests assert token parity on.

    def start_session(self, prompts: np.ndarray) -> "EngineSession":
        """Prefill a prompt batch; the session sits at a step boundary with
        its first generated token pending in ``next_tok``."""
        toks = jnp.asarray(prompts, jnp.int32)
        logits, cache = self.executor.prefill(toks)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        self.stats["prefill_calls"] += 1
        return EngineSession(cache=cache, next_tok=nxt, t=int(toks.shape[1]))

    def step_session(self, sess: "EngineSession") -> np.ndarray:
        """One greedy decode step; returns the (B,) token just consumed —
        i.e. the next generated token in order."""
        tok = np.asarray(sess.next_tok)
        logits, sess.cache = self.executor.decode(
            sess.cache, sess.next_tok[:, None], sess.t)
        sess.next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        sess.t += 1
        self.stats["decode_steps"] += 1
        return tok

    def export_session(self, sess: "EngineSession", *,
                       codec: str = "fp") -> bytes:
        """Serialize a session at its step boundary to a snapshot blob."""
        from repro.statexfer import SessionSnapshot, snapshot_to_blob

        state = {"cache": sess.cache, "next_tok": sess.next_tok}
        snap = SessionSnapshot(session_id=0, stage=0, step=sess.t,
                               batch=int(sess.next_tok.shape[0]), cache=state)
        return snapshot_to_blob(snap, codec=codec)

    def import_session(self, blob: bytes) -> "EngineSession":
        """Adopt an exported session; decoding resumes exactly where the
        exporter stopped (bit-identically under the fp codec)."""
        from repro.statexfer import snapshot_from_blob

        snap = snapshot_from_blob(blob)
        return EngineSession(cache=snap.cache["cache"],
                             next_tok=snap.cache["next_tok"], t=snap.step)


@dataclasses.dataclass
class EngineSession:
    """A resumable greedy decode: cache + the pending token and its
    position. Always at a step boundary, so always exportable."""

    cache: Any
    next_tok: jax.Array   # (B,) int32 token to feed at position ``t``
    t: int

from .engine import ServeEngine, sample_tokens
from .partition import (
    StageSpec,
    split_stages,
    stage_decode,
    stage_forward,
    stage_init_cache,
    stage_params,
    stage_prefill,
)
from .pipeline import CLIENT, PipelineServer
from .router import ReplicaRouter

__all__ = [
    "ServeEngine", "sample_tokens",
    "StageSpec", "split_stages", "stage_decode", "stage_forward",
    "stage_init_cache", "stage_params", "stage_prefill",
    "CLIENT", "PipelineServer", "ReplicaRouter",
]

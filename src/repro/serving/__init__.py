from .engine import EngineSession, ServeEngine, sample_tokens
from .envelope import (
    Envelope,
    Kind,
    ROLE_BOTH,
    ROLE_DECODE,
    ROLE_DRAFT,
    ROLE_PREFILL,
    payload_nbytes,
)
from .executor import StageExecutor
from .kvpool import (
    PagedCacheHandle,
    PagedView,
    PagePool,
    gather_pages,
    prefix_chunk_keys,
)
from .partition import (
    StageSpec,
    split_stages,
    stage_decode,
    stage_forward,
    stage_init_cache,
    stage_params,
    stage_prefill,
    stage_verify,
)
from .pipeline import CLIENT, PipelineServer
from .registry import ModelEntry, ModelRegistry, ResidencyError
from .router import ReplicaRouter

__all__ = [
    "EngineSession", "ServeEngine", "sample_tokens",
    "Envelope", "Kind", "payload_nbytes",
    "ROLE_BOTH", "ROLE_DECODE", "ROLE_DRAFT", "ROLE_PREFILL",
    "StageExecutor",
    "PagePool", "PagedCacheHandle", "PagedView",
    "gather_pages", "prefix_chunk_keys",
    "StageSpec", "split_stages", "stage_decode", "stage_forward",
    "stage_init_cache", "stage_params", "stage_prefill", "stage_verify",
    "CLIENT", "PipelineServer", "ReplicaRouter",
    "ModelEntry", "ModelRegistry", "ResidencyError",
]

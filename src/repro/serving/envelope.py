"""Typed request envelopes: what the pipeline wires actually carry.

The original data plane moved anonymous ``(req_id, tensor)`` tuples, which
was enough for one-shot scoring but made every other layer blind: routers
could not distinguish a prefill from a decode step (so no session affinity),
drain could not see open sessions, and transport byte accounting saw an
object with no ``nbytes``. The :class:`Envelope` gives every hop the request
identity, the session it belongs to, what kind of work it is, where in the
sequence it sits, and how long the client will still wait for it.

Lifecycle of a generative request (client-side loop in
``PipelineServer.generate``):

    PREFILL(history) -> stage0 .. stageN build per-session KV caches,
                        each pins the downstream world it chose
    DECODE(token, t) -> follows the pinned route; replicas coalesce
                        compatible steps into one batched dispatch
    FINISH           -> dropped-state marker along the pinned route
    RETRY            -> any replica that lost the session's state (death,
                        drain, fenced edge) answers with this; the client
                        re-prefills the full history on a survivor

``SCORE`` keeps the legacy stateless teacher-forced path alive under the
same typed wire format.

Disaggregated pools (role-specialized replicas): a stage may split its
replicas into a ``prefill`` pool (long, compute-bound dispatches) and a
``decode`` pool (short, latency-bound, batch-hungry steps). The envelope's
``role`` tag tells every router which pool the work belongs to, and the
``HANDOFF`` kind is the wire form of the freshly built KV cache streaming
from a prefill replica to its session's decode home — typed like all other
pipeline traffic, so byte accounting and dashboards see the transfer.

Multi-model, multi-tenant serving: one elastic pool can host several
registered models (see ``serving/registry.py``), so every envelope carries
the ``model`` its work belongs to (routers restrict rotation to replicas
with that model resident) and the ``tenant`` whose traffic it is (the
replica-side weighted-deficit fair scheduler and the per-tenant latency
sketches key on it). ``None`` for both preserves single-model single-tenant
behavior bit-for-bit. The model-residency control plane speaks three more
wire kinds: ``LOAD`` envelopes wrap a model's stage-weight chunks streaming
from a resident peer to a loading replica; a ``SWAP`` envelope heads that
stream when the load is one leg of an A→B swap; an ``UNLOAD`` envelope
trails it, directing the receiver to retire the outgoing model once the
incoming one is installed — so the whole residency change is typed,
self-describing traffic on the same accounted wire as everything else.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional

from repro.core.transport import payload_nbytes
from repro.obs.trace import TraceContext

#: replica/pool roles for disaggregated prefill/decode serving.
#: ``both`` is the colocated default — one pool serves prefill and decode,
#: exactly the pre-disaggregation behavior.
ROLE_BOTH = "both"
ROLE_PREFILL = "prefill"
ROLE_DECODE = "decode"
#: speculative-decoding proposer pool: replicas running the *small* draft
#: model that proposes k tokens per session (PROPOSE), verified in one
#: batched target-model dispatch on the decode pool (VERIFY). Draft
#: replicas hold no target-model state, so the pool is fully disposable —
#: killing/draining it degrades sessions to plain decode, never fails them.
ROLE_DRAFT = "draft"

#: worlds/replicas able to serve work of a given role. ``draft`` work runs
#: the draft model's weights, so only draft replicas qualify — a ``both``
#: world must NOT appear here (it holds target-model state only).
ROLE_CAPABLE = {
    ROLE_PREFILL: (ROLE_PREFILL, ROLE_BOTH),
    ROLE_DECODE: (ROLE_DECODE, ROLE_BOTH),
    ROLE_BOTH: (ROLE_BOTH,),
    ROLE_DRAFT: (ROLE_DRAFT,),
}


class Kind(enum.IntEnum):
    """Wire kinds.

    Numbering contract: kind values are *frozen wire constants*. SCORE=0
    through SWAP=8 shipped in earlier releases and snapshots/recorders
    persist raw ints, so existing values must never be renumbered or
    reused — new kinds append at the end (PROPOSE=9, VERIFY=10, next
    free: 11). tests/test_envelope_kinds.py pins every value.
    """

    SCORE = 0     # stateless teacher-forced batch (legacy submit() path)
    PREFILL = 1   # build a session's per-stage KV cache from token history
    DECODE = 2    # one autoregressive step against an open session
    FINISH = 3    # session over: client done (state dropped along the pinned
    #               route) or, with ``error`` set, server-initiated — e.g. a
    #               deadline-expired step dropped at a stage boundary
    RETRY = 4     # session state lost; client must re-prefill on a survivor
    HANDOFF = 5   # one chunk of a freshly prefilled KV cache streaming from
    #               a prefill replica to the session's decode-pool home
    LOAD = 6      # one chunk of a model's stage weights streaming from a
    #               resident peer (or the registry store) to a loading replica
    UNLOAD = 7    # residency-change trailer: retire ``model`` on the receiver
    #               once the accompanying LOAD stream is installed
    SWAP = 8      # residency-change header: the LOAD stream that follows is
    #               one leg of an atomic swap ``model`` -> stream's model
    PROPOSE = 9   # speculative decode, draft side: full committed history in,
    #               k greedy draft-model proposals out (draft pool only)
    VERIFY = 10   # speculative decode, target side: current token + k draft
    #               proposals in one batched target dispatch; the accepted
    #               prefix (plus the free bonus token) comes back as payload


@dataclasses.dataclass
class Envelope:
    """One unit of pipeline traffic.

    ``step`` is the decode position ``t`` of the carried token (DECODE) or
    the last history position (PREFILL). ``deadline`` is an absolute
    ``time.monotonic`` instant after which the client has given up — replicas
    drop expired envelopes instead of burning compute on them; 0 means no
    deadline. ``payload`` is tokens entering stage 0, hidden states between
    stages, logits toward the client, or None (FINISH/RETRY).
    """

    req_id: int
    session_id: int
    kind: Kind
    step: int = 0
    deadline: float = 0.0
    payload: Any = None
    #: FINISH only: why the server ended the session (e.g. a deadline-
    #: expired step dropped at a stage boundary). None for client FINISHes.
    error: Optional[str] = None
    #: which replica pool this work belongs to (routers restrict the
    #: rotation to role-capable worlds); None routes over the whole pool
    role: Optional[str] = None
    #: PREFILL chain only: worker id of the sending stage's decode home for
    #: this session — the receiving stage repins that home's route onto the
    #: decode home it chooses, stitching the decode path pool-to-pool
    home: Optional[str] = None
    #: which registered model this work belongs to; routers restrict the
    #: rotation to replicas with the model resident, and replicas resolve
    #: the per-model executor from it. None = the pipeline's default model
    #: (exact pre-multi-model behavior).
    model: Optional[str] = None
    #: whose traffic this is: the replica-side weighted-deficit fair
    #: scheduler arbitrates decode batch slots across tenants, and the
    #: client keys per-tenant latency sketches on it. None = untagged
    #: (single implicit tenant).
    tenant: Optional[str] = None
    #: speculative decoding: the k-token budget of a PROPOSE, or the number
    #: of proposed tokens carried by a VERIFY. 0 = not speculative traffic.
    spec_k: int = 0
    #: VERIFY through a multi-stage pipeline only: the proposed token block
    #: (B, k+1) riding beside the hidden-state payload, so the *last* stage
    #: (the one producing logits) can judge acceptance. None elsewhere.
    spec_tokens: Optional[Any] = None
    #: causal span context (trace_id, span_id, parent_id): every stage that
    #: does work on this envelope parents its span here, so the session's
    #: whole lifecycle — including RETRY bounces and re-prefills — rebuilds
    #: as one tree. None = untraced (tracer off, or pre-obs senders).
    trace: Optional[TraceContext] = None

    @property
    def nbytes(self) -> int:
        """Wire size of the tensor payload (transport byte accounting)."""
        return payload_nbytes(self.payload)

    @property
    def bulk(self) -> bool:
        """Bulk-transfer marker passthrough: a HANDOFF envelope wrapping a
        snapshot chunk counts in the transport's bulk byte slice exactly
        like the bare chunk would."""
        return bool(getattr(self.payload, "bulk", False))

    def expired(self, now: float) -> bool:
        return self.deadline > 0.0 and now > self.deadline

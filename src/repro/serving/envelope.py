"""Typed request envelopes: what the pipeline wires actually carry.

The original data plane moved anonymous ``(req_id, tensor)`` tuples, which
was enough for one-shot scoring but made every other layer blind: routers
could not distinguish a prefill from a decode step (so no session affinity),
drain could not see open sessions, and transport byte accounting saw an
object with no ``nbytes``. The :class:`Envelope` gives every hop the request
identity, the session it belongs to, what kind of work it is, where in the
sequence it sits, and how long the client will still wait for it.

Lifecycle of a generative request (client-side loop in
``PipelineServer.generate``):

    PREFILL(history) -> stage0 .. stageN build per-session KV caches,
                        each pins the downstream world it chose
    DECODE(token, t) -> follows the pinned route; replicas coalesce
                        compatible steps into one batched dispatch
    FINISH           -> dropped-state marker along the pinned route
    RETRY            -> any replica that lost the session's state (death,
                        drain, fenced edge) answers with this; the client
                        re-prefills the full history on a survivor

``SCORE`` keeps the legacy stateless teacher-forced path alive under the
same typed wire format.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional

from repro.core.transport import payload_nbytes


class Kind(enum.IntEnum):
    SCORE = 0     # stateless teacher-forced batch (legacy submit() path)
    PREFILL = 1   # build a session's per-stage KV cache from token history
    DECODE = 2    # one autoregressive step against an open session
    FINISH = 3    # session over: client done (state dropped along the pinned
    #               route) or, with ``error`` set, server-initiated — e.g. a
    #               deadline-expired step dropped at a stage boundary
    RETRY = 4     # session state lost; client must re-prefill on a survivor


@dataclasses.dataclass
class Envelope:
    """One unit of pipeline traffic.

    ``step`` is the decode position ``t`` of the carried token (DECODE) or
    the last history position (PREFILL). ``deadline`` is an absolute
    ``time.monotonic`` instant after which the client has given up — replicas
    drop expired envelopes instead of burning compute on them; 0 means no
    deadline. ``payload`` is tokens entering stage 0, hidden states between
    stages, logits toward the client, or None (FINISH/RETRY).
    """

    req_id: int
    session_id: int
    kind: Kind
    step: int = 0
    deadline: float = 0.0
    payload: Any = None
    #: FINISH only: why the server ended the session (e.g. a deadline-
    #: expired step dropped at a stage boundary). None for client FINISHes.
    error: Optional[str] = None

    @property
    def nbytes(self) -> int:
        """Wire size of the tensor payload (transport byte accounting)."""
        return payload_nbytes(self.payload)

    def expired(self, now: float) -> bool:
        return self.deadline > 0.0 and now > self.deadline

"""Pipeline-stage partitioning of a LanguageModel.

MultiWorld's serving story (paper Fig. 2) is a model split into stages, one
worker per stage (replicas for bottleneck stages), one world per edge. This
module produces the per-stage compute: contiguous slices of scan steps
across the model's block groups, with embedding on the first stage and the
LM head on the last.

Works for every decoder-only family (dense / moe / gemma-pair / mamba2 /
hybrid): a "unit" is one scan step of one group, so hybrid units keep their
shared-attention invocation with their mamba run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import ModelConfig
from repro.models import transformer as tfm
from repro.models.common import BlockGroup


@dataclasses.dataclass(frozen=True)
class StageSpec:
    index: int
    n_stages: int
    #: per source group: (group_idx, lo, hi) half-open slice of scan steps
    slices: tuple[tuple[int, int, int], ...]

    @property
    def first(self) -> bool:
        return self.index == 0

    @property
    def last(self) -> bool:
        return self.index == self.n_stages - 1


def split_stages(cfg: ModelConfig, n_stages: int) -> list[StageSpec]:
    units = [(gi, step) for gi, g in enumerate(cfg.groups)
             for step in range(g.count)]
    assert len(units) >= n_stages, (len(units), n_stages)
    per = [len(units) // n_stages + (1 if i < len(units) % n_stages else 0)
           for i in range(n_stages)]
    specs = []
    cursor = 0
    for i, n in enumerate(per):
        chunk = units[cursor:cursor + n]
        cursor += n
        slices: list[tuple[int, int, int]] = []
        for gi, step in chunk:
            if slices and slices[-1][0] == gi and slices[-1][2] == step:
                slices[-1] = (gi, slices[-1][1], step + 1)
            else:
                slices.append((gi, step, step + 1))
        specs.append(StageSpec(i, n_stages, tuple(slices)))
    return specs


def stage_params(cfg: ModelConfig, params: Any, spec: StageSpec) -> dict:
    """Extract the param subtree a stage needs (its slice + heads/embeds)."""
    out: dict = {"groups": [
        jax.tree.map(lambda a: a[lo:hi], params["groups"][gi])
        for gi, lo, hi in spec.slices
    ]}
    needs_shared = any(cfg.groups[gi].kind == "hybrid"
                       for gi, _, _ in spec.slices)
    if needs_shared and "shared_attn" in params:
        out["shared_attn"] = params["shared_attn"]
    if spec.first or cfg.tie_embeddings and spec.last:
        out["embed"] = params["embed"]
    if spec.last:
        out["final_norm"] = params["final_norm"]
        if not cfg.tie_embeddings:
            out["lm_head"] = params["lm_head"]
    return out


def _stage_groups(cfg: ModelConfig, spec: StageSpec) -> list[BlockGroup]:
    return [dataclasses.replace(cfg.groups[gi], count=hi - lo)
            for gi, lo, hi in spec.slices]


def stage_forward(cfg: ModelConfig, spec: StageSpec, sparams: dict,
                  x: jax.Array, *, tokens_in: bool) -> jax.Array:
    """Prefill compute for one stage. First stage takes tokens (B,S) int32;
    others take hidden states (B,S,D). Last stage returns logits."""
    if tokens_in:
        x = tfm.embed_tokens(cfg, sparams, x)
    bsz, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                 (bsz, s))
    shared = sparams.get("shared_attn")
    for g, gp in zip(_stage_groups(cfg, spec), sparams["groups"]):
        x, _ = tfm._group_prefill(cfg, g, gp, x, positions,
                                  mrope=None, shared=shared)
    if spec.last:
        return tfm.lm_logits(cfg, sparams, x)
    return x


def stage_prefill(cfg: ModelConfig, spec: StageSpec, sparams: dict,
                  x: jax.Array, max_len: int, *, tokens_in: bool):
    """Prefill + decode-cache build for one stage."""
    if tokens_in:
        x = tfm.embed_tokens(cfg, sparams, x)
    bsz, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                 (bsz, s))
    shared = sparams.get("shared_attn")
    cache = stage_init_cache(cfg, spec, bsz, max_len)
    new_cache = []
    for g, gp, gc in zip(_stage_groups(cfg, spec), sparams["groups"], cache):
        x, nc = tfm._group_prefill_cached(cfg, g, gp, gc, x, positions,
                                          mrope=None, shared=shared)
        new_cache.append(nc)
    if spec.last:
        return tfm.lm_logits(cfg, sparams, x), new_cache
    return x, new_cache


def stage_decode(cfg: ModelConfig, spec: StageSpec, sparams: dict, cache,
                 x: jax.Array, t: jax.Array, *, tokens_in: bool):
    """One-token decode for one stage; x is (B,1) tokens or (B,1,D) hidden."""
    if tokens_in:
        x = tfm.embed_tokens(cfg, sparams, x)
    shared = sparams.get("shared_attn")
    new_cache = []
    for g, gp, gc in zip(_stage_groups(cfg, spec), sparams["groups"], cache):
        x, nc = tfm._group_decode(cfg, g, gp, gc, x, t, mrope=None,
                                  shared=shared)
        new_cache.append(nc)
    if spec.last:
        return tfm.lm_logits(cfg, sparams, x)[:, 0], new_cache
    return x, new_cache


def stage_verify(cfg: ModelConfig, spec: StageSpec, sparams: dict, cache,
                 x: jax.Array, t: jax.Array, *, tokens_in: bool):
    """K-token teacher-forced continuation for one stage (speculative
    verification): x is (B,K) known tokens or (B,K,D) hidden for positions
    ``t..t+K-1``. One fused weight pass with the same math as K sequential
    :func:`stage_decode` calls. Last stage returns (B,K,V) logits — one row
    per verified position. Full-cache (dense/moe, unwindowed) stages only.
    """
    if tokens_in:
        x = tfm.embed_tokens(cfg, sparams, x)
    new_cache = []
    for g, gp, gc in zip(_stage_groups(cfg, spec), sparams["groups"], cache):
        x, nc = tfm._group_verify(cfg, g, gp, gc, x, t)
        new_cache.append(nc)
    if spec.last:
        return tfm.lm_logits(cfg, sparams, x), new_cache
    return x, new_cache


def stage_init_cache(cfg: ModelConfig, spec: StageSpec, batch: int,
                     max_len: int, dtype=None):
    sub = dataclasses.replace(cfg, groups=tuple(_stage_groups(cfg, spec)))
    return tfm.init_cache(sub, batch, max_len, dtype)


def stage_cache_seq_axes(cfg: ModelConfig, spec: StageSpec):
    """Per-leaf index of the decode-sequence axis of the stage cache tree
    (-1 for leaves without one). This is the structural ground truth the
    delta-snapshot codec slices along — a size-match heuristic is ambiguous
    whenever another axis happens to equal ``max_len`` (e.g. head_dim 64
    with a 64-token cache)."""
    sub = dataclasses.replace(cfg, groups=tuple(_stage_groups(cfg, spec)))
    axes = tfm.cache_logical_axes(sub, 1, 1)

    def _is_names(x) -> bool:
        return isinstance(x, tuple) and bool(x) and x[0] == "layers"

    return jax.tree.map(
        lambda names: (names.index("cache_seq")
                       if "cache_seq" in names else -1),
        axes, is_leaf=_is_names)

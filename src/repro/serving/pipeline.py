"""MultiWorld pipeline server — the paper's Fig. 2 with real models.

Topology: the model is split into N stages (serving/partition.py); each stage
has one or more replica workers; every (upstream replica, downstream replica)
pair gets its own pairwise world, as does every (client, stage-0 replica) and
(last-stage replica, client) pair. Worlds are fault domains: a replica death
breaks only its edges; upstream routers drop the broken worlds and keep
serving through the survivors; ``add_replica`` performs online instantiation
(new worker + fresh worlds) without touching any existing world.

Elastic control hooks (consumed by repro.control):

* ``remove_replica`` — the scale-down path the paper leaves open: stop
  routing to the replica, drain its inbox and in-flight work to zero, then
  tear down its worlds on every member in one event-loop tick (no spurious
  watchdog breaks, no dropped payloads).
* per-replica load counters (queue depth, in-flight, wait/service time) —
  the raw signals MetricsHub turns into EWMAs for the scaling policies.
* ``failed_replicas`` — watchdog-sourced failure view: a replica whose
  upstream edges have *all* been fenced can no longer receive traffic and
  is a heal candidate (paper Fig. 2c, but triggered by the watchdog).

Payloads are (request_id, tensor) tuples moved zero-copy by the in-process
transport; on real hardware the same worlds carry ICI/NCCL transfers.
"""
from __future__ import annotations

import asyncio
import itertools
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Cluster,
    WorldBrokenError,
    WorldNotFoundError,
    WorldSpec,
)
from repro.core.online import OnlineInstantiator
from .partition import StageSpec, split_stages, stage_forward, stage_params
from .router import ReplicaRouter

CLIENT = "client"


def _edge(name: str, up: str, down: str) -> str:
    return f"{name}:{up}->{down}"


class _Replica:
    def __init__(self, server: "PipelineServer", worker_id: str,
                 stage: int) -> None:
        self.server = server
        self.worker_id = worker_id
        self.stage = stage
        self.worker = server.cluster.worker(worker_id)
        self.upstream: list[str] = []          # world names we recv on
        #: (world, upstream router that routes onto it) — scale-down needs to
        #: know exactly which rotation each inbound edge lives in
        self.upstream_edges: list[tuple[str, ReplicaRouter]] = []
        self.router = ReplicaRouter()          # downstream worlds we send on
        self.router.set_load_probe(server._edge_load)
        self.inbox: asyncio.Queue = asyncio.Queue()
        self._pumps: dict[str, asyncio.Task] = {}
        self._run_task: Optional[asyncio.Task] = None
        self.draining = False
        # -- load/latency counters polled by control.MetricsHub ------------
        self.processed = 0
        self.inflight = 0
        self.wait_s_sum = 0.0        # inbox sojourn
        self.service_s_sum = 0.0     # compute + downstream send
        self.parked = 0              # sends parked on an empty rotation

    def queue_depth(self) -> int:
        return self.inbox.qsize() + self.inflight

    def watch_upstream(self, world: str, router: ReplicaRouter) -> None:
        self.upstream.append(world)
        self.upstream_edges.append((world, router))
        self._pumps[world] = self.worker.spawn(self._pump(world))

    def drop_upstream(self, world: str) -> None:
        task = self._pumps.pop(world, None)
        if task is not None and not task.done():
            task.cancel()
        if world in self.upstream:
            self.upstream.remove(world)
        self.upstream_edges = [(w, r) for w, r in self.upstream_edges
                               if w != world]

    async def _pump(self, world: str) -> None:
        comm = self.worker.comm
        try:
            while True:
                payload = await comm.recv(0, world)
                await self.inbox.put((payload, time.monotonic()))
        except (WorldBrokenError, WorldNotFoundError, asyncio.CancelledError):
            return

    async def run(self) -> None:
        fn = self.server.stage_fns[self.stage]
        sparams = self.server.stage_param_sets[self.stage]
        comm = self.worker.comm
        loop = asyncio.get_event_loop()
        while True:
            (req_id, x), t_enq = await self.inbox.get()
            t0 = time.monotonic()
            self.wait_s_sum += t0 - t_enq
            self.inflight += 1
            try:
                # run compute (incl. first-call jit compile) off the event
                # loop so watchdog heartbeats keep flowing — the same reason
                # the paper moves blocking NCCL init to a side thread (§4.2)
                y = await loop.run_in_executor(None, fn, sparams, x)
                sent = False
                while not sent:
                    world = self.router.try_pick(
                        least_loaded=self.server.least_loaded)
                    if world is None:
                        # Every downstream world is gone. Dying here would
                        # drop the in-flight payload and kill this serve loop
                        # for good — park instead and retry once the
                        # controller adds/heals a downstream replica.
                        self.parked += 1
                        await self.router.wait_healthy()
                        continue
                    try:
                        await comm.send((req_id, y), 1, world)
                        sent = True
                    except WorldBrokenError:
                        self.router.mark_broken(world)
                    except WorldNotFoundError:
                        self.router.remove(world)
                self.processed += 1
                self.service_s_sum += time.monotonic() - t0
            finally:
                self.inflight -= 1


class PipelineServer:
    """Build/serve/heal a replicated stage pipeline on a MultiWorld cluster."""

    def __init__(self, cluster: Cluster, model, params,
                 replicas: list[int], *, name: str = "pipe",
                 least_loaded: bool = False) -> None:
        self.cluster = cluster
        self.model = model
        self.cfg = model.cfg
        self.name = name
        self.replica_counts = replicas
        self.n_stages = len(replicas)
        self.least_loaded = least_loaded
        self.stage_specs = split_stages(self.cfg, self.n_stages)
        self.stage_param_sets = [stage_params(self.cfg, params, s)
                                 for s in self.stage_specs]
        self.stage_fns = [self._make_stage_fn(s) for s in self.stage_specs]
        self.instantiator = OnlineInstantiator(cluster)
        self.replicas: list[list[_Replica]] = [[] for _ in replicas]
        self.client = cluster.worker(CLIENT)
        self.client_router = ReplicaRouter()   # worlds to stage-0 replicas
        self.client_router.set_load_probe(self._edge_load)
        self._responses: dict[int, asyncio.Future] = {}
        self._req_ids = itertools.count()
        self._uid = itertools.count()
        self._collectors: dict[str, asyncio.Task] = {}
        #: downstream edge world -> receiving replica (load probing, drain)
        self._world_to_replica: dict[str, _Replica] = {}
        #: worlds the watchdog has fenced anywhere in the pipeline
        self.broken_worlds: set[str] = set()
        #: (t, kind, detail) scale/heal/drain timeline for Fig.5-style plots
        self.events: list[tuple[float, str, str]] = []
        self._wired_managers: set[str] = set()
        self._wire_manager(self.client.manager, self.client_router)

    def _make_stage_fn(self, spec: StageSpec):
        cfg = self.cfg

        @jax.jit
        def fn(sparams, x):
            return stage_forward(cfg, spec, sparams, x,
                                 tokens_in=spec.first)

        return fn

    def _edge_load(self, world: str) -> float:
        """Router load probe: queue depth of the replica behind an edge."""
        rep = self._world_to_replica.get(world)
        return float(rep.queue_depth()) if rep is not None else 0.0

    def _event(self, kind: str, detail: str) -> None:
        self.events.append((time.monotonic(), kind, detail))

    # ------------------------------------------------------------------ build
    async def start(self) -> None:
        for si, count in enumerate(self.replica_counts):
            for _ in range(count):
                await self.add_replica(si)

    def _wire_manager(self, manager, router: Optional[ReplicaRouter]) -> None:
        """Fault listeners: fenced worlds leave the router rotation and are
        recorded in ``broken_worlds`` (the controller's failure signal)."""
        if manager.worker_id in self._wired_managers:
            return
        self._wired_managers.add(manager.worker_id)

        def cb(world: str, reason: str) -> None:
            if router is not None:
                router.mark_broken(world)
            self.broken_worlds.add(world)
            self._event("world_broken", world)

        manager.on_world_broken(cb)

    async def add_replica(self, stage: int) -> str:
        """Online instantiation of one replica (paper Fig. 2c / §4.2)."""
        worker_id = f"{self.name}-s{stage}-r{next(self._uid)}"
        rep = _Replica(self, worker_id, stage)
        specs: list[WorldSpec] = []
        #: (world, router to register it in, peer replica or None for client)
        upstream_edges: list[tuple[str, ReplicaRouter, Optional[_Replica]]] = []
        down_watchers: list[tuple[str, Optional[_Replica]]] = []

        if stage == 0:
            w = _edge(self.name, CLIENT, worker_id)
            specs.append(WorldSpec.pair(w, CLIENT, worker_id))
            upstream_edges.append((w, self.client_router, None))
        else:
            for up in self.replicas[stage - 1]:
                if not up.worker.alive or up.draining:
                    continue
                w = _edge(self.name, up.worker_id, worker_id)
                specs.append(WorldSpec.pair(w, up.worker_id, worker_id))
                upstream_edges.append((w, up.router, up))
        if stage == self.n_stages - 1:
            w = _edge(self.name, worker_id, CLIENT)
            specs.append(WorldSpec.pair(w, worker_id, CLIENT))
            down_watchers.append((w, None))
        else:
            for down in self.replicas[stage + 1]:
                if not down.worker.alive or down.draining:
                    continue
                w = _edge(self.name, worker_id, down.worker_id)
                specs.append(WorldSpec.pair(w, worker_id, down.worker_id))
                down_watchers.append((w, down))

        await self.instantiator.instantiate(specs)

        # A peer snapshotted above may have been drained/healed away while
        # the rendezvous was in flight — wiring it now would route payloads
        # into a torn-down replica. Re-check and discard the fresh world
        # instead (None peer = the client, which never goes away).
        def _gone(peer: Optional[_Replica], adjacent: list[_Replica]) -> bool:
            return peer is not None and (peer not in adjacent
                                         or not peer.worker.alive
                                         or peer.draining)

        for world, router, up in upstream_edges:
            if _gone(up, self.replicas[stage - 1] if stage else []):
                self._remove_world_everywhere(world)
                continue
            rep.watch_upstream(world, router)
            self._world_to_replica[world] = rep
            router.add(world)
        for world, down in down_watchers:
            if _gone(down, self.replicas[stage + 1]
                     if stage < self.n_stages - 1 else []):
                self._remove_world_everywhere(world)
                continue
            rep.router.add(world)
            if down is None:
                self._watch_client_world(world)
            else:
                down.watch_upstream(world, rep.router)
                self._world_to_replica[world] = down

        # replica-side fault listener: broken downstream worlds leave rotation
        self._wire_manager(rep.worker.manager, rep.router)

        rep._run_task = rep.worker.spawn(rep.run())
        self.replicas[stage].append(rep)
        self._event("add_replica", worker_id)
        return worker_id

    # ------------------------------------------------------------- scale-down
    async def remove_replica(self, stage: int,
                             worker_id: Optional[str] = None, *,
                             drain: bool = True,
                             timeout: float = 30.0) -> str:
        """Retire one replica of ``stage``.

        ``drain=True`` (scale-down): stop routing to it, wait until its inbox,
        in-flight work, and adjacent transport channels are all empty, then
        tear its worlds down — zero request loss by construction.
        ``drain=False`` (heal): the replica is already dead; just unhook the
        bookkeeping and purge its (broken) worlds so a replacement can be
        instantiated cleanly.
        """
        reps = self.replicas[stage]
        if worker_id is not None:
            rep = next((r for r in reps if r.worker_id == worker_id), None)
            if rep is None:
                raise KeyError(f"no replica {worker_id} in stage {stage}")
        else:
            live = [r for r in reps if r.worker.alive and not r.draining]
            if not live:
                raise RuntimeError(f"stage {stage} has no removable replica")
            rep = min(live, key=lambda r: r.queue_depth())
        if drain and len([r for r in reps
                          if r.worker.alive and not r.draining]) <= 1:
            raise RuntimeError(
                f"refusing to drain the last healthy replica of stage {stage}")

        rep.draining = True
        self._event("drain_begin", rep.worker_id)
        # 1. stop routing new work to it (no new picks can reach these
        #    worlds once removed; an already-picked send has already been
        #    appended to the channel — the drain wait below flushes it)
        for world, router in rep.upstream_edges:
            router.remove(world)
        # 2. drain to zero
        if drain:
            await self._drain(rep, timeout)
        # 3. teardown in one event-loop tick
        self._teardown_replica(rep)
        self._event("remove_replica", rep.worker_id)
        return rep.worker_id

    async def _drain(self, rep: _Replica, timeout: float) -> None:
        transport = self.cluster.transport
        deadline = time.monotonic() + timeout

        def flushed() -> bool:
            return (rep.inbox.empty() and rep.inflight == 0
                    and all(transport.pending(w) == 0
                            for w in rep.upstream)
                    and all(transport.pending(w) == 0
                            for w in rep.router.worlds))

        while True:
            # A pump can be suspended on a fairness yield *between* popping a
            # payload off the channel and enqueueing it (neither place counts
            # it) — one scheduler pass lets any such pump land its payload,
            # so only two consecutive flushed observations prove empty.
            if flushed():
                await asyncio.sleep(0)
                if flushed():
                    return
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"drain of {rep.worker_id} exceeded {timeout}s "
                    f"(queue={rep.queue_depth()})")
            await asyncio.sleep(0.005)

    def _teardown_replica(self, rep: _Replica) -> None:
        """Unhook a replica and remove its worlds on every member in one
        synchronous pass — no await between key deletions, so no watchdog
        cycle can observe a half-removed world and fence it spuriously."""
        if rep._run_task is not None and not rep._run_task.done():
            rep._run_task.cancel()
        for world in list(rep.upstream):
            rep.drop_upstream(world)
            self._world_to_replica.pop(world, None)
            self._remove_world_everywhere(world)
        for world in list(rep.router.worlds):
            down = self._world_to_replica.pop(world, None)
            if down is not None:
                down.drop_upstream(world)
            collector = self._collectors.pop(world, None)
            if collector is not None and not collector.done():
                collector.cancel()
            rep.router.remove(world)
            self._remove_world_everywhere(world)
        if rep in self.replicas[rep.stage]:
            self.replicas[rep.stage].remove(rep)
        # reclaim the worker: stop its watchdog task and drop it from the
        # cluster registry, or every scale/heal cycle leaks one worker whose
        # heartbeat loop ticks forever
        worker = self.cluster.workers.pop(rep.worker_id, None)
        if worker is not None:
            worker.kill()
            worker.manager.shutdown()

    def _remove_world_everywhere(self, world: str) -> None:
        for worker in list(self.cluster.workers.values()):
            if world in worker.manager.worlds:
                worker.manager.remove_world(world)

    # ---------------------------------------------------------------- serving
    def _watch_client_world(self, world: str) -> None:
        self._collectors[world] = self.client.spawn(self._collect(world))

    async def _collect(self, world: str) -> None:
        comm = self.client.comm
        try:
            while True:
                req_id, logits = await comm.recv(0, world)
                fut = self._responses.pop(req_id, None)
                if fut is not None and not fut.done():
                    fut.set_result(logits)
        except (WorldBrokenError, WorldNotFoundError, asyncio.CancelledError):
            return

    async def submit(self, tokens: np.ndarray, *, timeout: float = 30.0,
                     retries: int = 2) -> jax.Array:
        """Score a token batch through the pipeline; returns logits (B,S,V).

        Beyond-paper nicety: at-least-once redispatch — if a replica dies
        with the request in flight, the client re-sends after ``timeout``.
        A fully-empty stage-0 rotation (every entry replica down) parks the
        attempt until the controller heals one, instead of failing fast.
        """
        x = jnp.asarray(tokens, jnp.int32)
        last_err: Optional[Exception] = None
        for _ in range(retries + 1):
            world = self.client_router.try_pick(self.least_loaded)
            if world is None:
                try:
                    await asyncio.wait_for(
                        self.client_router.wait_healthy(), timeout)
                except asyncio.TimeoutError as e:
                    last_err = e
                    continue
                world = self.client_router.try_pick(self.least_loaded)
                if world is None:
                    continue
            req_id = next(self._req_ids)
            fut: asyncio.Future = asyncio.get_event_loop().create_future()
            self._responses[req_id] = fut
            try:
                await self.client.comm.send((req_id, x), 1, world)
                return await asyncio.wait_for(fut, timeout)
            except WorldBrokenError as e:
                self.client_router.mark_broken(world)
                last_err = e
            except WorldNotFoundError as e:
                self.client_router.remove(world)
                last_err = e
            except asyncio.TimeoutError as e:
                last_err = e
            finally:
                self._responses.pop(req_id, None)
        raise RuntimeError(f"request failed after {retries + 1} attempts: "
                           f"{last_err}")

    # ------------------------------------------------------------------ intro
    def healthy_replicas(self, stage: int) -> list[str]:
        out = []
        for rep in self.replicas[stage]:
            if not rep.worker.alive or rep.draining:
                continue
            out.append(rep.worker_id)
        return out

    def failed_replicas(self, stage: int) -> list[str]:
        """Heal candidates: replicas the watchdog has cut off — every
        upstream edge fenced, so no traffic can reach them (or the worker
        is outright dead)."""
        out = []
        for rep in self.replicas[stage]:
            if rep.draining:
                continue
            dead = not rep.worker.alive
            cut_off = bool(rep.upstream) and all(
                w in self.broken_worlds for w in rep.upstream)
            if dead or cut_off:
                out.append(rep.worker_id)
        return out

    def replica_stats(self) -> dict[str, dict[str, Any]]:
        """Introspection snapshot of the raw per-replica load counters
        (MetricsHub reads the ``_Replica`` attributes directly; this is the
        public debugging/dashboard view of the same signals)."""
        out: dict[str, dict[str, Any]] = {}
        for stage, reps in enumerate(self.replicas):
            for rep in reps:
                out[rep.worker_id] = {
                    "stage": stage,
                    "alive": rep.worker.alive,
                    "draining": rep.draining,
                    "queue_depth": rep.queue_depth(),
                    "inflight": rep.inflight,
                    "processed": rep.processed,
                    "wait_s_sum": rep.wait_s_sum,
                    "service_s_sum": rep.service_s_sum,
                    "parked": rep.parked,
                }
        return out

"""MultiWorld pipeline server — the paper's Fig. 2 with real models.

Topology: the model is split into N stages (serving/partition.py); each stage
has one or more replica workers; every (upstream replica, downstream replica)
pair gets its own pairwise world, as does every (client, stage-0 replica) and
(last-stage replica, client) pair. Worlds are fault domains: a replica death
breaks only its edges; upstream routers drop the broken worlds and keep
serving through the survivors; ``add_replica`` performs online instantiation
(new worker + fresh worlds) without touching any existing world.

Payloads are (request_id, tensor) tuples moved zero-copy by the in-process
transport; on real hardware the same worlds carry ICI/NCCL transfers.
"""
from __future__ import annotations

import asyncio
import itertools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Cluster, WorldBrokenError, WorldSpec
from repro.core.online import OnlineInstantiator
from .partition import StageSpec, split_stages, stage_forward, stage_params
from .router import ReplicaRouter

CLIENT = "client"


def _edge(name: str, up: str, down: str) -> str:
    return f"{name}:{up}->{down}"


class _Replica:
    def __init__(self, server: "PipelineServer", worker_id: str,
                 stage: int) -> None:
        self.server = server
        self.worker_id = worker_id
        self.stage = stage
        self.worker = server.cluster.worker(worker_id)
        self.upstream: list[str] = []          # world names we recv on
        self.router = ReplicaRouter()          # downstream worlds we send on
        self.inbox: asyncio.Queue = asyncio.Queue()
        self._pumps: dict[str, asyncio.Task] = {}
        self.processed = 0

    def watch_upstream(self, world: str) -> None:
        self.upstream.append(world)
        self._pumps[world] = self.worker.spawn(self._pump(world))

    async def _pump(self, world: str) -> None:
        comm = self.worker.comm
        try:
            while True:
                payload = await comm.recv(0, world)
                await self.inbox.put(payload)
        except (WorldBrokenError, asyncio.CancelledError):
            return

    async def run(self) -> None:
        spec = self.server.stage_specs[self.stage]
        fn = self.server.stage_fns[self.stage]
        sparams = self.server.stage_param_sets[self.stage]
        comm = self.worker.comm
        loop = asyncio.get_event_loop()
        while True:
            req_id, x = await self.inbox.get()
            # run compute (incl. first-call jit compile) off the event loop so
            # watchdog heartbeats keep flowing — the same reason the paper
            # moves blocking NCCL init to a side thread (§4.2)
            y = await loop.run_in_executor(None, fn, sparams, x)
            self.processed += 1
            sent = False
            while not sent:
                world = self.router.pick()
                try:
                    await comm.send((req_id, y), 1, world)
                    sent = True
                except WorldBrokenError:
                    self.router.mark_broken(world)


class PipelineServer:
    """Build/serve/heal a replicated stage pipeline on a MultiWorld cluster."""

    def __init__(self, cluster: Cluster, model, params,
                 replicas: list[int], *, name: str = "pipe") -> None:
        self.cluster = cluster
        self.model = model
        self.cfg = model.cfg
        self.name = name
        self.replica_counts = replicas
        self.n_stages = len(replicas)
        self.stage_specs = split_stages(self.cfg, self.n_stages)
        self.stage_param_sets = [stage_params(self.cfg, params, s)
                                 for s in self.stage_specs]
        self.stage_fns = [self._make_stage_fn(s) for s in self.stage_specs]
        self.instantiator = OnlineInstantiator(cluster)
        self.replicas: list[list[_Replica]] = [[] for _ in replicas]
        self.client = cluster.worker(CLIENT)
        self.client_router = ReplicaRouter()   # worlds to stage-0 replicas
        self._responses: dict[int, asyncio.Future] = {}
        self._req_ids = itertools.count()
        self._uid = itertools.count()
        self._collector: Optional[asyncio.Task] = None
        self._collector_worlds: list[str] = []

    def _make_stage_fn(self, spec: StageSpec):
        cfg = self.cfg

        @jax.jit
        def fn(sparams, x):
            return stage_forward(cfg, spec, sparams, x,
                                 tokens_in=spec.first)

        return fn

    # ------------------------------------------------------------------ build
    async def start(self) -> None:
        for si, count in enumerate(self.replica_counts):
            for _ in range(count):
                await self.add_replica(si, _initial=True)
        self._wire_fault_listeners()

    def _wire_fault_listeners(self) -> None:
        def on_break(owner_router: ReplicaRouter):
            def cb(world: str, reason: str) -> None:
                owner_router.mark_broken(world)
            return cb
        self.client.manager.on_world_broken(on_break(self.client_router))

    async def add_replica(self, stage: int, _initial: bool = False) -> str:
        """Online instantiation of one replica (paper Fig. 2c / §4.2)."""
        worker_id = f"{self.name}-s{stage}-r{next(self._uid)}"
        rep = _Replica(self, worker_id, stage)
        specs: list[WorldSpec] = []
        upstream_edges: list[tuple[str, Any]] = []   # (world, upstream router)
        downstream_edges: list[str] = []

        if stage == 0:
            w = _edge(self.name, CLIENT, worker_id)
            specs.append(WorldSpec.pair(w, CLIENT, worker_id))
            upstream_edges.append((w, self.client_router))
        else:
            for up in self.replicas[stage - 1]:
                w = _edge(self.name, up.worker_id, worker_id)
                specs.append(WorldSpec.pair(w, up.worker_id, worker_id))
                upstream_edges.append((w, up.router))
        down_watchers: list[tuple[str, _Replica]] = []
        if stage == self.n_stages - 1:
            w = _edge(self.name, worker_id, CLIENT)
            specs.append(WorldSpec.pair(w, worker_id, CLIENT))
            downstream_edges.append(w)
        else:
            for down in self.replicas[stage + 1]:
                w = _edge(self.name, worker_id, down.worker_id)
                specs.append(WorldSpec.pair(w, worker_id, down.worker_id))
                downstream_edges.append(w)
                down_watchers.append((w, down))

        await self.instantiator.instantiate(specs)

        for world, router in upstream_edges:
            rep.watch_upstream(world)
            router.add(world)
        for world in downstream_edges:
            rep.router.add(world)
        for world, down in down_watchers:
            down.watch_upstream(world)   # downstream replicas pump the new edge
        if stage == self.n_stages - 1:
            self._watch_client_world(
                _edge(self.name, worker_id, CLIENT))

        # replica-side fault listener: broken downstream worlds leave rotation
        rep.worker.manager.on_world_broken(
            lambda wn, _r, router=rep.router: router.mark_broken(wn))

        rep.worker.spawn(rep.run())
        self.replicas[stage].append(rep)
        return worker_id

    # ---------------------------------------------------------------- serving
    def _watch_client_world(self, world: str) -> None:
        self._collector_worlds.append(world)
        self.client.spawn(self._collect(world))

    async def _collect(self, world: str) -> None:
        comm = self.client.comm
        try:
            while True:
                req_id, logits = await comm.recv(0, world)
                fut = self._responses.pop(req_id, None)
                if fut is not None and not fut.done():
                    fut.set_result(logits)
        except (WorldBrokenError, asyncio.CancelledError):
            return

    async def submit(self, tokens: np.ndarray, *, timeout: float = 30.0,
                     retries: int = 2) -> jax.Array:
        """Score a token batch through the pipeline; returns logits (B,S,V).

        Beyond-paper nicety: at-least-once redispatch — if a replica dies
        with the request in flight, the client re-sends after ``timeout``.
        """
        x = jnp.asarray(tokens, jnp.int32)
        last_err: Optional[Exception] = None
        for _ in range(retries + 1):
            req_id = next(self._req_ids)
            fut: asyncio.Future = asyncio.get_event_loop().create_future()
            self._responses[req_id] = fut
            world = self.client_router.pick()
            try:
                await self.client.comm.send((req_id, x), 1, world)
                return await asyncio.wait_for(fut, timeout)
            except WorldBrokenError as e:
                self.client_router.mark_broken(world)
                last_err = e
            except asyncio.TimeoutError as e:
                last_err = e
            finally:
                self._responses.pop(req_id, None)
        raise RuntimeError(f"request failed after {retries + 1} attempts: "
                           f"{last_err}")

    # ------------------------------------------------------------------ intro
    def healthy_replicas(self, stage: int) -> list[str]:
        out = []
        for rep in self.replicas[stage]:
            if not rep.worker.alive:
                continue
            out.append(rep.worker_id)
        return out
